"""Unified serving observability: tracing, metrics, trace-replay audit.

Three surfaces, all defaulting OFF so historical timelines and BENCH
numbers regenerate bit-identically:

  * ``trace.Tracer`` — structured span tracer on the injected serving
    clock; records every arrival's full lifecycle plus speculation /
    chaos annotations; Chrome trace-event (Perfetto-loadable) JSON
    export, byte-reproducible under the deterministic clock.
    ``Tracer.disabled`` is the falsy no-op default.
    ``trace.StreamingTracer`` is the bounded-memory variant for long
    open-loop fleet runs: a ring buffer spilled incrementally to a
    JSON Lines file, auditable with the same auditor.
  * ``metrics.Metrics`` — registry of counters / gauges / histograms
    with a DDSketch-style streaming quantile sketch (p50/p95/p99);
    one ``snapshot()``/``reset()`` API absorbing the stack's formerly
    ad hoc counters; fleet-wide union via ``Metrics.merged`` (exact
    sketch merge) and Prometheus text export via ``to_prometheus``.
  * ``audit`` — trace-replay auditor re-verifying the serving
    invariants from a trace alone (``python -m repro.obs.audit``);
    reads both export formats.

This package imports nothing from ``core`` or ``serving`` (no jax), so
any layer may depend on it.
"""
from .audit import (AuditReport, audit_doc, audit_file,  # noqa: F401
                    audit_tracer, jsonl_to_chrome, validate_chrome)
from .metrics import Metrics, QuantileSketch  # noqa: F401
from .trace import StreamingTracer, Tracer, TraceEvent  # noqa: F401
