"""Metrics registry: counters, gauges, and streaming quantile sketches.

One ``Metrics`` object is the single accounting surface for a serving
stack: the engine, its ``FeatureCache``, and every ``TransportChannel``
share the registry, so the ad hoc per-object counters that used to be
scattered across the stack (``duplicate_commits``, ``cancelled_bytes``,
placement tallies, ...) become names in one flat namespace with one
``snapshot()``/``reset()`` API.  The legacy attributes survive as
read-through properties on their original owners.

``QuantileSketch`` is a DDSketch-style log-bucketed quantile sketch:

  * deterministic — bucket index is ``ceil(log_gamma(v))``; no sampling,
    no randomness, insertion-order independent;
  * relative-error bounded — any reported quantile ``q̂`` satisfies
    ``|q̂ - q| <= rel_err * q`` against the true sample quantile;
  * exactly mergeable — ``a.merge(b)`` adds bucket counts, so merging
    is associative and commutative on the bucket state (the float
    running ``sum`` is the only approximately-associative field).

Nothing in this module imports jax or the serving stack; it is safe to
use from any layer (including ``core``).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional

__all__ = ["QuantileSketch", "Metrics"]

# values below this land in the exact zero bucket (log would diverge)
_ZERO_EPS = 1e-12

# log_gamma ratios within this of an integer snap to it before ceil:
# a value that far inside a bucket boundary is within a relative
# gamma^1e-9 - 1 (~1e-11 at rel_err=0.01) of the boundary itself, far
# below any rel_err the sketch accepts, so snapping never misassigns a
# genuinely interior value.
_BOUNDARY_EPS = 1e-9


class QuantileSketch:
    """DDSketch-style streaming quantile sketch for non-negative values."""

    __slots__ = ("rel_err", "_gamma", "_log_gamma", "_buckets", "_zero",
                 "_count", "_sum", "_min", "_max")

    def __init__(self, rel_err: float = 0.01):
        if not 0.0 < rel_err < 1.0:
            raise ValueError(f"rel_err must be in (0, 1), got {rel_err}")
        self.rel_err = rel_err
        self._gamma = (1.0 + rel_err) / (1.0 - rel_err)
        self._log_gamma = math.log(self._gamma)
        self._buckets: Dict[int, int] = {}
        self._zero = 0
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    # ---- ingest -----------------------------------------------------
    def add(self, value: float) -> None:
        v = float(value)
        if not math.isfinite(v) or v < 0.0:
            raise ValueError(f"QuantileSketch.add: need finite v >= 0, got {value}")
        if v < _ZERO_EPS:
            self._zero += 1
        else:
            # Bucket i covers (gamma^(i-1), gamma^i]. A value sitting
            # exactly on a boundary (v == gamma^i) has ratio == i in
            # exact arithmetic, but float slop in log()/division can
            # push it infinitesimally above i, and ceil then lands it
            # in bucket i+1 — whose midpoint breaks the advertised
            # |q̂ - q| <= rel_err*q bound. Snap near-integer ratios
            # before taking ceil.
            ratio = math.log(v) / self._log_gamma
            nearest = round(ratio)
            if abs(ratio - nearest) < _BOUNDARY_EPS:
                i = int(nearest)
            else:
                i = math.ceil(ratio)
            self._buckets[i] = self._buckets.get(i, 0) + 1
        self._count += 1
        self._sum += v
        self._min = min(self._min, v)
        self._max = max(self._max, v)

    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Return a NEW sketch holding both inputs' samples."""
        if not isinstance(other, QuantileSketch):
            raise TypeError("can only merge QuantileSketch with QuantileSketch")
        if other.rel_err != self.rel_err:
            raise ValueError("cannot merge sketches with different rel_err")
        out = QuantileSketch(self.rel_err)
        out._buckets = dict(self._buckets)
        for i, n in other._buckets.items():
            out._buckets[i] = out._buckets.get(i, 0) + n
        out._zero = self._zero + other._zero
        out._count = self._count + other._count
        out._sum = self._sum + other._sum
        out._min = min(self._min, other._min)
        out._max = max(self._max, other._max)
        return out

    # ---- read -------------------------------------------------------
    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def min(self) -> Optional[float]:
        return None if self._count == 0 else self._min

    @property
    def max(self) -> Optional[float]:
        return None if self._count == 0 else self._max

    def quantile(self, q: float) -> Optional[float]:
        """Value within ``rel_err`` (relative) of the true q-quantile.

        The true q-quantile here is ``sorted(samples)[floor(q*(n-1))]``.
        """
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self._count == 0:
            return None
        rank = int(math.floor(q * (self._count - 1)))  # 0-indexed target
        cum = self._zero
        if cum > rank:
            return 0.0
        for i in sorted(self._buckets):
            cum += self._buckets[i]
            if cum > rank:
                mid = 2.0 * self._gamma ** i / (self._gamma + 1.0)
                # clamping toward the observed range never leaves the
                # error bound: the true quantile lies inside [min, max]
                return min(max(mid, self._min), self._max)
        return self._max  # unreachable unless float slop; safe answer

    def state(self):
        """Exact mergeable state (for associativity checks / equality)."""
        return (tuple(sorted(self._buckets.items())), self._zero,
                self._count, self._min, self._max)

    def summary(self) -> dict:
        if self._count == 0:
            return {"count": 0}
        return {
            "count": self._count,
            "sum": self._sum,
            "mean": self._sum / self._count,
            "min": self._min,
            "max": self._max,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"QuantileSketch(count={self._count}, "
                f"buckets={len(self._buckets)}, rel_err={self.rel_err})")


class Metrics:
    """Registry of named counters, gauges, and quantile histograms.

    * counters — monotonically accumulated floats (``inc``); read with
      ``get`` (0.0 when never incremented).
    * gauges — last-write-wins values (``set_gauge``), or lazily
      evaluated callables (``gauge_fn``) sampled at ``snapshot()`` time.
    * histograms — ``QuantileSketch`` per name (``observe``).

    ``snapshot()`` returns one JSON-serializable dict with sorted keys;
    ``reset()`` zeroes counters and histogram contents but keeps gauge
    registrations (callable gauges describe live state, not history).
    """

    def __init__(self, *, rel_err: float = 0.01):
        self.rel_err = rel_err
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._gauge_fns: Dict[str, Callable[[], float]] = {}
        self._hists: Dict[str, QuantileSketch] = {}

    # ---- counters ---------------------------------------------------
    def inc(self, name: str, value: float = 1) -> None:
        self._counters[name] = self._counters.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        return self._counters.get(name, default)

    # ---- gauges -----------------------------------------------------
    def set_gauge(self, name: str, value: float) -> None:
        self._gauges[name] = value

    def gauge_fn(self, name: str, fn: Callable[[], float]) -> None:
        self._gauge_fns[name] = fn

    # ---- histograms -------------------------------------------------
    def observe(self, name: str, value: float) -> None:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = QuantileSketch(self.rel_err)
        h.add(value)

    def histogram(self, name: str) -> Optional[QuantileSketch]:
        return self._hists.get(name)

    # ---- merge ------------------------------------------------------
    @classmethod
    def merged(cls, registries) -> "Metrics":
        """Exact fleet-wide union of registries: counters and gauges
        sum (callable gauges are sampled), histograms merge through
        ``QuantileSketch.merge`` — associative and commutative on the
        bucket state, so N replicas' sketches fold into the same
        fleet-wide quantiles regardless of merge order."""
        regs = list(registries)
        if not regs:
            return cls()
        rel_err = regs[0].rel_err
        if any(r.rel_err != rel_err for r in regs):
            raise ValueError("cannot merge registries with different "
                             "rel_err")
        out = cls(rel_err=rel_err)
        for r in regs:
            for k, v in r._counters.items():
                out._counters[k] = out._counters.get(k, 0) + v
            gauges = dict(r._gauges)
            for k, fn in r._gauge_fns.items():
                gauges[k] = fn()
            for k, v in gauges.items():
                out._gauges[k] = out._gauges.get(k, 0) + v
            for k, h in r._hists.items():
                cur = out._hists.get(k)
                out._hists[k] = h.merge(cur) if cur is not None else \
                    h.merge(QuantileSketch(rel_err))
        return out

    # ---- export -----------------------------------------------------
    def to_prometheus(self, *, prefix: str = "emsserve") -> str:
        """Prometheus text exposition format (version 0.0.4).

        Counters export as ``counter``, gauges as ``gauge``, and each
        quantile sketch as a ``summary`` (p50/p95/p99 + ``_sum`` /
        ``_count``). Metric names are sanitized (dots and dashes to
        underscores) and prefixed; output is sorted and deterministic.

        Sanitization is lossy (``cache.hits`` and ``cache_hits`` both
        map to ``cache_hits``), so distinct registry keys — or the same
        key registered as two kinds — could collide into one exported
        name, emitting duplicate ``# TYPE`` lines that scrapers reject.
        Collisions are disambiguated deterministically with a numeric
        suffix (``_2``, ``_3``, ...) in sorted-key order, so every
        exported name carries exactly one ``# TYPE`` line.
        """
        gauges = dict(self._gauges)
        for k, fn in self._gauge_fns.items():
            gauges[k] = fn()

        def _sanitize(k):
            base = "".join(c if (c.isalnum() or c == "_") else "_"
                           for c in k)
            return f"{prefix}_{base}" if prefix else base

        names: Dict[tuple, str] = {}
        used = set()
        for kind, keys in (("counter", sorted(self._counters)),
                           ("gauge", sorted(gauges)),
                           ("summary", sorted(self._hists))):
            for k in keys:
                n = _sanitize(k)
                cand, suffix = n, 2
                while cand in used:
                    cand = f"{n}_{suffix}"
                    suffix += 1
                used.add(cand)
                names[(kind, k)] = cand

        def num(v):
            return repr(float(v))

        lines = []
        for k in sorted(self._counters):
            n = names[("counter", k)]
            lines.append(f"# TYPE {n} counter")
            lines.append(f"{n} {num(self._counters[k])}")
        for k in sorted(gauges):
            n = names[("gauge", k)]
            lines.append(f"# TYPE {n} gauge")
            lines.append(f"{n} {num(gauges[k])}")
        for k in sorted(self._hists):
            h = self._hists[k]
            n = names[("summary", k)]
            lines.append(f"# TYPE {n} summary")
            if h.count:
                for q in (0.5, 0.95, 0.99):
                    lines.append(f'{n}{{quantile="{q}"}} '
                                 f"{num(h.quantile(q))}")
            lines.append(f"{n}_sum {num(h.sum)}")
            lines.append(f"{n}_count {h.count}")
        return "\n".join(lines) + "\n"

    # ---- lifecycle --------------------------------------------------
    def snapshot(self) -> dict:
        gauges = dict(self._gauges)
        for name, fn in self._gauge_fns.items():
            gauges[name] = fn()
        return {
            "counters": {k: self._counters[k] for k in sorted(self._counters)},
            "gauges": {k: gauges[k] for k in sorted(gauges)},
            "histograms": {k: self._hists[k].summary()
                           for k in sorted(self._hists)},
        }

    def reset(self) -> None:
        self._counters.clear()
        self._gauges.clear()
        self._hists.clear()
