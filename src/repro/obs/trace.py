"""Structured span tracer on the injected serving clock.

``Tracer`` records the full lifecycle of every arrival the serving
stack handles — arrival, flush-queue wait, encode@tier compute spans,
uplink/downlink transport flights by flight id, tail fusion, cache
commits, partial/final prediction emits — plus the speculation and
chaos annotations (race start/win, cancel, crash detect, redispatch,
rejoin, evict).  Timestamps come from whatever clock the engine runs
on: the simulated per-tier episode clock in tiered mode (``set_time``
is called at each arrival), or the wall ``time_fn`` in flush mode
(``clock`` attribute).

Determinism: every event carries a monotone per-tracer sequence number,
and export stable-sorts by ``(ts, seq)`` and serializes with sorted
keys — so under the deterministic simulated clock the exported trace
file is byte-reproducible.  The sequence number is also the program-
order causality signal the trace-replay auditor (``obs.audit``) relies
on, since in tiered mode distinct hosts' spans legitimately overlap in
simulated time.

Export is Chrome trace-event format (the JSON object form), directly
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:
``ph="X"`` complete spans, ``ph="i"`` instants, ``ph="M"`` metadata
naming each track.  Track ids are assigned from the sorted set of
track names so they never depend on event arrival order.

``Tracer.disabled`` is a shared no-op singleton that is falsy, so hot
paths guard instrumentation with ``if self.tracer:`` and pay one
branch when tracing is off.
"""
from __future__ import annotations

import json
from typing import Callable, List, Optional

__all__ = ["Tracer", "TraceEvent", "StreamingTracer"]


class TraceEvent:
    """One recorded event (a span when ``dur`` is not None)."""

    __slots__ = ("name", "cat", "ts", "dur", "track", "args", "seq")

    def __init__(self, name, cat, ts, dur, track, args, seq):
        self.name = name
        self.cat = cat
        self.ts = float(ts)
        self.dur = None if dur is None else float(dur)
        self.track = track
        self.args = args
        self.seq = seq

    def __repr__(self):  # pragma: no cover - debugging aid
        kind = "span" if self.dur is not None else "instant"
        return (f"TraceEvent({self.name!r}, {kind}, t={self.ts:.6f}, "
                f"track={self.track!r}, seq={self.seq})")


class Tracer:
    """Append-only event recorder with deterministic Chrome export."""

    disabled: "Tracer"  # assigned below (a _DisabledTracer singleton)

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.events: List[TraceEvent] = []
        self.clock = clock       # wall-mode default timestamp source
        self._now = 0.0          # simulated-mode default timestamp
        self._seq = 0

    def __bool__(self) -> bool:
        return True

    # ---- clocks -----------------------------------------------------
    def set_time(self, t: float) -> None:
        """Advance the simulated-clock default timestamp."""
        self._now = float(t)

    def now(self) -> float:
        return self.clock() if self.clock is not None else self._now

    # ---- record -----------------------------------------------------
    def span(self, name: str, cat: str, t0: float, t1: float, *,
             track: str = "engine", **args) -> None:
        """Record a complete span [t0, t1] on ``track``."""
        self._seq += 1
        self.events.append(TraceEvent(name, cat, t0, max(0.0, t1 - t0),
                                      track, args, self._seq))

    def instant(self, name: str, cat: str, at: Optional[float] = None, *,
                track: str = "engine", **args) -> None:
        """Record a point event at ``at`` (default: the tracer clock).

        The parameter is named ``at`` (not ``t``) so callers can carry
        a ``t=...`` field in the event args without a collision."""
        self._seq += 1
        ts = self.now() if at is None else at
        self.events.append(TraceEvent(name, cat, ts, None, track,
                                      args, self._seq))

    def clear(self) -> None:
        self.events.clear()
        self._seq = 0
        self._now = 0.0

    # ---- export -----------------------------------------------------
    def to_chrome(self, other_data: Optional[dict] = None) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        tracks = sorted({e.track for e in self.events})
        tids = {name: i + 1 for i, name in enumerate(tracks)}
        out = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
                "ts": 0, "args": {"name": "EMSServe"}}]
        for name, tid in tids.items():
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": tid, "ts": 0, "args": {"name": name}})
        for e in sorted(self.events, key=lambda e: (e.ts, e.seq)):
            ev = {
                "name": e.name,
                "cat": e.cat,
                "ph": "X" if e.dur is not None else "i",
                "ts": round(e.ts * 1e6, 3),       # seconds -> microseconds
                "pid": 1,
                "tid": tids[e.track],
                "args": {**e.args, "seq": e.seq},
            }
            if e.dur is not None:
                ev["dur"] = round(e.dur * 1e6, 3)
            else:
                ev["s"] = "t"                      # instant scope: thread
            out.append(ev)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if other_data:
            doc["otherData"] = other_data
        return doc

    def export(self, path, other_data: Optional[dict] = None) -> int:
        """Write the Chrome trace JSON to ``path``; returns event count.

        Serialization is canonical (sorted keys, no whitespace), so two
        identical event streams produce byte-identical files.
        """
        doc = self.to_chrome(other_data)
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True, separators=(",", ":"))
        return len(self.events)


class StreamingTracer(Tracer):
    """Bounded-memory tracer for long open-loop runs: O(buffer), not
    O(events).

    Events accumulate in the in-memory ring (``self.events``); whenever
    it reaches ``buffer`` entries they are spilled to ``path`` as JSON
    Lines — one canonical-JSON event per line, in *seq* (program) order,
    the order the trace-replay auditor consumes. ``close()`` flushes the
    tail and (optionally) appends a final ``{"otherData": ...}`` line
    carrying live channel/metrics stats for the auditor's conservation
    cross-check. The resulting ``.jsonl`` file is auditable with
    ``python -m repro.obs.audit`` (``audit_file`` sniffs the format).

    Unlike ``Tracer.export`` there is no global ``(ts, seq)`` sort — a
    bounded writer cannot sort what it has already spilled — so the
    JSONL is an *audit/archive* format; convert to a Perfetto-loadable
    Chrome doc offline with ``repro.obs.audit.jsonl_to_chrome``.
    """

    def __init__(self, path, *, buffer: int = 1024,
                 clock: Optional[Callable[[], float]] = None):
        super().__init__(clock)
        if buffer < 1:
            raise ValueError(f"buffer must be >= 1, got {buffer}")
        self.path = path
        self.buffer = buffer
        self.events_written = 0
        self._fh = open(path, "w")
        self._closed = False

    # ---- record (spill when the ring fills) -------------------------
    def span(self, name: str, cat: str, t0: float, t1: float, *,
             track: str = "engine", **args) -> None:
        super().span(name, cat, t0, t1, track=track, **args)
        if len(self.events) >= self.buffer:
            self._spill()

    def instant(self, name: str, cat: str, at: Optional[float] = None, *,
                track: str = "engine", **args) -> None:
        super().instant(name, cat, at, track=track, **args)
        if len(self.events) >= self.buffer:
            self._spill()

    # ---- spill ------------------------------------------------------
    @staticmethod
    def event_line(e: TraceEvent) -> dict:
        """One JSONL record: the Chrome event fields (ts/dur in
        microseconds, like ``to_chrome``) with the track kept by name
        (tid assignment needs the full track set — the offline
        converter does it)."""
        ev = {
            "name": e.name,
            "cat": e.cat,
            "ph": "X" if e.dur is not None else "i",
            "ts": round(e.ts * 1e6, 3),
            "track": e.track,
            "args": {**e.args, "seq": e.seq},
        }
        if e.dur is not None:
            ev["dur"] = round(e.dur * 1e6, 3)
        return ev

    def _spill(self) -> None:
        for e in self.events:
            json.dump(self.event_line(e), self._fh,
                      sort_keys=True, separators=(",", ":"))
            self._fh.write("\n")
            self.events_written += 1
        del self.events[:]

    # ---- finalize ---------------------------------------------------
    def close(self, other_data: Optional[dict] = None) -> int:
        """Flush the ring and close the file; returns total events
        written. Idempotent (later calls are no-ops)."""
        if self._closed:
            return self.events_written
        self._spill()
        if other_data is not None:
            json.dump({"otherData": other_data}, self._fh,
                      sort_keys=True, separators=(",", ":"))
            self._fh.write("\n")
        self._fh.close()
        self._closed = True
        return self.events_written

    def export(self, path=None, other_data: Optional[dict] = None) -> int:
        """Streaming tracers export by finalizing their own JSONL file
        (``path`` must be None or the constructor path)."""
        if path is not None and path != self.path:
            raise ValueError(
                f"StreamingTracer writes to {self.path!r}; cannot "
                f"export to {path!r} (use jsonl_to_chrome offline)")
        return self.close(other_data)

    def __enter__(self) -> "StreamingTracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class _DisabledTracer(Tracer):
    """Falsy no-op tracer: the default wiring for every engine."""

    def __bool__(self) -> bool:
        return False

    def set_time(self, t: float) -> None:
        pass

    def span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass


Tracer.disabled = _DisabledTracer()
