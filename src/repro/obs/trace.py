"""Structured span tracer on the injected serving clock.

``Tracer`` records the full lifecycle of every arrival the serving
stack handles — arrival, flush-queue wait, encode@tier compute spans,
uplink/downlink transport flights by flight id, tail fusion, cache
commits, partial/final prediction emits — plus the speculation and
chaos annotations (race start/win, cancel, crash detect, redispatch,
rejoin, evict).  Timestamps come from whatever clock the engine runs
on: the simulated per-tier episode clock in tiered mode (``set_time``
is called at each arrival), or the wall ``time_fn`` in flush mode
(``clock`` attribute).

Determinism: every event carries a monotone per-tracer sequence number,
and export stable-sorts by ``(ts, seq)`` and serializes with sorted
keys — so under the deterministic simulated clock the exported trace
file is byte-reproducible.  The sequence number is also the program-
order causality signal the trace-replay auditor (``obs.audit``) relies
on, since in tiered mode distinct hosts' spans legitimately overlap in
simulated time.

Export is Chrome trace-event format (the JSON object form), directly
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:
``ph="X"`` complete spans, ``ph="i"`` instants, ``ph="M"`` metadata
naming each track.  Track ids are assigned from the sorted set of
track names so they never depend on event arrival order.

``Tracer.disabled`` is a shared no-op singleton that is falsy, so hot
paths guard instrumentation with ``if self.tracer:`` and pay one
branch when tracing is off.
"""
from __future__ import annotations

import json
from typing import Callable, List, Optional

__all__ = ["Tracer", "TraceEvent"]


class TraceEvent:
    """One recorded event (a span when ``dur`` is not None)."""

    __slots__ = ("name", "cat", "ts", "dur", "track", "args", "seq")

    def __init__(self, name, cat, ts, dur, track, args, seq):
        self.name = name
        self.cat = cat
        self.ts = float(ts)
        self.dur = None if dur is None else float(dur)
        self.track = track
        self.args = args
        self.seq = seq

    def __repr__(self):  # pragma: no cover - debugging aid
        kind = "span" if self.dur is not None else "instant"
        return (f"TraceEvent({self.name!r}, {kind}, t={self.ts:.6f}, "
                f"track={self.track!r}, seq={self.seq})")


class Tracer:
    """Append-only event recorder with deterministic Chrome export."""

    disabled: "Tracer"  # assigned below (a _DisabledTracer singleton)

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.events: List[TraceEvent] = []
        self.clock = clock       # wall-mode default timestamp source
        self._now = 0.0          # simulated-mode default timestamp
        self._seq = 0

    def __bool__(self) -> bool:
        return True

    # ---- clocks -----------------------------------------------------
    def set_time(self, t: float) -> None:
        """Advance the simulated-clock default timestamp."""
        self._now = float(t)

    def now(self) -> float:
        return self.clock() if self.clock is not None else self._now

    # ---- record -----------------------------------------------------
    def span(self, name: str, cat: str, t0: float, t1: float, *,
             track: str = "engine", **args) -> None:
        """Record a complete span [t0, t1] on ``track``."""
        self._seq += 1
        self.events.append(TraceEvent(name, cat, t0, max(0.0, t1 - t0),
                                      track, args, self._seq))

    def instant(self, name: str, cat: str, at: Optional[float] = None, *,
                track: str = "engine", **args) -> None:
        """Record a point event at ``at`` (default: the tracer clock).

        The parameter is named ``at`` (not ``t``) so callers can carry
        a ``t=...`` field in the event args without a collision."""
        self._seq += 1
        ts = self.now() if at is None else at
        self.events.append(TraceEvent(name, cat, ts, None, track,
                                      args, self._seq))

    def clear(self) -> None:
        self.events.clear()
        self._seq = 0
        self._now = 0.0

    # ---- export -----------------------------------------------------
    def to_chrome(self, other_data: Optional[dict] = None) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable)."""
        tracks = sorted({e.track for e in self.events})
        tids = {name: i + 1 for i, name in enumerate(tracks)}
        out = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
                "ts": 0, "args": {"name": "EMSServe"}}]
        for name, tid in tids.items():
            out.append({"ph": "M", "name": "thread_name", "pid": 1,
                        "tid": tid, "ts": 0, "args": {"name": name}})
        for e in sorted(self.events, key=lambda e: (e.ts, e.seq)):
            ev = {
                "name": e.name,
                "cat": e.cat,
                "ph": "X" if e.dur is not None else "i",
                "ts": round(e.ts * 1e6, 3),       # seconds -> microseconds
                "pid": 1,
                "tid": tids[e.track],
                "args": {**e.args, "seq": e.seq},
            }
            if e.dur is not None:
                ev["dur"] = round(e.dur * 1e6, 3)
            else:
                ev["s"] = "t"                      # instant scope: thread
            out.append(ev)
        doc = {"traceEvents": out, "displayTimeUnit": "ms"}
        if other_data:
            doc["otherData"] = other_data
        return doc

    def export(self, path, other_data: Optional[dict] = None) -> int:
        """Write the Chrome trace JSON to ``path``; returns event count.

        Serialization is canonical (sorted keys, no whitespace), so two
        identical event streams produce byte-identical files.
        """
        doc = self.to_chrome(other_data)
        with open(path, "w") as f:
            json.dump(doc, f, sort_keys=True, separators=(",", ":"))
        return len(self.events)


class _DisabledTracer(Tracer):
    """Falsy no-op tracer: the default wiring for every engine."""

    def __bool__(self) -> bool:
        return False

    def set_time(self, t: float) -> None:
        pass

    def span(self, *a, **kw) -> None:
        pass

    def instant(self, *a, **kw) -> None:
        pass


Tracer.disabled = _DisabledTracer()
