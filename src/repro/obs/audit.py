"""Trace-replay invariant auditor.

Re-verifies the serving stack's invariants from an exported trace
ALONE — no access to the engine, the cache, or the transport objects —
so a trace file is a self-contained proof obligation: if the runtime
lied about what happened, the replay catches the inconsistency.

Invariants checked (in program order = the per-tracer ``seq`` number,
NOT timestamps — in tiered mode distinct hosts' spans legitimately
overlap in simulated time):

  I1 **exactly-one commit** — at most one *accepted* ``cache.commit``
     per (session key, modality, step) between drops; versions
     increment by exactly 1 per accepted commit (0 after a drop);
     refused commits carry a consistent reason (``duplicate`` means
     the held step, ``stale`` means an older step).
  I2 **bounded staleness** — every ``fuse`` event's consumed features
     satisfy ``input_step - src_step <= max_staleness``.
  I3 **byte conservation** — flight ids are unique fabric-wide; every
     ``transport.cancel`` names a live flight on its own channel, at
     most once, strictly before its delivery instant (a cancelled
     flight never delivers); per channel,
     ``sent == delivered + cancelled`` in both bytes and messages;
     when the export embeds live channel stats (``otherData``), the
     trace-derived totals must match them exactly.
  I4 **no prediction before its inputs** — every feature a ``fuse``
     consumes was stamped (an accepted commit or a ``cache.touch``
     re-stamp at that exact step) EARLIER in program order; every
     ``emit`` is preceded by the ``fuse`` that produced it.

Run from the command line against an exported trace::

    python -m repro.obs.audit /tmp/trace.json
    python -m repro.obs.audit /tmp/trace.jsonl     # StreamingTracer output

exits 0 when clean, 1 on invariant violations, 2 on a schema-invalid
trace (not Chrome trace-event JSON, nor StreamingTracer JSON Lines).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["AuditReport", "validate_chrome", "audit_doc", "audit_tracer",
           "audit_file", "jsonl_to_chrome"]

_PHASES = {"X", "i", "M"}


@dataclass
class AuditReport:
    violations: List[str] = field(default_factory=list)
    checks: Dict[str, int] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    def summary(self) -> str:
        done = ", ".join(f"{k}={v}" for k, v in sorted(self.checks.items()))
        head = ("audit OK" if self.ok
                else f"audit FAILED ({len(self.violations)} violations)")
        return f"{head} [{done}]"


# ======================================================================
# schema validation (Chrome trace-event JSON object form)
# ======================================================================

def validate_chrome(doc) -> List[str]:
    """Structural errors that would make the file unloadable/meaningless
    to Perfetto or to this auditor. Empty list == valid."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["not a Chrome trace-event JSON object "
                "(missing top-level 'traceEvents')"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' is not a list"]
    for n, e in enumerate(evs):
        if not isinstance(e, dict):
            errs.append(f"event[{n}]: not an object")
            continue
        ph = e.get("ph")
        if ph not in _PHASES:
            errs.append(f"event[{n}]: bad ph {ph!r}")
            continue
        for k in ("name", "pid", "tid", "ts"):
            if k not in e:
                errs.append(f"event[{n}] ({e.get('name')!r}): missing {k!r}")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event[{n}] ({e.get('name')!r}): bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event[{n}] ({e.get('name')!r}): "
                            f"bad dur {dur!r}")
        if ph != "M" and "seq" not in e.get("args", {}):
            errs.append(f"event[{n}] ({e.get('name')!r}): args missing seq")
        if len(errs) > 20:
            errs.append("... (truncated)")
            break
    return errs


# ======================================================================
# replay
# ======================================================================

def _seq_ordered(doc):
    evs = [e for e in doc["traceEvents"] if e.get("ph") != "M"]
    return sorted(evs, key=lambda e: e["args"]["seq"])


def audit_doc(doc, *, max_staleness: int = 1) -> AuditReport:
    """Replay a Chrome trace doc and re-verify the serving invariants."""
    rep = AuditReport()
    bad = lambda msg: rep.violations.append(msg)  # noqa: E731

    # live cache model: (key, modality) -> (step, version) or absent
    live: Dict[tuple, tuple] = {}
    # every step ever stamped for a (key, modality) since its last drop
    stamped: Dict[tuple, set] = {}
    # flights: id -> dict(channel, nbytes, t_deliver, cancelled)
    flights: Dict[int, dict] = {}
    chan_sent: Dict[str, List[int]] = {}   # channel -> [msgs, bytes]
    chan_cancel: Dict[str, List[int]] = {}
    fused: set = set()                     # (key, model, step) seen at fuse
    n = dict(commits=0, touches=0, fuses=0, emits=0, flights=0,
             cancels=0, drops=0)

    for e in _seq_ordered(doc):
        name, a = e["name"], e.get("args", {})
        seq = a.get("seq")

        if name == "cache.commit":
            n["commits"] += 1
            ck = (a["key"], a["modality"])
            step = a["step"]
            cur = live.get(ck)
            if a.get("accepted"):
                if cur is not None and step <= cur[0]:
                    bad(f"I1 seq={seq}: accepted commit at step {step} "
                        f"for {ck} but cache already holds step {cur[0]} "
                        "(duplicate/stale accepted)")
                want = 0 if cur is None else cur[1] + 1
                ver = a.get("version")
                if ver != want:
                    bad(f"I1 seq={seq}: commit version {ver} for {ck} "
                        f"(expected {want})")
                live[ck] = (step, ver if isinstance(ver, int) else want)
                stamped.setdefault(ck, set()).add(step)
            else:
                reason = a.get("reason")
                if cur is None:
                    bad(f"I1 seq={seq}: refused commit for {ck} "
                        "with no live entry")
                elif reason == "duplicate" and step != cur[0]:
                    bad(f"I1 seq={seq}: 'duplicate' refusal at step "
                        f"{step} but cache holds step {cur[0]}")
                elif reason == "stale" and step >= cur[0]:
                    bad(f"I1 seq={seq}: 'stale' refusal at step {step} "
                        f"but cache holds step {cur[0]}")

        elif name == "cache.touch":
            n["touches"] += 1
            ck = (a["key"], a["modality"])
            cur = live.get(ck)
            if cur is None:
                bad(f"I4 seq={seq}: touch of absent entry {ck}")
            else:
                live[ck] = (a["step"], cur[1])
                stamped.setdefault(ck, set()).add(a["step"])

        elif name == "cache.drop":
            n["drops"] += 1
            for key, mod in a.get("dropped", []):
                live.pop((key, mod), None)
                stamped.pop((key, mod), None)

        elif name == "fuse":
            n["fuses"] += 1
            key = a["key"]
            for m, (src_step, input_step) in a["consumed"].items():
                lag = input_step - src_step
                if lag > max_staleness:
                    bad(f"I2 seq={seq}: fuse of {key}/{m} consumed step "
                        f"{src_step} against input step {input_step} "
                        f"(lag {lag} > max {max_staleness})")
                if src_step not in stamped.get((key, m), ()):
                    bad(f"I4 seq={seq}: fuse of {key}/{m} consumed step "
                        f"{src_step} never committed/touched before it")
            fused.add((key, a.get("model"), a.get("step")))

        elif name == "emit":
            n["emits"] += 1
            fk = (a["key"], a.get("model"), a.get("step"))
            if fk not in fused:
                bad(f"I4 seq={seq}: emit of {fk} with no prior fuse")

        elif name == "transport.flight":
            n["flights"] += 1
            fid = a["flight"]
            if fid in flights:
                bad(f"I3 seq={seq}: duplicate flight id {fid}")
            flights[fid] = {"channel": a["channel"], "nbytes": a["nbytes"],
                            "t_deliver": a["t_deliver"], "cancelled": False}
            s = chan_sent.setdefault(a["channel"], [0, 0])
            s[0] += 1
            s[1] += a["nbytes"]

        elif name == "transport.cancel":
            n["cancels"] += 1
            fid = a["flight"]
            f = flights.get(fid)
            if f is None:
                bad(f"I3 seq={seq}: cancel of unknown flight {fid}")
                continue
            if f["cancelled"]:
                bad(f"I3 seq={seq}: flight {fid} cancelled twice")
                continue
            if f["channel"] != a["channel"]:
                bad(f"I3 seq={seq}: cancel of flight {fid} on channel "
                    f"{a['channel']} but it flew on {f['channel']}")
            if a["t"] >= f["t_deliver"] - 1e-12:
                bad(f"I3 seq={seq}: flight {fid} cancelled at t={a['t']} "
                    f">= its delivery {f['t_deliver']} — a delivered "
                    "flight cannot be recalled")
            f["cancelled"] = True
            c = chan_cancel.setdefault(f["channel"], [0, 0])
            c[0] += 1
            c[1] += f["nbytes"]

    # ---- I3 conservation against embedded live channel stats --------
    stats = (doc.get("otherData") or {}).get("transport") or {}
    for ch, s in stats.items():
        sent = chan_sent.get(ch, [0, 0])
        canc = chan_cancel.get(ch, [0, 0])
        if (s.get("msgs") != sent[0] or s.get("bytes") != sent[1]
                or s.get("cancelled_msgs") != canc[0]
                or s.get("cancelled_bytes") != canc[1]):
            bad(f"I3 channel {ch}: trace-derived "
                f"(msgs={sent[0]}, bytes={sent[1]}, "
                f"cancelled_msgs={canc[0]}, cancelled_bytes={canc[1]}) "
                f"!= live stats ({s})")
    # internal conservation: delivered + cancelled == sent, per channel
    for ch, (msgs, nbytes) in chan_sent.items():
        cm, cb = chan_cancel.get(ch, [0, 0])
        delivered_m = sum(1 for f in flights.values()
                          if f["channel"] == ch and not f["cancelled"])
        delivered_b = sum(f["nbytes"] for f in flights.values()
                          if f["channel"] == ch and not f["cancelled"])
        if delivered_m + cm != msgs or delivered_b + cb != nbytes:
            bad(f"I3 channel {ch}: delivered+cancelled != sent "
                f"({delivered_m}+{cm} msgs vs {msgs}; "
                f"{delivered_b}+{cb} bytes vs {nbytes})")

    rep.checks = n
    return rep


def audit_tracer(tracer, *, max_staleness: int = 1,
                 other_data: Optional[dict] = None) -> AuditReport:
    """Audit an in-memory :class:`~repro.obs.trace.Tracer` directly."""
    return audit_doc(tracer.to_chrome(other_data),
                     max_staleness=max_staleness)


def jsonl_to_chrome(path) -> dict:
    """Load a ``StreamingTracer`` JSON Lines file into a Chrome
    trace-event doc (Perfetto-loadable and ``audit_doc``-able).

    Each line is one event record carrying its track by *name*; this
    loader assigns tids from the sorted track-name set, prepends the
    ``ph="M"`` metadata events, and sorts by ``(ts, seq)`` exactly like
    ``Tracer.to_chrome``. A ``{"otherData": ...}`` line (written by
    ``StreamingTracer.close``) becomes the doc's ``otherData``.
    Raises ``ValueError`` on a malformed line.
    """
    events: List[dict] = []
    other = None
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"line {ln}: not JSON — {e}") from None
            if not isinstance(rec, dict):
                raise ValueError(f"line {ln}: not an object")
            if "otherData" in rec and "ph" not in rec:
                other = rec["otherData"]
                continue
            events.append(rec)
    tracks = sorted({e.get("track", "engine") for e in events})
    tids = {name: i + 1 for i, name in enumerate(tracks)}
    out = [{"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
            "ts": 0, "args": {"name": "EMSServe"}}]
    for name, tid in tids.items():
        out.append({"ph": "M", "name": "thread_name", "pid": 1,
                    "tid": tid, "ts": 0, "args": {"name": name}})
    for e in sorted(events, key=lambda e: (e.get("ts", 0),
                                           e.get("args", {}).get("seq", 0))):
        ev = dict(e)
        ev["pid"] = 1
        ev["tid"] = tids[ev.pop("track", "engine")]
        if ev.get("ph") == "i":
            ev.setdefault("s", "t")
        out.append(ev)
    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if other:
        doc["otherData"] = other
    return doc


def _load_any(path) -> dict:
    """Load a trace file in either format: Chrome JSON object or
    StreamingTracer JSON Lines (sniffed by suffix, then by content)."""
    if str(path).endswith(".jsonl"):
        return jsonl_to_chrome(path)
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError:
            return jsonl_to_chrome(path)
    if isinstance(doc, dict) and "traceEvents" in doc:
        return doc
    # a one-line JSONL file parses as plain JSON but is not a trace doc
    return jsonl_to_chrome(path)


def audit_file(path, *, max_staleness: int = 1) -> AuditReport:
    """Validate + audit an exported trace file (Chrome JSON or
    StreamingTracer JSONL). Schema errors are reported as violations
    prefixed ``schema:``."""
    try:
        doc = _load_any(path)
    except ValueError as e:
        return AuditReport(violations=[f"schema: {e}"])
    errs = validate_chrome(doc)
    if errs:
        return AuditReport(violations=[f"schema: {e}" for e in errs])
    return audit_doc(doc, max_staleness=max_staleness)


def main(argv=None) -> int:
    import argparse
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.audit",
        description="Re-verify serving invariants from a trace file.")
    p.add_argument("path", help="Chrome trace-event JSON exported by "
                                "repro.obs.Tracer, or JSON Lines from "
                                "repro.obs.StreamingTracer")
    p.add_argument("--max-staleness", type=int, default=1)
    args = p.parse_args(argv)

    try:
        doc = _load_any(args.path)
    except ValueError as e:
        print(f"schema: {e}")
        return 2
    errs = validate_chrome(doc)
    if errs:
        for e in errs:
            print(f"schema: {e}")
        return 2
    rep = audit_doc(doc, max_staleness=args.max_staleness)
    for v in rep.violations:
        print(v)
    print(rep.summary())
    return 0 if rep.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
