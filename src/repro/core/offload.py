"""Adaptive edge-assisted offloading (EMSServe §4.2.3).

Decision rule, verbatim from the paper: offload a submodule iff
    Δt + t^e  <  t^g
where Δt = payload_bytes / bandwidth (the heartbeat monitor's measured
file-transfer time — "unlike RTT, Δt represents the actual file transfer
time"), t^e the profiled edge inference time, t^g the profiled on-glass
time.

Hardware tiers are reproduced from the paper's Figure 8/Table 2
measurements as slowdown factors over the edge server; the *decisions*
are exercised live against trace-driven bandwidth.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# Paper Fig. 8: per-component slowdown of each tier vs Edge-64X.
# (e.g. YOLO11n: 3.2s glass / 0.08s Edge-4C / 0.03s Edge-64X.)
TIER_FACTORS = {
    "edge64x": 1.0,
    "edge4c": 2.7,
    "ph1": 23.0,
    "glass": 107.0,
}


@dataclass
class ProfileTable:
    """One-time offline profiling result: submodule -> seconds per tier."""
    base: Dict[str, float]                       # measured on this host
    factors: Dict[str, float] = field(default_factory=lambda: dict(TIER_FACTORS))
    host_tier: str = "edge4c"                    # what this host stands for

    def time(self, submodule: str, tier: str) -> float:
        rel = self.factors[tier] / self.factors[self.host_tier]
        return self.base[submodule] * rel


@dataclass
class BandwidthTrace:
    """Piecewise-CONSTANT bandwidth over time (bytes/s). Models EMT
    mobility: walking away from the manpack degrades glass-edge WiFi.

    ``at(t)`` is right-continuous: it returns the value of the last
    point whose time is <= ``t`` (a new measurement takes effect exactly
    at its timestamp). At or before the first point it clamps to the
    first point's value — the trace's earliest measurement extends
    backwards, so probing ``t < points[0][0]`` is well-defined instead
    of silently depending on bisect's underflow behavior. Points are
    sorted at construction (last write wins on duplicate timestamps) and
    an empty trace is rejected eagerly rather than failing inside a
    lookup mid-serve."""
    points: List[Tuple[float, float]]            # (t_seconds, bytes/s)

    def __post_init__(self):
        if not self.points:
            raise ValueError("BandwidthTrace needs at least one point")
        self.points = sorted(self.points, key=lambda p: p[0])
        self._ts = [p[0] for p in self.points]   # cached breakpoints

    @staticmethod
    def static(bw: float):
        return BandwidthTrace([(0.0, bw)])

    @staticmethod
    def walk(distances, bw_at, period=1.0):
        """distances: list of meters over time; bw_at: fn(m)->bytes/s."""
        return BandwidthTrace([(i * period, bw_at(d))
                               for i, d in enumerate(distances)])

    def at(self, t: float) -> float:
        i = max(bisect.bisect_right(self._ts, t) - 1, 0)
        return self.points[i][1]


def nlos_bandwidth(distance_m: float) -> float:
    """WiFi through walls: ~56 Mbps at 0 m decaying ~1 NLOS room / 5 m
    (paper scenario 2: 30 m = 6 rooms). Returns bytes/s."""
    mbps = 56.0 * (0.55 ** (distance_m / 5.0))
    return max(mbps, 0.5) * 1e6 / 8


class HeartbeatMonitor:
    """Lightweight periodic bandwidth sampler (paper: every second)."""

    def __init__(self, trace: BandwidthTrace, period: float = 1.0):
        self.trace = trace
        self.period = period
        self._last_sample_t = None
        self._last_bw = None

    def bandwidth(self, now: float) -> float:
        # quantize to the heartbeat period: decisions use the most
        # recent measurement, not an oracle
        tick = now - (now % self.period)
        if self._last_sample_t != tick:
            self._last_sample_t = tick
            self._last_bw = self.trace.at(tick)
        return self._last_bw

    def delta_t(self, payload_bytes: int, now: float) -> float:
        return payload_bytes / self.bandwidth(now)


@dataclass
class Decision:
    tier: str                  # 'edge' | 'glass'
    delta_t: float
    t_edge: float
    t_glass: float


class AdaptiveOffloadPolicy:
    def __init__(self, profile: ProfileTable, monitor: HeartbeatMonitor,
                 *, glass_tier="glass", edge_tier="edge4c",
                 adaptive: bool = True, force: str | None = None):
        self.profile = profile
        self.monitor = monitor
        self.glass_tier = glass_tier
        self.edge_tier = edge_tier
        self.adaptive = adaptive
        self.force = force                      # 'glass'/'edge' for ablations

    def decide(self, submodule: str, payload_bytes: int, now: float) -> Decision:
        dt = self.monitor.delta_t(payload_bytes, now)
        te = self.profile.time(submodule, self.edge_tier)
        tg = self.profile.time(submodule, self.glass_tier)
        if self.force:
            tier = self.force
        elif not self.adaptive:
            tier = "edge"
        else:
            tier = "edge" if dt + te < tg else "glass"
        return Decision(tier=tier, delta_t=dt, t_edge=te, t_glass=tg)


# ======================================================================
# N-tier generalization (glass / phone / edge boxes)
# ======================================================================

@dataclass(frozen=True)
class SpeculationPolicy:
    """When to hedge a placement with a speculative dual dispatch.

    ``deadline_s`` is the per-arrival serving-latency budget (the EMT
    needs the prediction within this window of the datum arriving);
    ``margin_s`` is the pressure threshold: when the chosen placement's
    *estimated* completion leaves less than ``margin_s`` of slack before
    the deadline, the estimate can no longer be trusted to hold (the
    heartbeat-quantized bandwidth it is built on lags the wire), so the
    runtime dispatches the submodule on the local tier AND the best
    remote, commits whichever returns first, and cancels the loser."""
    deadline_s: float
    margin_s: float = 0.0

    def should_speculate(self, est_cost_s: float,
                         lateness_s: float = 0.0) -> bool:
        """Margin = deadline - time already burned - estimated cost;
        speculate when it dips below the configured threshold."""
        return (self.deadline_s - lateness_s - est_cost_s) < self.margin_s


@dataclass(frozen=True)
class TierEstimate:
    """One candidate (tier, precision)'s cost breakdown for one
    submodule placement. ``precision`` stays ``"fp32"`` unless the
    policy runs the joint precision+placement enumeration."""
    tier: str                  # host name
    transfer_s: float          # Δt to ship the inputs there (+ outputs home)
    queue_s: float             # current queueing delay on that host
    compute_s: float           # profiled submodule time on that tier
    precision: str = "fp32"    # numeric precision this estimate assumes

    @property
    def cost(self) -> float:
        return self.transfer_s + self.queue_s + self.compute_s


@dataclass
class TierDecision:
    """Outcome of one per-(submodule, tier) placement evaluation."""
    tier: str                            # chosen host name
    local: str                           # the always-available local host
    estimates: Dict[str, TierEstimate]   # every candidate evaluated
    speculate: bool = False              # deadline margin too thin: race
    margin_s: float = float("inf")       # slack the estimate left
    precision: str = "fp32"              # precision of the chosen estimate

    @property
    def best_remote(self) -> "str | None":
        """Name of the cheapest remote candidate (the speculation
        partner when the argmin picked the local tier)."""
        e = self._remote
        return e.tier if e is not None else None

    # ---- legacy 2-tier views (Decision compatibility)
    @property
    def _remote(self):
        remotes = [e for n, e in self.estimates.items() if n != self.local]
        if not remotes:
            return None
        if self.tier != self.local and self.tier in self.estimates:
            return self.estimates[self.tier]
        return min(remotes, key=lambda e: (e.cost, e.tier))

    @property
    def delta_t(self) -> float:
        e = self._remote
        return e.transfer_s if e is not None else 0.0

    @property
    def t_edge(self) -> float:
        e = self._remote
        return e.compute_s if e is not None else float("inf")

    @property
    def t_glass(self) -> float:
        return self.estimates[self.local].compute_s


class MultiTierPolicy:
    """The paper's Δt + t^e < t^g rule generalized to an ordered list of
    N tiers with per-link bandwidth monitors and contention awareness:

        place(submodule) = argmin_k [ Δt_k + queue_k + t_k(submodule) ]

    over the local tier (Δt = 0) and every *usable* remote, where
    ``queue_k`` is the tier's current work-queue delay (0 when the
    caller runs contention-blind — the paper-verbatim rule) and Δt_k is
    the heartbeat-measured transfer time on that tier's link. With one
    remote and no queues this reduces exactly to the 2-tier rule.

    ``force`` pins placement for ablations: a host name pins everything;
    a ``{submodule: host}`` dict pins per submodule (unlisted submodules
    stay adaptive). A forced tier that is currently unavailable falls
    back to the local host.

    ``speculation`` (a :class:`SpeculationPolicy`) arms the hedging
    rung: a decision whose estimated completion leaves less than the
    configured margin before the deadline is marked ``speculate`` — the
    engine then dispatches the submodule on the local tier AND the best
    remote and commits whichever returns first. Forced and non-adaptive
    decisions never speculate (ablations must stay pinned).

    ``precisions`` (host -> tuple of supported precisions, e.g.
    ``{"glass": ("fp32", "int8")}``) arms the JOINT precision+placement
    enumeration: the argmin then runs over (tier, precision) candidates
    where int8 scales a tier's compute by ``int8_compute_scale`` and —
    because int8-packed features are what ships home — scales the
    feature-return bytes by ``int8_bytes_scale``. The winning estimate's
    precision rides on the decision, so the engine sends quantized
    features exactly when the uplink is the bottleneck and raw when it
    isn't. Unset (None), every path below is BIT-IDENTICAL to the
    precision-less rule; hosts absent from the dict are fp32-only.
    """

    def __init__(self, profile: ProfileTable,
                 monitors: Dict[str, HeartbeatMonitor], *,
                 local: str, tier_of: Dict[str, str],
                 adaptive: bool = True,
                 force: "str | Dict[str, str] | None" = None,
                 speculation: "SpeculationPolicy | None" = None,
                 precisions: "Dict[str, tuple] | None" = None,
                 int8_compute_scale: float = 0.5,
                 int8_bytes_scale: float = 0.25):
        self.profile = profile
        self.monitors = monitors            # remote host name -> its link
        self.local = local
        self.tier_of = dict(tier_of)        # host name -> ProfileTable key
        self.remote_names = [n for n in tier_of if n != local]
        self.adaptive = adaptive
        self.force = force
        self.speculation = speculation
        self.precisions = (None if precisions is None
                           else {h: tuple(p) for h, p in precisions.items()})
        self.int8_compute_scale = int8_compute_scale
        self.int8_bytes_scale = int8_bytes_scale
        if self.precisions is not None:
            for h, ps in self.precisions.items():
                bad = set(ps) - {"fp32", "int8"}
                if h not in tier_of or bad:
                    raise ValueError(
                        f"precisions[{h!r}]={ps}: unknown host or "
                        f"precision (hosts {sorted(tier_of)}, "
                        "precisions fp32/int8)")
        names = set(tier_of)
        forced = (force.values() if isinstance(force, dict)
                  else [force] if force else [])
        for f in forced:
            if f not in names:
                raise ValueError(f"force names unknown tier {f!r}; "
                                 f"hosts are {sorted(names)}")

    def _forced(self, submodule: str):
        if isinstance(self.force, dict):
            return self.force.get(submodule)
        return self.force

    def link_bw(self, a: str, b: str, now: float) -> float:
        """Heartbeat-quantized bandwidth of the a->b link: each remote
        tier owns one radio link, so a transfer traverses every remote
        endpoint's link and the slower one bottlenecks. Local<->local
        never happens on a wire (infinite)."""
        bws = [self.monitors[x].bandwidth(now)
               for x in (a, b) if x != self.local]
        return min(bws) if bws else float("inf")

    def _pick(self, submodule: str, estimates: Dict[str, TierEstimate],
              prefer: str | None = None) -> str:
        forced = self._forced(submodule)
        if forced is not None:
            return forced if forced in estimates else self.local
        remotes = [e for n, e in estimates.items() if n != self.local]
        if not self.adaptive:
            if not remotes:
                return self.local
            return min(remotes, key=lambda e: (e.cost, e.tier)).tier
        best = min(estimates.values(),
                   key=lambda e: (e.cost, e.tier != prefer, e.tier))
        return best.tier

    def decide(self, submodule: str, payload_bytes: int, now: float, *,
               queues: "Dict[str, float] | None" = None,
               available=None, lateness_s: float = 0.0,
               feat_bytes: int = 0) -> TierDecision:
        """Place one submodule whose raw inputs currently sit on the
        local tier. ``available`` restricts the remote candidates (a
        crashed tier is not a candidate); ``queues`` carries each host's
        current queueing delay (omit for the contention-blind rule);
        ``lateness_s`` is serving time already burned against this
        arrival's deadline (feeds the speculation margin).

        ``feat_bytes`` is the estimated fp32 size of the encoded
        feature this submodule emits. It only enters the cost model
        when the joint precision enumeration is armed — a remote
        candidate then pays the feature's return trip too (scaled by
        ``int8_bytes_scale`` for int8 candidates), which is what makes
        the quantized variant win exactly when the radio is the
        bottleneck. With ``precisions=None`` the estimate is the
        legacy uplink-only Δt, bit-identical to the precision-less
        rule."""
        q = queues or {}
        remotes = (self.remote_names if available is None
                   else [n for n in self.remote_names if n in available])
        if self.precisions is None:
            est = {self.local: TierEstimate(
                self.local, 0.0, q.get(self.local, 0.0),
                self.profile.time(submodule, self.tier_of[self.local]))}
            for n in remotes:
                est[n] = TierEstimate(
                    n, self.monitors[n].delta_t(payload_bytes, now),
                    q.get(n, 0.0),
                    self.profile.time(submodule, self.tier_of[n]))
        else:
            est = {}
            for host in (self.local, *remotes):
                t_fp32 = self.profile.time(submodule, self.tier_of[host])
                cands = []
                for prec in self.precisions.get(host, ("fp32",)):
                    scale = (self.int8_compute_scale if prec == "int8"
                             else 1.0)
                    if host == self.local:
                        xfer = 0.0
                    else:
                        fb = feat_bytes * (self.int8_bytes_scale
                                           if prec == "int8" else 1.0)
                        xfer = self.monitors[host].delta_t(
                            payload_bytes + fb, now)
                    cands.append(TierEstimate(
                        host, xfer, q.get(host, 0.0), t_fp32 * scale,
                        precision=prec))
                # per-tier argmin over precisions; ties keep fp32 (no
                # gratuitous quantization when bytes aren't the issue)
                est[host] = min(cands,
                                key=lambda e: (e.cost,
                                               e.precision != "fp32"))
        # tie-break toward local: the legacy rule offloads only on a
        # STRICT win (dt + te < tg)
        pick = self._pick(submodule, est, prefer=self.local)
        spec, margin = False, float("inf")
        if (self.speculation is not None and self.adaptive
                and self._forced(submodule) is None and remotes):
            margin = (self.speculation.deadline_s - lateness_s
                      - est[pick].cost)
            spec = self.speculation.should_speculate(est[pick].cost,
                                                     lateness_s)
        return TierDecision(tier=pick, local=self.local, estimates=est,
                            speculate=spec, margin_s=margin,
                            precision=est[pick].precision)

    def decide_tail(self, feat_bytes: int, out_bytes: int, enc_tier: str,
                    now: float, *, queues: "Dict[str, float] | None" = None,
                    available=None) -> TierDecision:
        """Place the fusion *tail* separately from the encoder that
        feeds it: candidate costs add the feature transfer from
        ``enc_tier`` (0 when co-located) and the head-output return trip
        to the local tier (0 when the tail runs locally). Ties prefer
        co-location with the encoder (no extra hop)."""
        q = queues or {}
        remotes = (self.remote_names if available is None
                   else [n for n in self.remote_names if n in available])
        cands = {self.local, *remotes}
        est = {}
        for k in cands:
            xfer = 0.0
            if k != enc_tier:
                xfer += feat_bytes / self.link_bw(enc_tier, k, now)
            if k != self.local:
                xfer += out_bytes / self.link_bw(k, self.local, now)
            est[k] = TierEstimate(
                k, xfer, q.get(k, 0.0),
                self.profile.time("tail", self.tier_of[k]))
        return TierDecision(tier=self._pick("tail", est, prefer=enc_tier),
                            local=self.local, estimates=est)
