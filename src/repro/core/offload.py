"""Adaptive edge-assisted offloading (EMSServe §4.2.3).

Decision rule, verbatim from the paper: offload a submodule iff
    Δt + t^e  <  t^g
where Δt = payload_bytes / bandwidth (the heartbeat monitor's measured
file-transfer time — "unlike RTT, Δt represents the actual file transfer
time"), t^e the profiled edge inference time, t^g the profiled on-glass
time.

Hardware tiers are reproduced from the paper's Figure 8/Table 2
measurements as slowdown factors over the edge server; the *decisions*
are exercised live against trace-driven bandwidth.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# Paper Fig. 8: per-component slowdown of each tier vs Edge-64X.
# (e.g. YOLO11n: 3.2s glass / 0.08s Edge-4C / 0.03s Edge-64X.)
TIER_FACTORS = {
    "edge64x": 1.0,
    "edge4c": 2.7,
    "ph1": 23.0,
    "glass": 107.0,
}


@dataclass
class ProfileTable:
    """One-time offline profiling result: submodule -> seconds per tier."""
    base: Dict[str, float]                       # measured on this host
    factors: Dict[str, float] = field(default_factory=lambda: dict(TIER_FACTORS))
    host_tier: str = "edge4c"                    # what this host stands for

    def time(self, submodule: str, tier: str) -> float:
        rel = self.factors[tier] / self.factors[self.host_tier]
        return self.base[submodule] * rel


@dataclass
class BandwidthTrace:
    """Piecewise-CONSTANT bandwidth over time (bytes/s). Models EMT
    mobility: walking away from the manpack degrades glass-edge WiFi.

    ``at(t)`` is right-continuous: it returns the value of the last
    point whose time is <= ``t`` (a new measurement takes effect exactly
    at its timestamp). At or before the first point it clamps to the
    first point's value — the trace's earliest measurement extends
    backwards, so probing ``t < points[0][0]`` is well-defined instead
    of silently depending on bisect's underflow behavior. Points are
    sorted at construction (last write wins on duplicate timestamps) and
    an empty trace is rejected eagerly rather than failing inside a
    lookup mid-serve."""
    points: List[Tuple[float, float]]            # (t_seconds, bytes/s)

    def __post_init__(self):
        if not self.points:
            raise ValueError("BandwidthTrace needs at least one point")
        self.points = sorted(self.points, key=lambda p: p[0])
        self._ts = [p[0] for p in self.points]   # cached breakpoints

    @staticmethod
    def static(bw: float):
        return BandwidthTrace([(0.0, bw)])

    @staticmethod
    def walk(distances, bw_at, period=1.0):
        """distances: list of meters over time; bw_at: fn(m)->bytes/s."""
        return BandwidthTrace([(i * period, bw_at(d))
                               for i, d in enumerate(distances)])

    def at(self, t: float) -> float:
        i = max(bisect.bisect_right(self._ts, t) - 1, 0)
        return self.points[i][1]


def nlos_bandwidth(distance_m: float) -> float:
    """WiFi through walls: ~56 Mbps at 0 m decaying ~1 NLOS room / 5 m
    (paper scenario 2: 30 m = 6 rooms). Returns bytes/s."""
    mbps = 56.0 * (0.55 ** (distance_m / 5.0))
    return max(mbps, 0.5) * 1e6 / 8


class HeartbeatMonitor:
    """Lightweight periodic bandwidth sampler (paper: every second)."""

    def __init__(self, trace: BandwidthTrace, period: float = 1.0):
        self.trace = trace
        self.period = period
        self._last_sample_t = None
        self._last_bw = None

    def bandwidth(self, now: float) -> float:
        # quantize to the heartbeat period: decisions use the most
        # recent measurement, not an oracle
        tick = now - (now % self.period)
        if self._last_sample_t != tick:
            self._last_sample_t = tick
            self._last_bw = self.trace.at(tick)
        return self._last_bw

    def delta_t(self, payload_bytes: int, now: float) -> float:
        return payload_bytes / self.bandwidth(now)


@dataclass
class Decision:
    tier: str                  # 'edge' | 'glass'
    delta_t: float
    t_edge: float
    t_glass: float


class AdaptiveOffloadPolicy:
    def __init__(self, profile: ProfileTable, monitor: HeartbeatMonitor,
                 *, glass_tier="glass", edge_tier="edge4c",
                 adaptive: bool = True, force: str | None = None):
        self.profile = profile
        self.monitor = monitor
        self.glass_tier = glass_tier
        self.edge_tier = edge_tier
        self.adaptive = adaptive
        self.force = force                      # 'glass'/'edge' for ablations

    def decide(self, submodule: str, payload_bytes: int, now: float) -> Decision:
        dt = self.monitor.delta_t(payload_bytes, now)
        te = self.profile.time(submodule, self.edge_tier)
        tg = self.profile.time(submodule, self.glass_tier)
        if self.force:
            tier = self.force
        elif not self.adaptive:
            tier = "edge"
        else:
            tier = "edge" if dt + te < tg else "glass"
        return Decision(tier=tier, delta_t=dt, t_edge=te, t_glass=tg)
