"""EMSServe engine: event-driven multimodal serving with feature caching
and adaptive offloading (paper §4.2).

The engine consumes an *episode* — a stream of asynchronously arriving
modality payloads (speech/text, vitals, scene images) — and maintains,
per session:
  * the latest aggregated input per modality (new vitals extend the
    time series; new images refresh the scene vector);
  * the feature cache: per-(model, modality) encoder outputs.

Two serving disciplines, matching the paper's comparison:
  * ``cached=True`` (EMSServe): on each event, encode ONLY the arriving
    modality (per model that consumes it — in parallel for expensive
    text modules, serially for cheap vitals, per Fig. 8-right), reuse
    cached features for everything else, run the fused tail.
  * ``cached=False`` (direct PyTorch-style): on each event, re-run the
    full selected multimodal model over all data observed so far —
    re-encoding early-arrived text up to 30x per episode.

Placement of every encoder run goes through the AdaptiveOffloadPolicy
(Δt + t^e < t^g). A simulated clock accumulates transfer + tier-scaled
compute; ``real_time=True`` instead measures wall-clock of the actual
jitted calls (used for the on-host speedup claims).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax

from .bucketing import Bucketer
from .episodes import Event
from .feature_cache import FeatureCache
from .offload import AdaptiveOffloadPolicy
from .splitter import SplitModel, select_model


@dataclass
class EventRecord:
    index: int
    modality: str
    model: Optional[str]
    tier: str
    delta_t: float
    compute_s: float
    total_s: float
    cumulative_s: float
    recommendation: Optional[dict] = None
    cache_hits: int = 0


class EMSServe:
    def __init__(self, models: Dict[str, SplitModel], params: Dict[str, dict],
                 *, policy: Optional[AdaptiveOffloadPolicy] = None,
                 cached: bool = True, real_time: bool = False,
                 session: str = "s0", bucketer: Optional[Bucketer] = None):
        # models keyed by name, e.g. {"m1": text-only, "m2": text+vitals, ...}
        self.models = models
        self.params = params
        self.policy = policy
        self.cached = cached
        self.real_time = real_time
        self.session = session
        # bucketer: pad variable-length inputs to power-of-two shapes so
        # encoder recompiles plateau as the vitals stream grows
        self.bucketer = bucketer
        self.cache = FeatureCache(max_staleness=1)
        self.inputs: Dict[str, object] = {}
        self.input_step: Dict[str, int] = {}
        self.step = 0
        self.clock = 0.0
        self.records: List[EventRecord] = []
        self._cum_total = 0.0            # running sum of record.total_s
        self.edge_alive = True

    # ------------------------------------------------------------ utils

    def crash_edge(self):
        """Manpack battery died: all subsequent work runs on-glass. Cached
        features survive (the edge returned them with every result)."""
        self.edge_alive = False
        if self.policy is not None:
            self.policy.force = "glass"

    def _select_model(self, observed):
        return select_model(self.models, observed)

    def _enc_input(self, modality: str):
        """Aggregated input for an encoder call, bucketed when enabled."""
        x = self.inputs[modality]
        return self.bucketer.fit(modality, x) if self.bucketer else x

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def _decide(self, submodule: str, payload_bytes: int):
        if self.policy is None:
            return "glass", 0.0
        d = self.policy.decide(submodule, payload_bytes, self.clock)
        tier = d.tier if (self.edge_alive or d.tier == "glass") else "glass"
        return tier, (d.delta_t if tier == "edge" else 0.0)

    def _run(self, fn, *args, submodule: str, tier: str):
        """Execute a jitted submodule; return (result, seconds-for-clock)."""
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        if self.real_time or self.policy is None:
            return out, wall
        tname = self.policy.glass_tier if tier == "glass" else self.policy.edge_tier
        return out, self.policy.profile.time(submodule, tname)

    # ------------------------------------------------------------ event

    def on_event(self, event: Event, payload, *, aggregate=None):
        """Process one arriving datum. ``aggregate(old, new) -> input``
        merges it into the modality's aggregated input (default: replace).
        """
        self.step += 1
        m = event.modality
        old = self.inputs.get(m)
        self.inputs[m] = aggregate(old, payload) if aggregate else payload
        self.input_step[m] = self.step
        observed = set(self.inputs)
        model_name = self._select_model(observed)

        compute_s = 0.0
        dt_total = 0.0
        tier_used = "glass"
        rec_out = None
        hits0 = self.cache.hits

        if self.cached:
            # --- EMSServe path: encode only modality m, per consuming model.
            consumers = [(n, sm) for n, sm in self.models.items()
                         if m in sm.modalities()]
            enc_times = []
            payload_b = (consumers[0][1].module.payload_bytes.get(m, 1 << 16)
                         if consumers else 1 << 16)
            tier_used, dt = self._decide(f"enc:{m}", payload_b)
            dt_total += dt
            enc_in = self._enc_input(m)
            for name, sm in consumers:
                feat, secs = self._run(sm.encoders[m], self.params[name],
                                       enc_in,
                                       submodule=f"enc:{m}", tier=tier_used)
                self.cache.put(f"{self.session}:{name}", m, feat,
                               step=self.step, tier=tier_used)
                enc_times.append(secs)
            if enc_times:
                # parallel cache computation for expensive modules (text),
                # serial for cheap ones (paper Fig. 8-right)
                compute_s += max(enc_times) if m == "text" else sum(enc_times)
            if model_name is not None:
                sm = self.models[model_name]
                feats = self.cache.features(f"{self.session}:{model_name}",
                                            sm.modalities(),
                                            input_steps=self.input_step)
                if feats is not None:
                    rec_out, secs = self._run(sm.tail, self.params[model_name],
                                              feats, submodule="tail",
                                              tier=tier_used)
                    compute_s += secs
                    for mm in sm.modalities():   # edge returns cache w/ result
                        self.cache.touch(f"{self.session}:{model_name}", mm,
                                         self.step)
        else:
            # --- direct path: re-run the full model over everything.
            if model_name is not None:
                sm = self.models[model_name]
                payload_b = sum(sm.module.payload_bytes.get(mm, 1 << 16)
                                for mm in sm.modalities())
                tier_used, dt = self._decide("full", payload_b)
                dt_total += dt
                batch = {mm: self._enc_input(mm) for mm in sm.modalities()}
                rec_out, secs = self._run(sm.full, self.params[model_name],
                                          batch, submodule="full",
                                          tier=tier_used)
                compute_s += secs
            else:
                # conventional framework still pays the arriving modality's
                # encode to display *something* (perception cost)
                for name, sm in self.models.items():
                    if m in sm.modalities():
                        _, secs = self._run(sm.encoders[m], self.params[name],
                                            self._enc_input(m),
                                            submodule=f"enc:{m}", tier="glass")
                        compute_s += secs
                        break

        total = dt_total + compute_s
        self.clock = max(self.clock, event.arrival_time) + total
        self._cum_total += total        # O(1), was O(n) per event
        rec = EventRecord(
            index=event.index, modality=m, model=model_name, tier=tier_used,
            delta_t=dt_total, compute_s=compute_s, total_s=total,
            cumulative_s=self._cum_total,
            recommendation=(jax.tree.map(lambda a: a, rec_out)
                            if rec_out is not None else None),
            cache_hits=self.cache.hits - hits0)
        self.records.append(rec)
        return rec

    def run_episode(self, events, payload_fn, *, aggregate=None):
        for ev in events:
            self.on_event(ev, payload_fn(ev), aggregate=aggregate)
        return self.records

    def cumulative_time(self):
        return self.records[-1].cumulative_s if self.records else 0.0
