"""Versioned per-(session, modality) feature cache (EMSServe's key idea).

Invariants (paper §4.2.3, fault tolerance):
  * an entry is stamped with the engine step that produced it; the
    engine asserts entries it consumes are never staler than one step
    ("the cache on the smart glasses is never outdated by more than one
    step" — the edge returns the cache with every result);
  * entries carry the tier that computed them, so the fault-tolerance
    path can tell which features survive an edge crash;
  * commits are idempotent and monotone in the step clock: a second
    commit of the same (session, modality, step) is a structural no-op
    (the version does NOT bump — a duplicate is the same feature, since
    placement never changes the math), and a commit at an OLDER step
    than the stored entry is refused outright. Speculative dual
    placement races two tiers on the same submodule and commits
    whichever returns first; these two rules are what make a losing
    flight's late commit harmless — it can never clobber a newer
    version or regress staleness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from repro.obs import Metrics, Tracer


@dataclass
class CacheEntry:
    feature: Any               # device array (B, d_m)
    step: int                  # engine step that produced it
    tier: str                  # 'glass' | 'edge'
    modality: str
    version: int = 0


class StalenessError(RuntimeError):
    pass


class FeatureCache:
    def __init__(self, max_staleness: int = 1, *,
                 metrics: Optional[Metrics] = None,
                 tracer: Optional[Tracer] = None):
        self.max_staleness = max_staleness
        self._store: Dict[Tuple[str, str], CacheEntry] = {}
        # counters live on the (possibly shared) metrics registry; the
        # historical attributes survive as read-through properties
        self.metrics = metrics if metrics is not None else Metrics()
        self.tracer = tracer if tracer is not None else Tracer.disabled

    # ---- legacy counter attributes (read-through to the registry)
    @property
    def hits(self) -> int:
        return int(self.metrics.get("cache.hits"))

    @property
    def misses(self) -> int:
        return int(self.metrics.get("cache.misses"))

    @property
    def duplicate_commits(self) -> int:
        """Same-step re-commits (no-ops)."""
        return int(self.metrics.get("cache.duplicate_commits"))

    @property
    def stale_commits(self) -> int:
        """Older-step late commits (refused)."""
        return int(self.metrics.get("cache.stale_commits"))

    def put(self, session: str, modality: str, feature, *, step: int,
            tier: str = "glass") -> bool:
        """Commit a feature; returns True iff the entry changed.

        Commits are idempotent and monotone: re-committing the step the
        entry already holds is a structural no-op (same step = same
        input = same feature — the version does NOT bump, so tier
        replicas never re-ship), and committing an older step than the
        stored entry is refused — a losing speculative flight or a
        crash-delayed straggler can never regress staleness."""
        key = (session, modality)
        prev = self._store.get(key)
        if prev is not None:
            if step < prev.step:
                self.metrics.inc("cache.stale_commits")
                if self.tracer:
                    self.tracer.instant(
                        "cache.commit", "cache", track="cache",
                        key=session, modality=modality, step=step,
                        tier=tier, accepted=False, reason="stale")
                return False
            if step == prev.step:
                self.metrics.inc("cache.duplicate_commits")
                if self.tracer:
                    self.tracer.instant(
                        "cache.commit", "cache", track="cache",
                        key=session, modality=modality, step=step,
                        tier=tier, accepted=False, reason="duplicate")
                return False
        version = (prev.version + 1) if prev else 0
        self._store[key] = CacheEntry(
            feature=feature, step=step, tier=tier, modality=modality,
            version=version)
        self.metrics.inc("cache.commits")
        if self.tracer:
            self.tracer.instant(
                "cache.commit", "cache", track="cache", key=session,
                modality=modality, step=step, tier=tier, accepted=True,
                version=version)
        return True

    def get(self, session: str, modality: str, *,
            input_step: Optional[int] = None):
        """``input_step``: the engine step at which this modality's
        aggregated input last changed. A cache entry must have been
        computed no more than ``max_staleness`` steps before that —
        the paper's "never outdated by more than one step" invariant
        (the slack covers an edge crash mid-recompute)."""
        entry = self._store.get((session, modality))
        if entry is None:
            self.metrics.inc("cache.misses")
            return None
        if input_step is not None and input_step - entry.step > self.max_staleness:
            raise StalenessError(
                f"cache for {modality} lags its input by "
                f"{input_step - entry.step} steps (max {self.max_staleness}) "
                "— fault-tolerance invariant broken")
        self.metrics.inc("cache.hits")
        return entry

    def features(self, session: str, modalities, *, input_steps=None):
        """Dict of cached features for the given modalities (None if any missing)."""
        out = {}
        for m in modalities:
            e = self.get(session, m,
                         input_step=(input_steps or {}).get(m))
            if e is None:
                return None
            out[m] = e.feature
        return out

    def peek(self, session: str, modality: str):
        """Non-counting, non-asserting read — for replica bookkeeping
        (byte accounting, eviction scans), not the serving fuse path."""
        return self._store.get((session, modality))

    def entries(self):
        """Iterate ((session, modality), entry) pairs — replica re-warm
        scans after a tier restart read the whole live cache."""
        return self._store.items()

    def touch(self, session: str, modality: str, step: int):
        """Re-stamp an entry (edge returned it alongside a result)."""
        e = self._store.get((session, modality))
        if e is not None:
            e.step = step
            if self.tracer:
                self.tracer.instant("cache.touch", "cache", track="cache",
                                    key=session, modality=modality,
                                    step=step)

    def drop_tier(self, tier: str):
        """Invalidate entries held only by a crashed tier."""
        dropped = [list(k) for k, v in self._store.items()
                   if v.tier == tier]
        self._store = {k: v for k, v in self._store.items() if v.tier != tier}
        if self.tracer and dropped:
            self.tracer.instant("cache.drop", "cache", track="cache",
                                scope="tier", tier=tier, dropped=dropped)

    def drop_session(self, session: str) -> int:
        """Evict every modality entry of one session key (cross-incident
        session eviction); returns how many entries were dropped."""
        keys = [k for k in self._store if k[0] == session]
        for k in keys:
            del self._store[k]
        if self.tracer and keys:
            self.tracer.instant("cache.drop", "cache", track="cache",
                                scope="session", key=session,
                                dropped=[list(k) for k in keys])
        return len(keys)

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)
