"""Versioned per-(session, modality) feature cache (EMSServe's key idea).

Invariants (paper §4.2.3, fault tolerance):
  * an entry is stamped with the engine step that produced it; the
    engine asserts entries it consumes are never staler than one step
    ("the cache on the smart glasses is never outdated by more than one
    step" — the edge returns the cache with every result);
  * entries carry the tier that computed them, so the fault-tolerance
    path can tell which features survive an edge crash;
  * commits are idempotent and monotone in the step clock: a second
    commit of the same (session, modality, step) is a structural no-op
    (the version does NOT bump — a duplicate is the same feature, since
    placement never changes the math), and a commit at an OLDER step
    than the stored entry is refused outright. Speculative dual
    placement races two tiers on the same submodule and commits
    whichever returns first; these two rules are what make a losing
    flight's late commit harmless — it can never clobber a newer
    version or regress staleness.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple


@dataclass
class CacheEntry:
    feature: Any               # device array (B, d_m)
    step: int                  # engine step that produced it
    tier: str                  # 'glass' | 'edge'
    modality: str
    version: int = 0


class StalenessError(RuntimeError):
    pass


class FeatureCache:
    def __init__(self, max_staleness: int = 1):
        self.max_staleness = max_staleness
        self._store: Dict[Tuple[str, str], CacheEntry] = {}
        self.hits = 0
        self.misses = 0
        self.duplicate_commits = 0    # same-step re-commits (no-ops)
        self.stale_commits = 0        # older-step late commits (refused)

    def put(self, session: str, modality: str, feature, *, step: int,
            tier: str = "glass") -> bool:
        """Commit a feature; returns True iff the entry changed.

        Commits are idempotent and monotone: re-committing the step the
        entry already holds is a structural no-op (same step = same
        input = same feature — the version does NOT bump, so tier
        replicas never re-ship), and committing an older step than the
        stored entry is refused — a losing speculative flight or a
        crash-delayed straggler can never regress staleness."""
        key = (session, modality)
        prev = self._store.get(key)
        if prev is not None:
            if step < prev.step:
                self.stale_commits += 1
                return False
            if step == prev.step:
                self.duplicate_commits += 1
                return False
        self._store[key] = CacheEntry(
            feature=feature, step=step, tier=tier, modality=modality,
            version=(prev.version + 1) if prev else 0)
        return True

    def get(self, session: str, modality: str, *,
            input_step: Optional[int] = None):
        """``input_step``: the engine step at which this modality's
        aggregated input last changed. A cache entry must have been
        computed no more than ``max_staleness`` steps before that —
        the paper's "never outdated by more than one step" invariant
        (the slack covers an edge crash mid-recompute)."""
        entry = self._store.get((session, modality))
        if entry is None:
            self.misses += 1
            return None
        if input_step is not None and input_step - entry.step > self.max_staleness:
            raise StalenessError(
                f"cache for {modality} lags its input by "
                f"{input_step - entry.step} steps (max {self.max_staleness}) "
                "— fault-tolerance invariant broken")
        self.hits += 1
        return entry

    def features(self, session: str, modalities, *, input_steps=None):
        """Dict of cached features for the given modalities (None if any missing)."""
        out = {}
        for m in modalities:
            e = self.get(session, m,
                         input_step=(input_steps or {}).get(m))
            if e is None:
                return None
            out[m] = e.feature
        return out

    def peek(self, session: str, modality: str):
        """Non-counting, non-asserting read — for replica bookkeeping
        (byte accounting, eviction scans), not the serving fuse path."""
        return self._store.get((session, modality))

    def entries(self):
        """Iterate ((session, modality), entry) pairs — replica re-warm
        scans after a tier restart read the whole live cache."""
        return self._store.items()

    def touch(self, session: str, modality: str, step: int):
        """Re-stamp an entry (edge returned it alongside a result)."""
        e = self._store.get((session, modality))
        if e is not None:
            e.step = step

    def drop_tier(self, tier: str):
        """Invalidate entries held only by a crashed tier."""
        self._store = {k: v for k, v in self._store.items() if v.tier != tier}

    def drop_session(self, session: str) -> int:
        """Evict every modality entry of one session key (cross-incident
        session eviction); returns how many entries were dropped."""
        keys = [k for k in self._store if k[0] == session]
        for k in keys:
            del self._store[k]
        return len(keys)

    def __contains__(self, key):
        return key in self._store

    def __len__(self):
        return len(self._store)
