"""MultimodalModule protocol: the contract the modality-aware splitter
operates on.

A multimodal multitask model is declared as named per-modality encoder
functions plus a fused tail (fusion + task heads), each a pure function
over its own parameter subtree. EMSNet is the paper's instance; any
model with a decomposable front (e.g. a VLM's vision cross-KV encoder)
fits the same protocol.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence


@dataclass(frozen=True)
class MultimodalModule:
    name: str
    modalities: tuple                          # ordering defines fusion concat
    encoder_fns: Dict[str, Callable]           # m -> fn(params, inputs) -> feature
    tail_fn: Callable                          # fn(params, {m: feature}) -> outputs
    init_fn: Callable                          # fn(key) -> params
    # representative input sizes in bytes, used by the offloading policy
    payload_bytes: Dict[str, int] = field(default_factory=dict)
    # hard per-modality input-length caps (e.g. a positional-embedding
    # table); the serving bucketer must never pad past these
    max_lengths: Dict[str, int] = field(default_factory=dict)
    # encoded-feature widths per modality (the F_C slice layout) —
    # what lets a serving engine zero-fill a missing modality's slice
    # and run every subset tail through the FULL fusion heads in one
    # grouped call; empty when the model doesn't declare them
    feature_dims: Dict[str, int] = field(default_factory=dict)
    # optional int8 support: fn(params) -> sidecar pytree the SAME
    # encoder_fns accept (quantized dense leaves, fp32 rest shared by
    # reference). None = the model has no quantized variant and a
    # precision-enabled engine spec must reject it.
    quantize_fn: Optional[Callable] = None

    def full_fn(self):
        """The monolithic forward — what a conventional framework runs."""
        def fn(params, batch):
            feats = {m: self.encoder_fns[m](params, batch[m])
                     for m in self.modalities}
            return self.tail_fn(params, feats)
        return fn


def _emsnet_quantize_fn():
    from repro.models import quantized as Q
    return Q.quantize_emsnet_params


def emsnet_module(cfg, modalities=("text", "vitals", "scene")) -> MultimodalModule:
    """Wrap EMSNet into the protocol."""
    import jax
    from repro.models import emsnet as E

    def enc(m):
        return lambda params, inputs: E.encode(params, cfg, m, inputs)

    payload = {
        "text": 16000 * 30,        # ~order of a short speech clip (bytes)
        "vitals": cfg.vitals_len * cfg.n_vitals * 4,
        "scene": 640 * 480 * 3,    # a scene image
    }
    return MultimodalModule(
        name=f"emsnet-{cfg.text_encoder}-{cfg.vitals_encoder}-fc",
        modalities=tuple(modalities),
        encoder_fns={m: enc(m) for m in modalities},
        tail_fn=lambda params, feats: E.fuse_and_heads(
            params["heads"], feats, modalities),
        init_fn=lambda key: E.init_params(cfg, key, modalities),
        payload_bytes={m: payload[m] for m in modalities},
        max_lengths=({"text": cfg.max_text_len} if "text" in modalities
                     else {}),
        feature_dims={m: cfg.feature_dims[m] for m in modalities},
        quantize_fn=_emsnet_quantize_fn(),
    )


def emsnet_subset_module(cfg, subset,
                         all_modalities=("text", "vitals", "scene")
                         ) -> MultimodalModule:
    """An EMSNet view over a modality *subset* that runs on the FULL
    model's parameters: encoders are the full model's encoders, the tail
    slices the full fusion heads to the subset's rows
    (``models.emsnet.slice_heads``). Every subset module therefore
    shares one parameter pytree with the full model — the property the
    streaming runtime's progressive re-fusion relies on (`init_fn` inits
    the FULL model, and one such pytree serves all subsets)."""
    from repro.models import emsnet as E

    subset = tuple(m for m in all_modalities if m in set(subset))

    def enc(m):
        return lambda params, inputs: E.encode(params, cfg, m, inputs)

    def tail(params, feats):
        ph = E.slice_heads(params["heads"], cfg, all_modalities, subset)
        return E.fuse_and_heads(ph, feats, subset)

    base = emsnet_module(cfg, all_modalities)
    return MultimodalModule(
        name=f"{base.name}[{'+'.join(subset)}]",
        modalities=subset,
        encoder_fns={m: enc(m) for m in subset},
        tail_fn=tail,
        init_fn=lambda key: E.init_params(cfg, key, all_modalities),
        payload_bytes={m: base.payload_bytes[m] for m in subset},
        max_lengths={m: n for m, n in base.max_lengths.items()
                     if m in subset},
        feature_dims={m: base.feature_dims[m] for m in subset},
        quantize_fn=_emsnet_quantize_fn(),
    )


def emsnet_zoo(cfg, all_modalities=("text", "vitals", "scene")):
    """Subset modules for every non-empty modality combination, keyed
    ``"text+vitals"``-style. All share one full-model parameter pytree:
    ``params = zoo["text+vitals+scene"].init_fn(key)`` serves them all."""
    from itertools import combinations

    zoo = {}
    for r in range(1, len(all_modalities) + 1):
        for subset in combinations(all_modalities, r):
            zoo["+".join(subset)] = emsnet_subset_module(
                cfg, subset, all_modalities)
    return zoo
