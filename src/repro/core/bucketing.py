"""Shape bucketing: bounded static shapes for variable-length inputs.

XLA compiles one program per input shape. An EMS episode grows its
vitals time series with every event and text utterances vary in token
count, so serving them at their natural shapes recompiles the encoders
over and over — the dominant cost on the serving hot path. The bucketer
pads every variable-length input up to the next power-of-two length
(floored at ``min_bucket``, clamped at ``max_bucket``) so each encoder
only ever sees O(log max_len) distinct shapes and the recompile count
plateaus after warmup.

Padding must not change the math:
  * text: PAD id 0 — the text encoder already key-masks ``tokens > 0``
    and mean-pools over the same mask;
  * vitals: zero-padded timesteps plus an explicit ``len`` vector; the
    recurrent encoders freeze their carry on padded steps (see
    ``models.emsnet.vitals_encoder``), so the final state equals the
    unpadded run's;
  * batch axis (multi-session coalescing): rows above ``n`` are
    zero/PAD rows the caller slices away.

Inputs longer than ``max_bucket`` are cropped to it: vitals keep their
most recent steps (a sliding window; NEMSIS caps at 30 per event
anyway), text keeps its leading tokens (the valid prefix).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax.numpy as jnp


def next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def bucket_length(n: int, *, min_bucket: int = 8,
                  max_bucket: Optional[int] = None) -> int:
    b = max(min_bucket, next_pow2(n))
    if max_bucket is not None:
        # the cap itself is the top bucket (NOT rounded up: a
        # non-power-of-two cap like max_text_len=48 must never produce
        # inputs longer than the positional table)
        b = min(b, max_bucket)
    return b


def pad_axis(x, length: int, axis: int, pad_value=0, keep: str = "tail"):
    """Pad ``axis`` to ``length``; when cropping keep the trailing
    (``keep="tail"``, for streams where the recent window matters) or
    leading (``keep="head"``, for right-padded sequences whose valid
    prefix must survive) slice."""
    n = x.shape[axis]
    if n == length:
        return x
    if n > length:
        idx = [slice(None)] * x.ndim
        idx[axis] = (slice(n - length, n) if keep == "tail"
                     else slice(0, length))
        return x[tuple(idx)]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, length - n)
    return jnp.pad(x, widths, constant_values=pad_value)


@dataclass
class Bucketer:
    """Pads per-modality payloads to bucketed lengths.

    ``fit`` returns the exact pytree the (mask-aware) encoders consume:
      * text  (B, S) int32  -> (B, S_b) int32, PAD=0
      * vitals (B, T, n)    -> {"x": (B, T_b, n), "len": (B,) int32}
      * anything fixed-size -> passthrough
    """
    min_bucket: int = 8
    max_buckets: Dict[str, int] = field(default_factory=dict)
    # (modality, bucket) -> times served; bounded <=> compiles bounded
    histogram: Dict[tuple, int] = field(default_factory=dict)

    def bucket(self, modality: str, n: int) -> int:
        return bucket_length(n, min_bucket=self.min_bucket,
                             max_bucket=self.max_buckets.get(modality))

    def _count(self, modality: str, b: int):
        key = (modality, b)
        self.histogram[key] = self.histogram.get(key, 0) + 1

    def fit(self, modality: str, x):
        if modality == "text":
            b = self.bucket(modality, x.shape[1])
            self._count(modality, b)
            # valid tokens are a prefix (PAD suffix): keep the head so a
            # crop drops PAD, not the utterance
            return pad_axis(x, b, axis=1, keep="head")
        if modality == "vitals":
            T = x.shape[1]
            b = self.bucket(modality, T)
            self._count(modality, b)
            return {"x": pad_axis(x, b, axis=1),
                    "len": jnp.full((x.shape[0],), min(T, b), jnp.int32)}
        return x

    def n_buckets(self) -> int:
        return len(self.histogram)


def stack_bucketed(payloads, batch_bucket: int):
    """Coalesce per-session payloads (each batch dim 1, same bucketed
    length) into one batch of ``batch_bucket`` rows; surplus rows are
    zero/PAD padding (zero ``len`` for masked-vitals dicts, so padded
    rows encode to the zero initial state). Returns the stacked pytree;
    row i -> session i for the first ``len(payloads)`` rows."""
    if isinstance(payloads[0], dict):
        keys = payloads[0].keys()
        for i, p in enumerate(payloads[1:], start=1):
            if p.keys() != keys:
                raise ValueError(
                    f"stack_bucketed: payload 0 has keys {sorted(keys)} but "
                    f"payload {i} has {sorted(p.keys())}; refusing to drop "
                    "mismatched keys")
        return {k: pad_axis(jnp.concatenate([p[k] for p in payloads], axis=0),
                            batch_bucket, axis=0)
                for k in keys}
    x = jnp.concatenate(list(payloads), axis=0)
    return pad_axis(x, batch_bucket, axis=0)


@dataclass
class RaggedBatch:
    """Concatenated ragged layout: rows of different natural lengths
    packed back-to-back into ONE flat buffer, so a flush issues a single
    encoder call per modality regardless of how many length buckets are
    live (vs one call per ``(modality, bucket)`` for :class:`Bucketer`).

    ``pack`` returns the payload dict the (ragged-aware) encoders
    consume. For ``text`` (B=1 rows of (1, S_i) int32):

      * ``tokens``  (1, T) int32 — rows concatenated, PAD=0 between and
        after; each row starts at a multiple of ``align`` (the flash
        block size: block-aligned row starts are what make the packed
        kernel bit-identical to per-row calls);
      * ``row_ids`` (T,) int32 — position -> row index, -1 on padding
        AND on a row's interior PAD tokens (so the segment mask equals
        the reference ``tokens > 0`` key mask);
      * ``pos``     (T,) int32 — position within the row (for the
        positional embedding gather);
      * ``offsets`` (R,) int32 / ``lengths`` (R,) int32 — row i occupies
        ``[offsets[i], offsets[i] + lengths[i])``; surplus rows (R is
        padded to a power of two) have length 0 and offset == the total
        packed extent, so the segments tile the buffer exactly.

    For ``vitals`` (rows of (1, S_i, n) float): ``x`` (1, T, n) packed
    with ``align=1`` (the segmented scan has no cross-length reduction,
    so alignment buys nothing), plus ``reset`` (T, 1, 1) bool marking
    each row's first step, and the same ``offsets``/``lengths``.

    Both T and R are padded to powers of two (T floored at
    ``min_total``, R at ``min_rows``) so compile counts stay
    O(log² total) like the bucketer's. Rows longer than
    ``max_lengths[modality]`` are cropped exactly like ``Bucketer.fit``
    (text keeps its head, vitals its tail). ``histogram`` counts packed
    ``(modality, (R, T))`` shapes served.
    """
    align: int = 8
    min_total: int = 8
    min_rows: int = 1
    max_lengths: Dict[str, int] = field(default_factory=dict)
    histogram: Dict[tuple, int] = field(default_factory=dict)

    def _crop(self, modality: str, x):
        cap = self.max_lengths.get(modality)
        if cap is not None and x.shape[1] > cap:
            x = pad_axis(x, cap, axis=1,
                         keep="head" if modality == "text" else "tail")
        return x

    def _layout(self, lengths, align: int):
        offs, o = [], 0
        for n in lengths:
            offs.append(o)
            if n:
                o += -(-n // align) * align
        total = o
        T = max(self.min_total, next_pow2(max(total, 1)))
        R = max(self.min_rows, next_pow2(max(len(lengths), 1)))
        return offs, total, T, R

    def _index_vectors(self, offs, lens, total, T, R):
        import numpy as np
        offsets = np.full((R,), total, np.int32)
        lengths = np.zeros((R,), np.int32)
        offsets[:len(offs)] = offs
        lengths[:len(lens)] = lens
        return offsets, lengths

    def pack(self, modality: str, payloads):
        """payloads: list of (1, S_i, ...) arrays (one per session row)."""
        import numpy as np
        rows = [self._crop(modality, p) for p in payloads]
        lens = [int(r.shape[1]) for r in rows]
        if modality == "text":
            offs, total, T, R = self._layout(lens, self.align)
            toks = np.zeros((1, T), np.int32)
            seg = np.full((T,), -1, np.int32)
            pos = np.zeros((T,), np.int32)
            for i, (r, o, n) in enumerate(zip(rows, offs, lens)):
                rv = np.asarray(r[0], np.int32)
                toks[0, o:o + n] = rv
                seg[o:o + n] = np.where(rv > 0, i, -1)
                pos[o:o + n] = np.arange(n)
            offsets, lengths = self._index_vectors(offs, lens, total, T, R)
            self._count(modality, (R, T))
            return {"tokens": jnp.asarray(toks),
                    "row_ids": jnp.asarray(seg),
                    "pos": jnp.asarray(pos),
                    "offsets": jnp.asarray(offsets),
                    "lengths": jnp.asarray(lengths)}
        if modality == "vitals":
            offs, total, T, R = self._layout(lens, 1)
            n_feat = int(rows[0].shape[2])
            x = np.zeros((1, T, n_feat), np.float32)
            reset = np.zeros((T, 1, 1), bool)
            for r, o, n in zip(rows, offs, lens):
                if n:
                    x[0, o:o + n] = np.asarray(r[0], np.float32)
                    reset[o] = True
            offsets, lengths = self._index_vectors(offs, lens, total, T, R)
            self._count(modality, (R, T))
            return {"x": jnp.asarray(x),
                    "reset": jnp.asarray(reset),
                    "offsets": jnp.asarray(offsets),
                    "lengths": jnp.asarray(lengths)}
        raise ValueError(f"RaggedBatch.pack: no ragged layout for "
                         f"modality {modality!r} (fixed-size payloads "
                         "stack on the batch axis instead)")

    def _count(self, modality: str, shape):
        key = (modality, shape)
        self.histogram[key] = self.histogram.get(key, 0) + 1

    def n_shapes(self) -> int:
        return len(self.histogram)
