"""Shape bucketing: bounded static shapes for variable-length inputs.

XLA compiles one program per input shape. An EMS episode grows its
vitals time series with every event and text utterances vary in token
count, so serving them at their natural shapes recompiles the encoders
over and over — the dominant cost on the serving hot path. The bucketer
pads every variable-length input up to the next power-of-two length
(floored at ``min_bucket``, clamped at ``max_bucket``) so each encoder
only ever sees O(log max_len) distinct shapes and the recompile count
plateaus after warmup.

Padding must not change the math:
  * text: PAD id 0 — the text encoder already key-masks ``tokens > 0``
    and mean-pools over the same mask;
  * vitals: zero-padded timesteps plus an explicit ``len`` vector; the
    recurrent encoders freeze their carry on padded steps (see
    ``models.emsnet.vitals_encoder``), so the final state equals the
    unpadded run's;
  * batch axis (multi-session coalescing): rows above ``n`` are
    zero/PAD rows the caller slices away.

Inputs longer than ``max_bucket`` are cropped to it: vitals keep their
most recent steps (a sliding window; NEMSIS caps at 30 per event
anyway), text keeps its leading tokens (the valid prefix).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax.numpy as jnp


def next_pow2(n: int) -> int:
    return 1 << max(0, int(n) - 1).bit_length()


def bucket_length(n: int, *, min_bucket: int = 8,
                  max_bucket: Optional[int] = None) -> int:
    b = max(min_bucket, next_pow2(n))
    if max_bucket is not None:
        # the cap itself is the top bucket (NOT rounded up: a
        # non-power-of-two cap like max_text_len=48 must never produce
        # inputs longer than the positional table)
        b = min(b, max_bucket)
    return b


def pad_axis(x, length: int, axis: int, pad_value=0, keep: str = "tail"):
    """Pad ``axis`` to ``length``; when cropping keep the trailing
    (``keep="tail"``, for streams where the recent window matters) or
    leading (``keep="head"``, for right-padded sequences whose valid
    prefix must survive) slice."""
    n = x.shape[axis]
    if n == length:
        return x
    if n > length:
        idx = [slice(None)] * x.ndim
        idx[axis] = (slice(n - length, n) if keep == "tail"
                     else slice(0, length))
        return x[tuple(idx)]
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, length - n)
    return jnp.pad(x, widths, constant_values=pad_value)


@dataclass
class Bucketer:
    """Pads per-modality payloads to bucketed lengths.

    ``fit`` returns the exact pytree the (mask-aware) encoders consume:
      * text  (B, S) int32  -> (B, S_b) int32, PAD=0
      * vitals (B, T, n)    -> {"x": (B, T_b, n), "len": (B,) int32}
      * anything fixed-size -> passthrough
    """
    min_bucket: int = 8
    max_buckets: Dict[str, int] = field(default_factory=dict)
    # (modality, bucket) -> times served; bounded <=> compiles bounded
    histogram: Dict[tuple, int] = field(default_factory=dict)

    def bucket(self, modality: str, n: int) -> int:
        return bucket_length(n, min_bucket=self.min_bucket,
                             max_bucket=self.max_buckets.get(modality))

    def _count(self, modality: str, b: int):
        key = (modality, b)
        self.histogram[key] = self.histogram.get(key, 0) + 1

    def fit(self, modality: str, x):
        if modality == "text":
            b = self.bucket(modality, x.shape[1])
            self._count(modality, b)
            # valid tokens are a prefix (PAD suffix): keep the head so a
            # crop drops PAD, not the utterance
            return pad_axis(x, b, axis=1, keep="head")
        if modality == "vitals":
            T = x.shape[1]
            b = self.bucket(modality, T)
            self._count(modality, b)
            return {"x": pad_axis(x, b, axis=1),
                    "len": jnp.full((x.shape[0],), min(T, b), jnp.int32)}
        return x

    def n_buckets(self) -> int:
        return len(self.histogram)


def stack_bucketed(payloads, batch_bucket: int):
    """Coalesce per-session payloads (each batch dim 1, same bucketed
    length) into one batch of ``batch_bucket`` rows; surplus rows are
    zero/PAD padding (zero ``len`` for masked-vitals dicts, so padded
    rows encode to the zero initial state). Returns the stacked pytree;
    row i -> session i for the first ``len(payloads)`` rows."""
    if isinstance(payloads[0], dict):
        keys = payloads[0].keys()
        return {k: pad_axis(jnp.concatenate([p[k] for p in payloads], axis=0),
                            batch_bucket, axis=0)
                for k in keys}
    x = jnp.concatenate(list(payloads), axis=0)
    return pad_axis(x, batch_bucket, axis=0)
