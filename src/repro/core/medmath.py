"""Tasks 4 & 5: med-math dosage and disease-history inference.

Task 4 (paper §3.4): dosage = prescribed quantity / label concentration
— "a division operator" fed by header-3 output and the OCR/barcode
concentration. Task 5: medicine name -> disease history via a dictionary
of 82 common EMS diseases (synthetic stand-in table with the same
cardinality).
"""
from __future__ import annotations

import difflib

N_DISEASES = 82

# synthetic stand-ins with the paper's cardinalities (18 medicines)
MEDICINES = [
    "adrenaline", "atrovent", "naloxone", "aspirin", "nitroglycerin",
    "albuterol", "epinephrine", "glucagon", "morphine", "fentanyl",
    "midazolam", "diazepam", "amiodarone", "lidocaine", "atropine",
    "dextrose", "ondansetron", "diphenhydramine",
]

DISEASE_MAP = {m: sorted((hash(m) + i) % N_DISEASES for i in range(3))
               for m in MEDICINES}

CONCENTRATIONS = {m: round(0.5 + (hash(m) % 80) / 10.0, 1) for m in MEDICINES}


def med_math(quantity_mg: float, concentration_mg_per_ml: float) -> float:
    """Paper example: 21 mg of Adrenaline at 4.2 mg/ml -> 5 ml."""
    if concentration_mg_per_ml <= 0:
        raise ValueError("concentration must be positive")
    return quantity_mg / concentration_mg_per_ml


def ed_match(raw: str, candidates=MEDICINES, cutoff: float = 0.4):
    """Edit-distance matching of noisy OCR output to the true medicine
    list (paper Fig. 6 'ED-Match'). Returns best candidate or None."""
    hits = difflib.get_close_matches(raw.lower().strip(), candidates,
                                     n=1, cutoff=cutoff)
    return hits[0] if hits else None


def disease_history(medicine_name: str):
    m = ed_match(medicine_name)
    if m is None:
        return []
    return DISEASE_MAP[m]


def dosage_from_label(quantity_mg: float, ocr_text: str):
    """End of the image pipeline: OCR text -> medicine + concentration ->
    dosage (task 4) + disease history (task 5)."""
    m = ed_match(ocr_text)
    if m is None:
        return None
    conc = CONCENTRATIONS[m]
    return {
        "medicine": m,
        "concentration_mg_per_ml": conc,
        "dosage_ml": med_math(quantity_mg, conc),
        "disease_history": DISEASE_MAP[m],
    }
