"""Modality-aware model splitter (EMSServe §4.2.1).

Decomposes a MultimodalModule into independently-jitted single-modality
callables plus a fused tail. In the PyTorch original this is an offline
graph-surgery step on module objects; in JAX the split boundary is a
pytree of features, so each piece is its own XLA program — which is
exactly what lets EMSServe (a) run one modality the moment it arrives,
(b) cache its output feature, and (c) place each piece on a different
tier.

``split`` also returns the monolithic jitted forward — the "direct
PyTorch" baseline the paper compares against.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict

import jax

from .modular import MultimodalModule


@dataclass
class SplitModel:
    module: MultimodalModule
    encoders: Dict[str, Callable]     # jitted per-modality: (params, x) -> feature
    tail: Callable                    # jitted: (params, feats) -> outputs
    full: Callable                    # jitted monolithic forward (baseline)

    def modalities(self):
        return self.module.modalities

    def submodules(self):
        """The independently *placeable* pieces of this model, in the
        naming the profiling/placement layers address them by: one
        ``"enc:<modality>"`` per encoder plus the fused ``"tail"`` —
        the same keys :func:`profile` emits and
        ``core.offload.MultiTierPolicy`` places (each may land on a
        different hardware tier)."""
        return tuple(f"enc:{m}" for m in self.module.modalities) + ("tail",)

    def compile_count(self) -> int:
        """Total XLA compilations across this model's jitted callables —
        the number the shape bucketer bounds. Non-jitted splits report 0."""
        n = 0
        for fn in (*self.encoders.values(), self.tail, self.full):
            size = getattr(fn, "_cache_size", None)
            n += size() if callable(size) else 0
        return n

    def quantize_params(self, params):
        """Derive the int8 sidecar pytree the SAME jitted encoders
        accept (``layers.dense`` dispatches on the sidecar leaf form).
        Raises for modules without a quantized variant — a precision-
        enabled spec over such a model is a configuration error, not a
        silent fp32 fallback."""
        if self.module.quantize_fn is None:
            raise ValueError(
                f"model {self.module.name!r} declares no quantize_fn; "
                "it cannot serve an int8 precision tier")
        return self.module.quantize_fn(params)


def select_model(models: Dict[str, SplitModel], observed) -> str | None:
    """EMSServe's model-selection rule (paper §4.2): the model consuming
    the most modalities whose inputs have all been observed. Shared by
    the per-event, batched, and streaming engines so their
    recommendations agree.

    Ties (several models consuming the same number of observed
    modalities) break on the lexicographically greatest sorted modality
    tuple, then the model name — NOT on dict insertion order, so two
    engines built from differently-ordered zoos always pick the same
    model."""
    obs = set(observed)
    best, best_key = None, None
    for name, sm in models.items():
        mods = set(sm.modalities())
        if mods <= obs:
            key = (len(mods), tuple(sorted(mods)), name)
            if best_key is None or key > best_key:
                best, best_key = name, key
    return best


def split(module: MultimodalModule, *, jit: bool = True) -> SplitModel:
    wrap = jax.jit if jit else (lambda f: f)
    encoders = {m: wrap(fn) for m, fn in module.encoder_fns.items()}
    tail = wrap(module.tail_fn)
    full = wrap(module.full_fn())
    return SplitModel(module=module, encoders=encoders, tail=tail, full=full)


def profile(split_model: SplitModel, params, sample_batch: dict,
            *, iters: int = 5) -> Dict[str, float]:
    """One-time offline inference-time profiling (EMSServe §4.2.2).

    Returns wall-seconds per submodule (and the monolithic model) on
    *this* host — the `t^e` column; tier tables derive `t^g` from it.
    """
    times = {}

    def bench(fn, *args):
        out = fn(*args)
        jax.block_until_ready(out)             # warmup/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    feats = {}
    for m in split_model.modalities():
        times[f"enc:{m}"] = bench(split_model.encoders[m], params, sample_batch[m])
        feats[m] = split_model.encoders[m](params, sample_batch[m])
    times["tail"] = bench(split_model.tail, params, feats)
    times["full"] = bench(split_model.full, params, sample_batch)
    return times


def payload_nbytes(tree) -> int:
    """Serialized size in bytes of a pytree of device/NumPy arrays:
    ``size * itemsize`` per array leaf, 8 bytes per scalar. THE one
    byte-sizing rule — the tier transport charges with it and the
    benchmarks report with it, so the two can never diverge."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        itemsize = getattr(getattr(leaf, "dtype", None), "itemsize", None)
        if itemsize is not None and hasattr(leaf, "size"):
            total += int(leaf.size) * int(itemsize)
        else:
            total += 8
    return total


def feature_sizes(split_model: SplitModel, params,
                  sample_batch: dict) -> Dict[str, int]:
    """On-wire bytes of each modality's encoded feature (and the tail's
    head outputs under ``"outputs"``) for a representative batch — what
    the tiered runtime's downlink actually ships, sized from the real
    arrays by :func:`payload_nbytes` rather than guessed. Complements
    :func:`profile` the way the transport complements the profile-table
    clock."""
    feats = {m: split_model.encoders[m](params, sample_batch[m])
             for m in split_model.modalities()}
    sizes = {m: payload_nbytes(f) for m, f in feats.items()}
    sizes["outputs"] = payload_nbytes(split_model.tail(params, feats))
    return sizes
