"""EMS episodes: asynchronously-arriving multimodal data sequences.

Table 6 of the paper, verbatim (S = speech/text, V = vitals, I = image/
scene), plus a seeded random-episode generator. Episode 1 is the
canonical Fig.-1 arrival order; 2 and 3 are its shuffles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

_MAP = {"S": "text", "V": "vitals", "I": "scene"}

EPISODE_1 = "S V V V V V V V V V V I I I I I I I I I I"
EPISODE_2 = "I V I V I V I S V I V I I V V I V V I V I"
EPISODE_3 = "V V V V V V I I I I I I V I V V I I S V I"


@dataclass(frozen=True)
class Event:
    index: int
    modality: str              # text | vitals | scene
    arrival_time: float        # seconds since episode start


def parse(seq: str, *, inter_arrival: float = 1.0) -> List[Event]:
    toks = seq.split()
    return [Event(i, _MAP[t], i * inter_arrival) for i, t in enumerate(toks)]


def table6(inter_arrival: float = 1.0):
    return {
        1: parse(EPISODE_1, inter_arrival=inter_arrival),
        2: parse(EPISODE_2, inter_arrival=inter_arrival),
        3: parse(EPISODE_3, inter_arrival=inter_arrival),
    }


def random_episode(n_events: int, seed: int, *, inter_arrival: float = 1.0,
                   p=(0.05, 0.5, 0.45)) -> List[Event]:
    """One speech event (paper: a single symptom description) plus a
    random mix of vitals/images — NEMSIS records up to 30 vitals/event."""
    rng = np.random.default_rng(seed)
    kinds = rng.choice(["text", "vitals", "scene"], size=n_events, p=p).tolist()
    if "text" not in kinds:
        kinds[rng.integers(n_events)] = "text"
    return [Event(i, k, i * inter_arrival) for i, k in enumerate(kinds)]
