"""EMS episodes: asynchronously-arriving multimodal data sequences.

Table 6 of the paper, verbatim (S = speech/text, V = vitals, I = image/
scene), plus a seeded random-episode generator. Episode 1 is the
canonical Fig.-1 arrival order; 2 and 3 are its shuffles.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

_MAP = {"S": "text", "V": "vitals", "I": "scene"}

EPISODE_1 = "S V V V V V V V V V V I I I I I I I I I I"
EPISODE_2 = "I V I V I V I S V I V I I V V I V V I V I"
EPISODE_3 = "V V V V V V I I I I I I V I V V I I S V I"


@dataclass(frozen=True)
class Event:
    index: int
    modality: str              # text | vitals | scene
    arrival_time: float        # seconds since episode start


def parse(seq: str, *, inter_arrival: float = 1.0) -> List[Event]:
    toks = seq.split()
    return [Event(i, _MAP[t], i * inter_arrival) for i, t in enumerate(toks)]


def table6(inter_arrival: float = 1.0):
    return {
        1: parse(EPISODE_1, inter_arrival=inter_arrival),
        2: parse(EPISODE_2, inter_arrival=inter_arrival),
        3: parse(EPISODE_3, inter_arrival=inter_arrival),
    }


def _check_times(ts, *, what="arrival_times") -> List[float]:
    """Validate an explicit per-event arrival-time sequence: finite,
    non-negative, non-decreasing. Returns it as a list of floats."""
    out = [float(t) for t in ts]
    for i, t in enumerate(out):
        if not np.isfinite(t) or t < 0.0:
            raise ValueError(f"{what}[{i}] = {t!r}: need finite t >= 0")
        if i and t < out[i - 1]:
            raise ValueError(f"{what} must be non-decreasing: "
                             f"[{i}] = {t} < [{i - 1}] = {out[i - 1]}")
    return out


def random_episode(n_events: int, seed: int, *, inter_arrival: float = 1.0,
                   arrival_times=None, p=(0.05, 0.5, 0.45)) -> List[Event]:
    """One speech event (paper: a single symptom description) plus a
    random mix of vitals/images — NEMSIS records up to 30 vitals/event.

    ``arrival_times`` replaces the fixed ``i * inter_arrival`` grid with
    an explicit per-event arrival-time sequence (length ``n_events``,
    non-decreasing) — stochastic intra-session lags without a shim.
    """
    rng = np.random.default_rng(seed)
    kinds = rng.choice(["text", "vitals", "scene"], size=n_events, p=p).tolist()
    if "text" not in kinds:
        kinds[rng.integers(n_events)] = "text"
    if arrival_times is not None:
        times = _check_times(arrival_times)
        if len(times) != n_events:
            raise ValueError(f"arrival_times has {len(times)} entries "
                             f"for n_events={n_events}")
    else:
        times = [i * inter_arrival for i in range(n_events)]
    return [Event(i, k, t) for i, (k, t) in enumerate(zip(kinds, times))]


def horizon(episodes) -> float:
    """Latest arrival time across per-session episodes — the episode-time
    span a driver must replay (used to place mid-episode fault injections
    at a fraction of the incident and to scale wall-clock replays)."""
    return max((ev.arrival_time for evs in episodes.values()
                for ev in evs), default=0.0)


def merge_arrivals(episodes):
    """Interleave per-session episodes into one global arrival stream:
    ``{sid: [Event]} -> [(arrival_time, sid, Event)]`` sorted by time
    (ties broken by sid) — what one edge box at one incident sees. The
    single definition shared by the streaming engine, launcher, and
    benchmarks so they can never disagree on arrival order."""
    return sorted(((ev.arrival_time, sid, ev)
                   for sid, evs in episodes.items() for ev in evs),
                  key=lambda x: (x[0], x[1]))


# ----------------------------------------------------------------------
# Asynchronous-arrival scenarios (streaming runtime workloads)
# ----------------------------------------------------------------------

# Per-modality onset-lag distributions: modality -> (mu, sigma) seconds
# until that modality FIRST becomes available, N(mu, sigma) clipped >= 0.
# The three presets mirror how incidents actually unfold in the field:
#   text_first   — the radio transcript lands before anything else
#                  (dispatch/handover speech precedes patient contact);
#   vitals_first — the monitor is hooked up before anyone narrates
#                  (unresponsive patient, vitals stream starts at once);
#   scene_late   — the camera comes up last (glasses donned / scene
#                  detector warm-up while text + vitals already flow).
LAG_SCENARIOS = {
    "text_first":   {"text": (0.0, 0.05), "vitals": (2.0, 0.8),
                     "scene": (5.0, 1.5)},
    "vitals_first": {"vitals": (0.0, 0.1), "text": (3.0, 1.0),
                     "scene": (4.0, 1.5)},
    "scene_late":   {"text": (0.5, 0.3), "vitals": (1.0, 0.4),
                     "scene": (8.0, 2.0)},
}


def async_episode(scenario: str = "text_first", seed: int = 0, *,
                  n_vitals: int = 6, n_scene: int = 3,
                  vitals_period: float = 1.0, scene_period: float = 2.0,
                  lags=None, times=None) -> List[Event]:
    """Episode with per-modality asynchronous onsets.

    Each modality's first arrival is drawn from its lag distribution
    (``lags`` overrides a ``LAG_SCENARIOS`` preset; values are
    ``(mu, sigma)`` pairs). Text is a single utterance; vitals then
    stream every ``vitals_period`` s and scene refreshes every
    ``scene_period`` s after their onsets. Events are returned sorted by
    arrival time and re-indexed — so the *order in which modalities
    appear* varies per seed/scenario, which is exactly the workload the
    streaming runtime must absorb.

    ``times`` — optional ``{modality: [arrival seconds]}``. A modality
    listed here uses that explicit per-event sequence verbatim (one
    event per entry, non-decreasing) instead of the drawn onset + fixed
    period grid, so callers (e.g. the fleet workload generator) can
    carry true stochastic intra-session lags without a shim layer.
    Modalities absent from ``times`` keep the grid behavior, and the
    rng draw order is unchanged when ``times`` is None."""
    spec = dict(lags if lags is not None else LAG_SCENARIOS[scenario])
    times = dict(times or {})
    rng = np.random.default_rng(seed)

    def onset(m):
        mu, sigma = spec[m]
        return float(max(0.0, rng.normal(mu, sigma)))

    def explicit(m):
        return [(m, t) for t in _check_times(times[m], what=f"times[{m!r}]")]

    events = []
    if "text" in times:
        events += explicit("text")
    elif "text" in spec:
        events.append(("text", onset("text")))
    if "vitals" in times:
        events += explicit("vitals")
    elif "vitals" in spec:
        t0 = onset("vitals")
        events += [("vitals", t0 + i * vitals_period)
                   for i in range(max(1, n_vitals))]
    if "scene" in times:
        events += explicit("scene")
    elif "scene" in spec:
        t0 = onset("scene")
        events += [("scene", t0 + i * scene_period)
                   for i in range(max(1, n_scene))]
    events.sort(key=lambda kt: (kt[1], kt[0]))
    return [Event(i, k, t) for i, (k, t) in enumerate(events)]
