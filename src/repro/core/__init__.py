"""The paper's primary contribution: EMSServe — modality-aware model
splitting, per-modality feature caching, and adaptive edge offloading
for asynchronously-arriving multimodal EMS data."""
from .bucketing import Bucketer, bucket_length, next_pow2  # noqa: F401
from .engine import EMSServe, EventRecord  # noqa: F401
from .episodes import (Event, LAG_SCENARIOS, async_episode,  # noqa: F401
                       horizon, merge_arrivals, random_episode, table6)
from .feature_cache import FeatureCache, StalenessError  # noqa: F401
from .modular import (MultimodalModule, emsnet_module,  # noqa: F401
                      emsnet_subset_module, emsnet_zoo)
from .offload import (TIER_FACTORS, AdaptiveOffloadPolicy,  # noqa: F401
                      BandwidthTrace, HeartbeatMonitor, MultiTierPolicy,
                      ProfileTable, TierDecision, TierEstimate,
                      nlos_bandwidth)
from .splitter import (SplitModel, feature_sizes,  # noqa: F401
                       payload_nbytes, profile, split)
