"""Pallas TPU kernel for one-token decode attention against a
ring-buffer KV cache (the serving hot spot).

Differences from the prefill flash kernel:
  * queries are the G grouped heads of one new token — the "query block"
    is (G, D), tiny; the work is streaming the (W, D) cache through VMEM;
  * validity comes from the cache's per-slot *position* array (slot is
    valid iff 0 <= pos <= t and t - pos < window) rather than iota
    causality — the same masking rule as
    ``repro.models.attention.plain_attention_vs_cache``;
  * grid = (batch*kv_heads, cache_blocks), cache innermost/sequential,
    online-softmax state in VMEM scratch (a flash-decode split-K variant
    with cross-core combine is the natural next step on real hardware;
    this single-pass form is the correctness/roofline reference).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale, window, n_blocks):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = t_ref[0]
    q = q_ref[0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0].astype(jnp.float32)                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = pos_ref[...]                                  # (bk,)
    valid = (pos >= 0) & (pos <= t)
    if window:
        valid &= t - pos < window
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention(q, kbuf, vbuf, slot_pos, t, *, window=0, scale=None,
                     block_k=256, interpret=False):
    """q: (B, 1, H, D); kbuf/vbuf: (B, W, KV, D); slot_pos: (W,) int32;
    t: scalar int32 current position. Returns (B, 1, H, Dv)."""
    B, _, H, D = q.shape
    _, W, KV, Dv = vbuf.shape
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    bk = min(block_k, W)
    pk = (-W) % bk
    qr = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kr = jnp.moveaxis(kbuf, 2, 1).reshape(B * KV, W, D)
    vr = jnp.moveaxis(vbuf, 2, 1).reshape(B * KV, W, Dv)
    pos = slot_pos
    if pk:
        kr = jnp.pad(kr, ((0, 0), (0, pk), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pk), (0, 0)))
        pos = jnp.pad(pos, (0, pk), constant_values=-1)
    nk = (W + pk) // bk
    t_arr = jnp.asarray(t, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=scale, window=window,
                               n_blocks=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, ki: (0,)),
            pl.BlockSpec((1, G, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((bk,), lambda bh, ki: (ki,)),
        ],
        out_specs=pl.BlockSpec((1, G, Dv), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, G, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(t_arr, qr, kr, vr, pos)
    return out.reshape(B, 1, KV * G, Dv)
