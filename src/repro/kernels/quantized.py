"""int8 symmetric per-channel quantized matmul (Pallas).

Three kernels back the quantized glass tier:

  * ``quantize_rowwise`` — per-row symmetric int8 quantization:
    ``scale[m] = max_k |x[m, k]| / 127`` (1.0 for an all-zero row),
    ``q = clip(round(x / scale), -127, 127)``. Round-to-nearest gives
    the per-element round-trip bound ``|dequant(quant(x)) - x| <=
    scale / 2``. Per-output-channel weight quantization is the same
    kernel applied to ``w.T`` (see ``ops.quantize_colwise``).
  * ``dequantize_rowwise`` — ``q.astype(f32) * scale`` (the packed
    wire format a consuming tier unpacks before fusion).
  * ``int8_matmul`` — the fused ``int8 x int8 -> int32 -> scaled f32``
    GEMM: ``out[m, n] = (sum_k xq[m, k] * wq[k, n]) * sx[m] * sw[n]``.
    The contraction accumulates EXACTLY in int32 (no overflow for
    ``K <= 2^31 / 127^2 ~ 133k``, asserted in the wrapper), so the
    only error vs fp32 is the input quantization itself.

Blocking: the grid tiles M (and N for the GEMM); K is kept whole per
block — every matmul in this repo has K = the model width (<= a few
hundred), far under VMEM pressure. Inputs are zero-padded to block
multiples (zero rows quantize to scale 1.0 / q 0 and contribute 0 to
the dot); pad rows/cols are sliced off the output.

On CPU the kernels run with ``interpret=True`` (see ``ops``); on TPU
the same calls lower to Mosaic with the int8 (32, 128) tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams

# int32 accumulator headroom: K * 127 * 127 must stay below 2^31
MAX_K = (1 << 31) // (127 * 127)


def _pad_to(x, mult, axis):
    p = (-x.shape[axis]) % mult
    if not p:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, p)
    return jnp.pad(x, pads)


# ----------------------------------------------------------------------
# quantize / dequantize
# ----------------------------------------------------------------------

def _quantize_kernel(x_ref, q_ref, s_ref):
    x = x_ref[:].astype(jnp.float32)                    # (bm, K)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)   # (bm, 1)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127.0, 127.0)
    q_ref[:] = q.astype(jnp.int8)
    s_ref[:] = scale


def quantize_rowwise(x, *, block_m: int = 32, interpret: bool = False):
    """x: (M, K) float -> (q int8 (M, K), scale f32 (M, 1))."""
    M, K = x.shape
    bm = min(block_m, max(M, 1))
    xp = _pad_to(x, bm, 0)
    nm = xp.shape[0] // bm
    q, s = pl.pallas_call(
        _quantize_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((xp.shape[0], K), jnp.int8),
                   jax.ShapeDtypeStruct((xp.shape[0], 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp)
    return q[:M], s[:M]


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[:] = q_ref[:].astype(jnp.float32) * s_ref[:]


def dequantize_rowwise(q, scale, *, block_m: int = 32,
                       interpret: bool = False):
    """(q int8 (M, K), scale (M, 1)) -> f32 (M, K)."""
    M, K = q.shape
    bm = min(block_m, max(M, 1))
    qp = _pad_to(q, bm, 0)
    sp = _pad_to(scale.astype(jnp.float32), bm, 0)
    nm = qp.shape[0] // bm
    out = pl.pallas_call(
        _dequantize_kernel,
        grid=(nm,),
        in_specs=[pl.BlockSpec((bm, K), lambda i: (i, 0)),
                  pl.BlockSpec((bm, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], K), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(qp, sp)
    return out[:M]


# ----------------------------------------------------------------------
# fused int8 x int8 -> int32 -> scaled f32 GEMM
# ----------------------------------------------------------------------

def _matmul_kernel(xq_ref, wq_ref, sx_ref, sw_ref, o_ref):
    acc = jax.lax.dot_general(
        xq_ref[:], wq_ref[:],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)               # exact in int32
    o_ref[:] = acc.astype(jnp.float32) * sx_ref[:] * sw_ref[:]


def int8_matmul(xq, sx, wq, sw, *, block_m: int = 32, block_n: int = 128,
                interpret: bool = False):
    """Fused quantized GEMM.

    xq: (M, K) int8, sx: (M, 1) f32 row scales,
    wq: (K, N) int8, sw: (1, N) f32 output-channel scales
    -> (M, N) f32 ``(xq @ wq) * sx * sw``.
    """
    M, K = xq.shape
    K2, N = wq.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {xq.shape} x {wq.shape}")
    if K > MAX_K:
        raise ValueError(f"K={K} overflows the int32 accumulator "
                         f"(max {MAX_K})")
    bm = min(block_m, max(M, 1))
    bn = min(block_n, max(N, 1))
    xp = _pad_to(xq, bm, 0)
    sxp = _pad_to(sx.astype(jnp.float32), bm, 0)
    wp = _pad_to(wq, bn, 1)
    swp = _pad_to(sw.astype(jnp.float32), bn, 1)
    nm, nn = xp.shape[0] // bm, wp.shape[1] // bn
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(nm, nn),
        in_specs=[pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
                  pl.BlockSpec((K, bn), lambda i, j: (0, j)),
                  pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((1, bn), lambda i, j: (0, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]),
                                       jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp, wp, sxp, swp)
    return out[:M, :N]


def quantized_matmul(x, wq, sw, *, block_m: int = 32, block_n: int = 128,
                     interpret: bool = False):
    """fp32 activations x pre-quantized weights, one fused path:
    rowwise-quantize ``x`` then ``int8_matmul``. x: (M, K) f32."""
    xq, sx = quantize_rowwise(x, block_m=block_m, interpret=interpret)
    return int8_matmul(xq, sx, wq, sw, block_m=block_m, block_n=block_n,
                       interpret=interpret)
