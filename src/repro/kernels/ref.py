"""Pure-jnp oracles for the Pallas kernels (fully materialized, O(S^2))."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=0, scale=None,
                  kv_lengths=None):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D/Dv). Materialized softmax.
    ``kv_lengths``: optional (B,) per-row valid-key count (key-padding
    mask, non-causal only — mirrors the kernel); a zero-length row
    outputs exactly 0."""
    if causal and kv_lengths is not None:
        raise NotImplementedError("kv_lengths requires causal=False")
    B, Sq, H, D = q.shape
    _, Sk, KV, Dv = v.shape
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale
    q_pos = jnp.arange(Sq)[:, None] + (Sk - Sq if causal else 0)
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    mask = jnp.broadcast_to(mask[None, None, None], s.shape)
    if kv_lengths is not None:
        valid = k_pos[None] < kv_lengths[:, None, None]        # (B, 1, Sk)
        mask &= valid[:, None, None]
    s = jnp.where(mask, s, -1e30)
    w = jnp.where(mask, jax.nn.softmax(s, axis=-1), 0.0)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def rwkv6_ref(r, k, v, w, u, s0=None):
    """Sequential RWKV6 recurrence oracle.

    r/k/v/w: (B, S, H, n) f32, u: (H, n), s0: (B, H, n, n).
    Returns (y (B,S,H,n), final_state)."""
    B, S, H, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)
    f32 = lambda a: a.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, s + u[..., None] * kv)
        s = w_t[..., None] * s + kv
        return s, y

    tm = lambda a: jnp.moveaxis(f32(a), 1, 0)
    s, ys = jax.lax.scan(step, f32(s0), (tm(r), tm(k), tm(v), tm(w)))
    return jnp.moveaxis(ys, 0, 1), s


# ----------------------------------------------------------------------
# int8 quantization oracles
# ----------------------------------------------------------------------

def quantize_rowwise_ref(x):
    """Per-row symmetric int8: scale = max|x|/127 (1.0 for zero rows)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = jnp.where(amax > 0.0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale


def int8_matmul_ref(xq, sx, wq, sw):
    """Exact int32 accumulation, then the per-row/per-channel rescale."""
    acc = xq.astype(jnp.int32) @ wq.astype(jnp.int32)
    return (acc.astype(jnp.float32) * sx.astype(jnp.float32)
            * sw.astype(jnp.float32))


def quantized_matmul_ref(x, wq, sw):
    xq, sx = quantize_rowwise_ref(x)
    return int8_matmul_ref(xq, sx, wq, sw)
