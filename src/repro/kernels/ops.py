"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python); on TPU the same calls lower to
Mosaic. ``REPRO_PALLAS_INTERPRET=0`` switches to compiled mode.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from . import decode_attention as _da
from . import flash_attention as _fa
from . import quantized as _q
from . import rwkv6 as _rw


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET") is not None:
        return os.environ["REPRO_PALLAS_INTERPRET"] not in ("0", "false")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, block_t=64, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _rw.rwkv6_scan(r, k, v, w, u, s0, block_t=block_t,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, kbuf, vbuf, slot_pos, t, *, window=0, block_k=256,
                     interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _da.decode_attention(q, kbuf, vbuf, slot_pos, t, window=window,
                                block_k=block_k, interpret=interpret)


# ----------------------------------------------------------------------
# int8 symmetric per-channel quantization (the quantized glass tier)
# ----------------------------------------------------------------------

@partial(jax.jit, static_argnames=("block_m", "interpret"))
def quantize_rowwise(x, *, block_m=32, interpret=None):
    """x (M, K) f32 -> (q int8 (M, K), scale f32 (M, 1)); symmetric,
    round-to-nearest, so |dequant(q) - x| <= scale/2 elementwise."""
    if interpret is None:
        interpret = _interpret_default()
    return _q.quantize_rowwise(x, block_m=block_m, interpret=interpret)


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def quantize_colwise(w, *, block_m=32, interpret=None):
    """Per-output-channel weight quantization: w (K, N) f32 ->
    (q int8 (K, N), scale f32 (1, N)) — the rowwise kernel on w.T."""
    if interpret is None:
        interpret = _interpret_default()
    q, s = _q.quantize_rowwise(w.T, block_m=block_m, interpret=interpret)
    return q.T, s.T


@partial(jax.jit, static_argnames=("block_m", "interpret"))
def dequantize_rowwise(q, scale, *, block_m=32, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _q.dequantize_rowwise(q, scale, block_m=block_m,
                                 interpret=interpret)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def int8_matmul(xq, sx, wq, sw, *, block_m=32, block_n=128,
                interpret=None):
    """Fused int8 x int8 -> int32 -> scaled f32 GEMM."""
    if interpret is None:
        interpret = _interpret_default()
    return _q.int8_matmul(xq, sx, wq, sw, block_m=block_m,
                          block_n=block_n, interpret=interpret)


@partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def quantized_matmul(x, wq, sw, *, block_m=32, block_n=128,
                     interpret=None):
    """fp32 activations x pre-quantized int8 weights: rowwise-quantize
    then the fused GEMM. Leading dims of x are flattened into M."""
    if interpret is None:
        interpret = _interpret_default()
    lead = x.shape[:-1]
    x2 = x.reshape((-1, x.shape[-1]))
    out = _q.quantized_matmul(x2, wq, sw, block_m=block_m,
                              block_n=block_n, interpret=interpret)
    return out.reshape(lead + (wq.shape[1],))
