"""Jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python); on TPU the same calls lower to
Mosaic. ``REPRO_PALLAS_INTERPRET=0`` switches to compiled mode.
"""
from __future__ import annotations

import os
from functools import partial

import jax

from . import decode_attention as _da
from . import flash_attention as _fa
from . import rwkv6 as _rw


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET") is not None:
        return os.environ["REPRO_PALLAS_INTERPRET"] not in ("0", "false")
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("block_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0=None, *, block_t=64, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _rw.rwkv6_scan(r, k, v, w, u, s0, block_t=block_t,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(q, kbuf, vbuf, slot_pos, t, *, window=0, block_k=256,
                     interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _da.decode_attention(q, kbuf, vbuf, slot_pos, t, window=window,
                                block_k=block_k, interpret=interpret)
