"""Pallas TPU kernel for the RWKV6 time-mix recurrence.

    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;   y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

TPU adaptation: the recurrence is inherently sequential in t, but each
(batch, head) pair is independent and the per-head state is a dense
(n, n) = (64, 64) f32 tile — a perfect VMEM/VPU working set. Layout:
  * grid = (batch*heads, time_chunks); time innermost and sequential so
    the state tile persists in VMEM scratch across chunks (never spilled
    to HBM between chunks — the HBM-resident state of a GPU-style
    implementation is the thing this kernel removes);
  * r/k/v/w stream through VMEM in (chunk, n) blocks;
  * an optional initial state input supports chunked prefill / decode
    restart, and the final state is written out once.

Validated in interpret mode against ``ref.rwkv6_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sf_ref,
            state, *, bt, n_chunks):
    ti = pl.program_id(1)

    @pl.when(ti == 0)
    def _init():
        state[...] = s0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                    # (n,)

    def body(t, _):
        r_t = r_ref[0, t, :].astype(jnp.float32)        # (n,)
        k_t = k_ref[0, t, :].astype(jnp.float32)
        v_t = v_ref[0, t, :].astype(jnp.float32)
        w_t = w_ref[0, t, :].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                # (n, n)
        s_prev = state[...]
        y = jax.lax.dot_general(
            r_t[None, :], s_prev + u[:, None] * kv,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)          # (1, n)
        y_ref[0, t, :] = y[0].astype(y_ref.dtype)
        state[...] = w_t[:, None] * s_prev + kv
        return 0

    jax.lax.fori_loop(0, bt, body, 0)

    @pl.when(ti == n_chunks - 1)
    def _finish():
        sf_ref[0] = state[...].astype(sf_ref.dtype)


def rwkv6_scan(r, k, v, w, u, s0=None, *, block_t=64, interpret=False):
    """r/k/v/w: (B, S, H, n); u: (H, n); s0: (B, H, n, n) or None.

    Returns (y (B, S, H, n) f32, final_state (B, H, n, n) f32)."""
    B, S, H, n = r.shape
    if s0 is None:
        s0 = jnp.zeros((B, H, n, n), jnp.float32)
    bt = min(block_t, S)
    pt = (-S) % bt
    resh = lambda a: jnp.moveaxis(a, 2, 1).reshape(B * H, S, n)
    rr, kk, vv, ww = map(resh, (r, k, v, w))
    if pt:
        # pad with w=1, k=0: state passes through unchanged on pad steps
        rr = jnp.pad(rr, ((0, 0), (0, pt), (0, 0)))
        kk = jnp.pad(kk, ((0, 0), (0, pt), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, pt), (0, 0)))
        ww = jnp.pad(ww, ((0, 0), (0, pt), (0, 0)), constant_values=1.0)
    nt = (S + pt) // bt
    ur = u.reshape(H, n)
    s0r = s0.reshape(B * H, n, n)

    kernel = functools.partial(_kernel, bt=bt, n_chunks=nt)
    t_spec = pl.BlockSpec((1, bt, n), lambda bh, ti: (bh, ti, 0))
    y, sf = pl.pallas_call(
        kernel,
        grid=(B * H, nt),
        in_specs=[
            t_spec, t_spec, t_spec, t_spec,
            pl.BlockSpec((1, n), lambda bh, ti: (bh % H, 0)),
            pl.BlockSpec((1, n, n), lambda bh, ti: (bh, 0, 0)),
        ],
        out_specs=[
            t_spec,
            pl.BlockSpec((1, n, n), lambda bh, ti: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S + pt, n), jnp.float32),
            jax.ShapeDtypeStruct((B * H, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(rr, kk, vv, ww, ur, s0r)
    y = y[:, :S].reshape(B, H, S, n)
    return jnp.moveaxis(y, 1, 2), sf.reshape(B, H, n, n)
