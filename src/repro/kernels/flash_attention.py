"""Pallas TPU flash-attention (forward) kernel.

Blocked online-softmax attention with GQA, causal and sliding-window
masking. TPU-native layout decisions:
  * grid = (batch*heads, q_blocks, kv_blocks), kv innermost and
    sequential so the f32 running max / denominator / accumulator live
    in VMEM scratch across kv steps;
  * block shapes default to (128, head_dim) — MXU-aligned multiples of
    128 on both matmul dims;
  * GQA is handled in the k/v BlockSpec index maps (query head h reads
    kv head h // group_size) — no materialized head repetition in HBM;
  * masks come from broadcasted iotas; fully-masked kv blocks still
    execute and contribute zeros (structural simplicity over
    skip-scheduling; the ~2x causal overhead is quantified in
    EXPERIMENTS.md §Perf).

Validated in interpret mode on CPU against ``ref.attention_ref``; the
TPU path is the same `pl.pallas_call` with interpret=False.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, *refs,
            scale, causal, window, bq, bk, seq_k, n_kv_blocks, q_offset,
            has_lengths, has_segments):
    if has_segments:
        sq_ref, sk_ref, o_ref, m_ref, l_ref, acc_ref = refs
        len_ref = None
    elif has_lengths:
        len_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        len_ref, (o_ref, m_ref, l_ref, acc_ref) = None, refs
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                  # (bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if has_segments:
        # ragged layout: a key is visible iff it belongs to the same row
        # segment as the query; padding carries segment id -1 and is
        # never equal to a valid id, so block/tail padding and foreign
        # rows mask out identically. A fully-masked q row outputs 0.
        sq = sq_ref[0]                                # (bq,) int32
        sk = sk_ref[0]                                # (bk,) int32
        mask = (sq[:, None] == sk[None, :]) & (sk[None, :] >= 0)
    else:
        q_pos = (q_offset + qi * bq
                 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        # kv padding: block padding, or the row's true key count
        mask = k_pos < (len_ref[0, 0] if has_lengths else seq_k)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # re-mask after the shift: when every key so far is masked
    # (m_new == NEG_INF) the subtraction above yields exp(0) = 1, which
    # would let zero-length rows attend uniformly instead of outputting 0.
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)                  # (bk, Dv)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    kv_lengths=None, segment_ids=None, block_q=128,
                    block_k=128, interpret=False):
    """q: (B, Sq, H, D); k, v: (B, Sk, KV, D/Dv). Returns (B, Sq, H, Dv).

    ``kv_lengths``: optional (B,) int32 per-row key count — keys at
    positions >= kv_lengths[b] are masked out (key-padding mask for
    length-bucketed batches). A zero-length row outputs exactly 0.
    Non-causal only: the causal q/k alignment would need a per-row
    offset, which no caller needs yet.

    ``segment_ids``: optional (B, S) int32 for the concatenated ragged
    layout — many natural-length rows packed into one sequence. A query
    attends a key iff their ids match; id -1 marks padding (between
    aligned rows, and block-tail padding) and masks for every query, so
    a -1 query row outputs exactly 0. Requires Sq == Sk and causal=False.
    Unlike the other paths, block shapes are taken exactly as requested
    (sequence padded up to a block multiple): fixed per-block reduction
    shapes are what make a packed call bit-identical to per-row calls
    whose rows start on block boundaries.
    """
    if causal and (kv_lengths is not None or segment_ids is not None):
        raise NotImplementedError(
            "kv_lengths/segment_ids require causal=False (per-row causal "
            "alignment is not implemented)")
    if kv_lengths is not None and segment_ids is not None:
        raise ValueError("kv_lengths and segment_ids are mutually exclusive")
    B, Sq, H, D = q.shape
    _, Sk, KV, Dv = v.shape
    if segment_ids is not None and Sq != Sk:
        raise ValueError("segment_ids requires Sq == Sk (self-attention "
                         "over one packed buffer)")
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    if segment_ids is not None:
        bq, bk = block_q, block_k
    else:
        bq = min(block_q, Sq)
        bk = min(block_k, Sk)
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    qr = jnp.moveaxis(q, 2, 1).reshape(B * H, Sq, D)
    kr = jnp.moveaxis(k, 2, 1).reshape(B * KV, Sk, D)
    vr = jnp.moveaxis(v, 2, 1).reshape(B * KV, Sk, Dv)
    if pq:
        qr = jnp.pad(qr, ((0, 0), (0, pq), (0, 0)))
    if pk:
        kr = jnp.pad(kr, ((0, 0), (0, pk), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (Sk + pk) // bk

    def kv_index(bh, qi, ki):
        return ((bh // H) * KV + (bh % H) // G, ki, 0)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
        seq_k=Sk, n_kv_blocks=nk, q_offset=(Sk - Sq) if causal else 0,
        has_lengths=kv_lengths is not None,
        has_segments=segment_ids is not None)

    in_specs = [
        pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        pl.BlockSpec((1, bk, D), kv_index),
        pl.BlockSpec((1, bk, Dv), kv_index),
    ]
    operands = [qr, kr, vr]
    if segment_ids is not None:
        seg = segment_ids.astype(jnp.int32)
        if pk:
            seg = jnp.pad(seg, ((0, 0), (0, pk)), constant_values=-1)
        # the same (B, S) id array feeds two views: the query block and
        # the key block of each grid step
        in_specs.append(pl.BlockSpec((1, bq), lambda bh, qi, ki: (bh // H, qi)))
        in_specs.append(pl.BlockSpec((1, bk), lambda bh, qi, ki: (bh // H, ki)))
        operands.extend([seg, seg])
    elif kv_lengths is not None:
        # one (1, 1) scalar block per (batch, head) program
        lr = jnp.repeat(kv_lengths.astype(jnp.int32), H)[:, None]
        in_specs.append(pl.BlockSpec((1, 1), lambda bh, qi, ki: (bh, 0)))
        operands.append(lr)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq + pq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    out = out[:, :Sq].reshape(B, H, Sq, Dv)
    return jnp.moveaxis(out, 1, 2)
