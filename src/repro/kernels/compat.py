"""Version-compat shims for the Pallas TPU API.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and
back again across releases); the installed 0.4.x line only has the
``TPU``-prefixed spelling. Every kernel imports ``CompilerParams`` from
here so the rename never touches kernel code again.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
