"""Synthetic NEMSIS-schema multimodal EMS dataset (D1/D2 analogues).

NEMSIS itself is request-gated, so this module implements a generative
stand-in with the *documented* schema and preprocessing (paper §3.1 +
Appendix A):
  * 46 protocols, 18 medicine types, quantity regression labels;
  * symptom text: 4 concatenated symptom fields drawn from
    protocol-conditioned vocabulary (primary symptom, primary
    impression, associated symptom, secondary impression);
  * 6 vitals time series (BP, HR, PO, RR, CO2, BG) with
    protocol-conditioned means, NEMSIS-style recording artifacts
    (default-max outliers like HR=500), variable lengths;
  * scene flags (alcohol / pills / medicine bottle) correlated with the
    protocol (paper §2.3: pill/alcohol presence narrows protocols);
  * preprocessing: 2%-98% percentile clipping, zero *left*-padding to
    30 steps, cross-sample z-score / min-max normalization.

The generative process ties labels to all three modalities so that the
paper's comparative claims (multimodal > unimodal; PMI > scratch on the
small 3-modal set) are testable directionally.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.configs.emsnet import EMSNetConfig

VITALS = ("BP", "HR", "PO", "RR", "CO2", "BG")
VITAL_RANGES = {  # plausible clinical ranges (lo, hi) and default-error value
    "BP": (60, 220, 999), "HR": (30, 180, 500), "PO": (60, 100, 0),
    "RR": (6, 40, 99), "CO2": (15, 60, 0), "BG": (40, 400, 2000),
}
WORDS_PER_FIELD = 4
N_FIELDS = 4          # primary symptom/impression, assoc. symptom, secondary


@dataclass
class Dataset:
    text: np.ndarray          # (N, max_text_len) int32 token ids, 0=PAD
    vitals: np.ndarray        # (N, vitals_len, 6) float32 normalized
    scene: np.ndarray         # (N, 3) float32 {0,1}
    protocol: np.ndarray      # (N,) int32
    medicine: np.ndarray      # (N,) int32
    quantity: np.ndarray      # (N,) float32 normalized

    def __len__(self):
        return len(self.protocol)

    def subset(self, idx):
        return Dataset(self.text[idx], self.vitals[idx], self.scene[idx],
                       self.protocol[idx], self.medicine[idx], self.quantity[idx])

    def batch(self, idx, modalities=("text", "vitals", "scene")):
        b = {m: getattr(self, m)[idx] for m in modalities}
        b["labels"] = {"protocol": self.protocol[idx],
                       "medicine": self.medicine[idx],
                       "quantity": self.quantity[idx]}
        return b


def _protocol_params(cfg: EMSNetConfig, rng):
    """Per-protocol generative parameters."""
    P = cfg.n_protocols
    vocab_per_proto = 24
    word_bank = rng.integers(5, cfg.vocab_size, size=(P, vocab_per_proto))
    vital_mean = rng.uniform(0.25, 0.75, size=(P, len(VITALS)))
    # medicine conditional on protocol (sparse support of 3 options each)
    med_support = rng.integers(0, cfg.n_medicines, size=(P, 3))
    scene_prob = rng.uniform(0.05, 0.9, size=(P, 3))
    qty_base = rng.uniform(0.5, 8.0, size=(cfg.n_medicines,))
    return dict(word_bank=word_bank, vital_mean=vital_mean,
                med_support=med_support, scene_prob=scene_prob,
                qty_base=qty_base)


def generate(cfg: EMSNetConfig, n: int, *, seed: int = 0,
             modal3: bool = False) -> Dataset:
    """Raw event generation + the documented preprocessing pipeline."""
    rng = np.random.default_rng(seed)
    gp = _protocol_params(cfg, np.random.default_rng(1234))  # fixed world

    proto = rng.integers(0, cfg.n_protocols, size=n)
    med = gp["med_support"][proto, rng.integers(0, 3, size=n)]
    qty_raw = gp["qty_base"][med] * rng.lognormal(0, 0.35, size=n)

    # ---- text: 4 fields x words from the protocol's vocabulary ----
    L = cfg.max_text_len
    text = np.zeros((n, L), np.int32)
    n_words = min(N_FIELDS * WORDS_PER_FIELD, L)
    picks = rng.integers(0, gp["word_bank"].shape[1], size=(n, n_words))
    text[:, :n_words] = gp["word_bank"][proto[:, None], picks]
    # drop some words (shorter sentences)
    drop = rng.random((n, n_words)) < 0.15
    text[:, :n_words] = np.where(drop, 0, text[:, :n_words])

    # ---- vitals: protocol-conditioned random walks with artifacts ----
    T, V = cfg.vitals_len, len(VITALS)
    lens = rng.integers(max(1, T // 6), T + 1, size=n)
    lo = np.array([VITAL_RANGES[v][0] for v in VITALS], np.float32)
    hi = np.array([VITAL_RANGES[v][1] for v in VITALS], np.float32)
    bad = np.array([VITAL_RANGES[v][2] for v in VITALS], np.float32)
    mean = lo + gp["vital_mean"][proto] * (hi - lo)              # (n, V)
    walk = rng.normal(0, 0.03, size=(n, T, V)).cumsum(axis=1)
    raw = mean[:, None, :] * (1 + walk) \
        + rng.normal(0, 0.02, size=(n, T, V)) * (hi - lo)
    # inject NEMSIS default-value recording errors (~2% of entries)
    err = rng.random((n, T, V)) < 0.02
    raw = np.where(err, bad, raw)

    # ---- scene flags ----
    scene = (rng.random((n, 3)) < gp["scene_prob"][proto]).astype(np.float32)
    if modal3:
        # 3-modal events: scene flags sharpen the protocol signal
        med = np.where(scene[:, 1] > 0, gp["med_support"][proto, 0], med)

    # ================= preprocessing (Appendix A) =================
    # (1) 2%-98% percentile clipping per vital
    ql = np.quantile(raw, 0.02, axis=(0, 1))
    qh = np.quantile(raw, 0.98, axis=(0, 1))
    clipped = np.clip(raw, ql, qh)
    # (2) zero left-padding: only the last `len` steps are real
    t_idx = np.arange(T)[None, :, None]
    valid = t_idx >= (T - lens)[:, None, None]
    padded = np.where(valid, clipped, 0.0)
    # (3) cross-sample normalization (z-score over valid entries)
    flat = np.where(valid, padded, np.nan)
    mu = np.nanmean(flat, axis=(0, 1))
    sd = np.nanstd(flat, axis=(0, 1)) + 1e-6
    vitals = np.where(valid, (padded - mu) / sd, 0.0).astype(np.float32)

    # quantity labels: same clip + z-score discipline
    qlo, qhi = np.quantile(qty_raw, [0.02, 0.98])
    q = np.clip(qty_raw, qlo, qhi)
    q = (q - q.mean()) / (q.std() + 1e-6)

    return Dataset(text=text, vitals=vitals, scene=scene,
                   protocol=proto.astype(np.int32), medicine=med.astype(np.int32),
                   quantity=q.astype(np.float32))


def splits(ds: Dataset, *, seed=0, ratios=(3, 1, 1)):
    """Paper: 74821/24761/24761 = 3:1:1 train/val/test."""
    n = len(ds)
    rng = np.random.default_rng(seed)
    order = rng.permutation(n)
    a = n * ratios[0] // sum(ratios)
    b = n * (ratios[0] + ratios[1]) // sum(ratios)
    return ds.subset(order[:a]), ds.subset(order[a:b]), ds.subset(order[b:])


def loader(ds: Dataset, batch_size: int, *, seed=0, shuffle=True,
           modalities=("text", "vitals", "scene"), drop_last=True):
    rng = np.random.default_rng(seed)
    while True:
        order = rng.permutation(len(ds)) if shuffle else np.arange(len(ds))
        stop = len(ds) - batch_size + 1 if drop_last else len(ds)
        for i in range(0, stop, batch_size):
            yield ds.batch(order[i:i + batch_size], modalities)
