"""MusicGen-large [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens: 48 layers, d_model=2048,
32 heads (MHA), d_ff=8192, 4 codebooks x vocab 2048 (delay interleaving
pattern), cross-attention to text-conditioning embeddings in every
layer (T5 frontend is a stub providing precomputed embeddings).
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="dense", cross=True)


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        arch_type="audio",
        d_model=2048,
        n_layers=48,
        n_heads=32,
        n_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        groups=(((L,), 48),),
        n_codebooks=4,
        cond_seq_len=64,      # stub: T5 text-conditioning tokens
        cond_dim=768,
        rope_theta=10000.0,
    )
