"""Jamba-v0.1 52B [arXiv:2403.19887].

32 layers, d_model=4096, hybrid Mamba+attention with a 1:7
attention:mamba ratio (one attention layer per 8-layer period), GQA
kv=8 on the attention layers, MoE (16 experts top-2, expert d_ff 14336)
on every other layer, vocab 65536.
"""
from .base import LayerSpec, MambaConfig, ModelConfig

M_D = LayerSpec(mixer="mamba", mlp="dense")
M_E = LayerSpec(mixer="mamba", mlp="moe")
A_E = LayerSpec(mixer="attn", mlp="moe")


def config() -> ModelConfig:
    # 8-layer period: mamba at 0-3,5-7 / attention at 4; MoE on odd layers.
    period = (M_D, M_E, M_D, M_E, A_E, M_D, M_E, M_D)
    return ModelConfig(
        name="jamba-v0.1-52b",
        arch_type="hybrid",
        d_model=4096,
        n_layers=32,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        groups=((period, 4),),
        n_experts=16,
        experts_per_tok=2,
        moe_d_ff=14336,
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        rope_theta=10000.0,
    )
