"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision].

Language backbone: 40 layers, d_model=4096, 32 heads GQA kv=8,
d_ff=14336, vocab 128256; every 5th layer is a gated cross-attention
layer over vision-patch embeddings. The ViT frontend is a stub
providing (B, 1601, 1280) patch embeddings (projected to d_model).
"""
from .base import LayerSpec, ModelConfig

SELF = LayerSpec(mixer="attn", mlp="dense")
CROSS = LayerSpec(mixer="cross_attn", mlp="dense")


def config() -> ModelConfig:
    # period: 4 self-attn layers then 1 cross-attn layer, x8 = 40 layers
    return ModelConfig(
        name="llama-3.2-vision-11b",
        arch_type="vlm",
        d_model=4096,
        n_layers=40,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=128256,
        groups=(((SELF, SELF, SELF, SELF, CROSS), 8),),
        cond_seq_len=1601,    # stub ViT patch embeddings
        cond_dim=1280,
        rope_theta=500000.0,
    )
