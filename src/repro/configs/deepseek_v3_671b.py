"""DeepSeek-V3 671B [arXiv:2412.19437].

61 layers, d_model=7168, 128 heads, MLA (q_lora 1536 / kv_lora 512,
nope 128 + rope 64, v 128), first 3 layers dense FFN (d_ff 18432), the
remaining 58 layers MoE with 1 shared + 256 routed experts, top-8,
expert d_ff 2048, vocab 129280, MTP head.

The assignment line "d_ff=2048" is the routed-expert intermediate size;
the dense layers use the published 18432.
"""
from .base import LayerSpec, MLAConfig, ModelConfig

DENSE = LayerSpec(mixer="mla", mlp="dense")
MOE = LayerSpec(mixer="mla", mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        arch_type="moe",
        d_model=7168,
        n_layers=61,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=18432,
        vocab_size=129280,
        groups=(((DENSE,), 3), ((MOE,), 58)),
        n_experts=256,
        experts_per_tok=8,
        n_shared_experts=1,
        moe_d_ff=2048,
        mla=MLAConfig(),
        rope_theta=10000.0,
        mtp=True,
        train_microbatches=8,
    )
