"""Config system: architecture configs, input shapes, reduced (smoke) variants.

Every assigned architecture gets one ``<id>.py`` in this package that
builds a :class:`ModelConfig` with the exact published dimensions (source
cited in the file header). ``reduced()`` derives the CPU-smoke-test
variant (<=2 effective layer periods, d_model<=512, <=4 experts) while
preserving the layer-group *structure* of the family.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims [arXiv:2412.19437]."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM dims (Jamba uses these) [arXiv:2403.19887]."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model


@dataclass(frozen=True)
class LayerSpec:
    """One layer position inside a repeating period.

    mixer: 'attn' | 'mla' | 'mamba' | 'rwkv6' | 'cross_attn'
    mlp:   'dense' | 'moe' | 'rwkv_cmix' | 'none'
    cross: if True, an additional cross-attention sublayer runs after the
           self mixer (musicgen-style conditioning).
    window: sliding-window size for this layer's self attention
            (0 = full causal).
    """
    mixer: str = "attn"
    mlp: str = "dense"
    cross: bool = False
    window: int = 0


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                      # dense|moe|ssm|hybrid|vlm|audio
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # repeating layer structure: ((period_specs, repeat_count), ...)
    # n_layers == sum(len(period) * count)
    groups: tuple = ()
    # --- MoE ---
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    # --- attention details ---
    mlp_act: str = "swiglu"             # swiglu | relu2
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv_head_dim: int = 64
    # --- modality frontend stubs ---
    cond_seq_len: int = 0               # vision patches / conditioning tokens
    cond_dim: int = 0                   # frontend embedding dim
    n_codebooks: int = 1                # musicgen EnCodec codebooks
    # --- extras ---
    mtp: bool = False                   # DeepSeek multi-token prediction
    long_context_window: int = 8192     # sliding window used for long_500k
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- training / distribution policy ---
    fsdp_weights: bool = True
    remat: bool = True
    train_microbatches: int = 4
    optimizer: str = "adafactor"

    def __post_init__(self):
        if self.groups:
            n = sum(len(specs) * count for specs, count in self.groups)
            if n != self.n_layers:
                raise ValueError(
                    f"{self.name}: groups cover {n} layers != n_layers={self.n_layers}")

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d = self.d_model
        total = self.vocab_size * d * self.n_codebooks          # embed
        if not self.tie_embeddings:
            total += d * self.vocab_size * self.n_codebooks     # lm head
        for specs, count in self.groups:
            per = 0
            for s in specs:
                per += _mixer_params(self, s)
                per += _mlp_params(self, s)
                per += 2 * d                                     # norms
            total += per * count
        if self.cond_dim:
            total += self.cond_dim * d                           # projector
        return total

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top-k experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # subtract inactive routed experts
        for specs, count in self.groups:
            for s in specs:
                if s.mlp == "moe":
                    inactive = self.n_experts - self.experts_per_tok
                    total -= count * inactive * 3 * d * self.moe_d_ff
        return total


def _mixer_params(cfg: ModelConfig, s: LayerSpec) -> int:
    d = cfg.d_model
    if s.mixer == "attn" or s.mixer == "cross_attn":
        n = d * cfg.q_dim + 2 * d * cfg.kv_dim + cfg.q_dim * d
        if s.cross:
            n += d * cfg.q_dim + 2 * cfg.cond_dim * cfg.kv_dim + cfg.q_dim * d
        return n
    if s.mixer == "mla":
        m = cfg.mla
        qh = cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
        return (d * m.q_lora_rank + m.q_lora_rank * qh
                + d * (m.kv_lora_rank + m.qk_rope_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_dim)
                + cfg.n_heads * m.v_dim * d)
    if s.mixer == "mamba":
        di = cfg.mamba.d_inner(d)
        st = cfg.mamba.d_state
        dtr = max(d // 16, 1)
        return (d * 2 * di + di * cfg.mamba.d_conv + di * (dtr + 2 * st)
                + dtr * di + di * st + di + di * d)
    if s.mixer == "rwkv6":
        return 4 * d * d + d * d + 2 * d * 64  # r,k,v,g,o + w lora
    raise ValueError(s.mixer)


def _mlp_params(cfg: ModelConfig, s: LayerSpec) -> int:
    d = cfg.d_model
    if s.mlp == "dense":
        mult = 3 if cfg.mlp_act == "swiglu" else 2
        return mult * d * cfg.d_ff
    if s.mlp == "moe":
        n = cfg.n_experts * 3 * d * cfg.moe_d_ff + d * cfg.n_experts
        n += cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        return n
    if s.mlp == "rwkv_cmix":
        return 2 * d * cfg.d_ff
    return 0


# ----------------------------------------------------------------------
# Input shapes (assigned)
# ----------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, d_model: int = 128, seq_cap: int = 64) -> ModelConfig:
    """Smoke-test variant: same family structure, tiny dims.

    Keeps one period per group with count 1 (so every layer *kind* in the
    family is exercised) and scales every dimension down.
    """
    del seq_cap
    scale = d_model / cfg.d_model
    groups = tuple((specs, 1) for specs, _ in cfg.groups)
    n_layers = sum(len(specs) for specs, _ in groups)
    n_heads = max(2, min(4, cfg.n_heads))
    head_dim = max(8, d_model // n_heads)
    n_kv = n_heads if cfg.n_kv_heads == cfg.n_heads else max(1, n_heads // 2)
    n_experts = min(cfg.n_experts, 4) if cfg.n_experts else 0
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                        qk_rope_dim=8, v_dim=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        d_model=d_model,
        n_layers=n_layers,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=max(32, int(math.ceil(cfg.d_ff * scale / 16) * 16)),
        vocab_size=min(cfg.vocab_size, 512),
        groups=groups,
        n_experts=n_experts,
        experts_per_tok=min(cfg.experts_per_tok, 2) if n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        moe_d_ff=max(16, int(cfg.moe_d_ff * scale)) if cfg.n_experts else 0,
        mla=mla,
        cond_seq_len=min(cfg.cond_seq_len, 8),
        cond_dim=min(cfg.cond_dim, 32) if cfg.cond_dim else 0,
        long_context_window=128,
        dtype="float32",
        fsdp_weights=False,
        remat=False,
        train_microbatches=1,
        optimizer="adamw",
        capacity_factor=2.0,
    )
