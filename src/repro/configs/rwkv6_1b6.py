"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892].

24 layers, d_model=2048, attention-free time-mix with data-dependent
decay (64-dim heads -> 32 heads), channel-mix d_ff=7168 (relu^2),
vocab 65536. Constant-size recurrent state; sub-quadratic by
construction, so long_500k runs natively.
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="rwkv6", mlp="rwkv_cmix")


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        arch_type="ssm",
        d_model=2048,
        n_layers=24,
        n_heads=32,           # d_model / rwkv_head_dim
        n_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65536,
        groups=(((L,), 24),),
        rwkv_head_dim=64,
        fsdp_weights=False,   # 1.6B fits replicated-over-data comfortably
        optimizer="adamw",
    )
