"""Architecture registry: ``get_config(arch_id)`` and ``ARCHS``."""
from __future__ import annotations

from . import (codeqwen15_7b, deepseek_v3_671b, emsnet, jamba_v01_52b,
               llama32_vision_11b, mistral_nemo_12b, musicgen_large,
               nemotron_4_15b, olmoe_1b_7b, qwen15_32b, rwkv6_1b6)
from .base import SHAPES, InputShape, LayerSpec, MambaConfig, MLAConfig, ModelConfig, reduced

_REGISTRY = {
    "deepseek-v3-671b": deepseek_v3_671b.config,
    "nemotron-4-15b": nemotron_4_15b.config,
    "codeqwen1.5-7b": codeqwen15_7b.config,
    "musicgen-large": musicgen_large.config,
    "llama-3.2-vision-11b": llama32_vision_11b.config,
    "qwen1.5-32b": qwen15_32b.config,
    "rwkv6-1.6b": rwkv6_1b6.config,
    "jamba-v0.1-52b": jamba_v01_52b.config,
    "mistral-nemo-12b": mistral_nemo_12b.config,
    "olmoe-1b-7b": olmoe_1b_7b.config,
}

ARCHS = tuple(_REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return _REGISTRY[name]()


def get_emsnet_config(**kw):
    return emsnet.config(**kw)


__all__ = [
    "ARCHS", "SHAPES", "InputShape", "LayerSpec", "MambaConfig", "MLAConfig",
    "ModelConfig", "get_config", "get_emsnet_config", "reduced",
]
