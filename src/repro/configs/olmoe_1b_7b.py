"""OLMoE-1B-7B [arXiv:2409.02060].

16 layers, d_model=2048, 16 heads (MHA kv=16), MoE with 64 experts
top-8, expert d_ff=1024, vocab 50304.
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        arch_type="moe",
        d_model=2048,
        n_layers=16,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        groups=(((L,), 16),),
        n_experts=64,
        experts_per_tok=8,
        moe_d_ff=1024,
        rope_theta=10000.0,
        fsdp_weights=False,   # 7B total fits without FSDP
        optimizer="adamw",
    )
