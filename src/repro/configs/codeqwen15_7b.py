"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B].

Qwen1.5 architecture: 32 layers, d_model=4096, 32 heads (MHA kv=32),
d_ff=13440, vocab 92416, QKV bias, SwiGLU, RoPE theta 1e6.
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b",
        arch_type="dense",
        d_model=4096,
        n_layers=32,
        n_heads=32,
        n_kv_heads=32,
        head_dim=128,
        d_ff=13440,
        vocab_size=92416,
        groups=(((L,), 32),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
