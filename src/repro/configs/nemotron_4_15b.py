"""Nemotron-4 15B [arXiv:2402.16819].

32 layers, d_model=6144, 48 heads, GQA kv=8, d_ff=24576 (squared-ReLU,
no gate), vocab 256000, RoPE.
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b",
        arch_type="dense",
        d_model=6144,
        n_layers=32,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=24576,
        vocab_size=256000,
        groups=(((L,), 32),),
        mlp_act="relu2",
        rope_theta=10000.0,
    )
