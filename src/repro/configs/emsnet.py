"""EMSNet — the paper's own multimodal multitask model.

Text encoder (TinyBERT/MobileBERT/BERTBase-class bidirectional
transformer), vitals encoder (RNN/LSTM/GRU), scene encoder (FC over the
object-detection one-hot), concatenation fusion, three headers:
protocol (46-way), medicine type (18-way), quantity (regression).
Dims follow the paper's candidates (Table 1); defaults are the
TinyBERT-GRU-FC combination the paper highlights for on-device use.
"""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class EMSNetConfig:
    name: str = "emsnet"
    # text encoder (bidirectional transformer)
    text_encoder: str = "tinybert"        # tinybert | mobilebert | bertbase
    vocab_size: int = 8192
    max_text_len: int = 64
    # vitals encoder
    vitals_encoder: str = "gru"           # rnn | lstm | gru
    n_vitals: int = 6                     # BP, HR, PO, RR, CO2, BG
    vitals_len: int = 30                  # up to 30 vitals per event (NEMSIS)
    vitals_hidden: int = 64
    # scene encoder
    scene_dim: int = 3                    # alcohol / pill / medicine-bottle
    scene_hidden: int = 16
    # tasks
    n_protocols: int = 46                 # paper follows EMSAssist: 46
    n_medicines: int = 18
    # training
    dropout: float = 0.0
    dtype: str = "float32"
    # text-attention backend: route _bert_block through the Pallas
    # flash kernel (key-padding-masked). flash_interpret=True runs the
    # kernel body on CPU (this container); set False on real TPUs.
    use_flash_text: bool = False
    flash_interpret: bool = True
    # ragged text attention: flash_segments routes the *natural* (B, S)
    # path through the segment-masked flash kernel at the same fixed
    # flash_block the packed ragged layout uses. Fixed per-block
    # reduction shapes make a packed ragged call bit-identical to the
    # per-row reference, so a bit-parity (atol 0) reference config must
    # set use_flash_text=True, flash_segments=True with the same
    # flash_block as the ragged engine.
    flash_segments: bool = False
    flash_block: int = 8

    @property
    def text_dims(self) -> Tuple[int, int, int, int]:
        """(layers, d_model, heads, d_ff) for the text encoder."""
        return {
            "microbert": (2, 64, 4, 128),      # CPU-bench tier (not in paper)
            "tinybert": (4, 312, 12, 1200),
            "mobilebert": (24, 128, 4, 512),
            "bertbase": (12, 768, 12, 3072),
        }[self.text_encoder]

    @property
    def feature_dims(self):
        """|F_T|, |F_V|, |F_I| — concatenated into F_C."""
        return {
            "text": self.text_dims[1],
            "vitals": self.vitals_hidden,
            "scene": self.scene_hidden,
        }


def config(**kw) -> EMSNetConfig:
    return EMSNetConfig(**kw)


def tiny(**kw) -> EMSNetConfig:
    """Fast CPU-test variant."""
    base = dict(vocab_size=256, max_text_len=16, vitals_len=8,
                vitals_hidden=16, scene_hidden=8)
    base.update(kw)
    return EMSNetConfig(**base)
