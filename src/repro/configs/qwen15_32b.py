"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family card].

64 layers, d_model=5120, 40 heads GQA kv=40 (MHA per assignment),
d_ff=27392, vocab 152064, QKV bias, SwiGLU.
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b",
        arch_type="dense",
        d_model=5120,
        n_layers=64,
        n_heads=40,
        n_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        groups=(((L,), 64),),
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
