"""Mistral-Nemo-Base-2407 12B [hf:mistralai/Mistral-Nemo-Base-2407].

40 layers, d_model=5120, 32 heads GQA kv=8, head_dim=128, d_ff=14336,
vocab 131072, 128k context (rope theta 1e6).
"""
from .base import LayerSpec, ModelConfig

L = LayerSpec(mixer="attn", mlp="dense")


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b",
        arch_type="dense",
        d_model=5120,
        n_layers=40,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        groups=(((L,), 40),),
        rope_theta=1_000_000.0,
    )
