"""Sharding policy: map parameter/cache/batch pytrees to PartitionSpecs.

Policy (MaxText-style 2D "FSDP + tensor parallel"):
  * mesh axes: ('pod', 'data', 'model') multi-pod, ('data', 'model') single.
  * weights: the tensor-parallel dim (heads / d_ff / experts) shards on
    'model'; when ``cfg.fsdp_weights`` the other big dim shards on
    'data' (ZeRO-3 via GSPMD — all-gathered at use). Weights are never
    sharded on 'pod' (pure data parallel across pods).
  * activations: batch shards on ('pod', 'data').
  * decode caches: batch on ('pod','data'); for long_500k (batch=1) the
    *sequence* dim of KV/latent buffers shards on ('pod','data') instead.
  * any dim not divisible by its mesh axis falls back to replication.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

NORM_PARENTS = {"norm1", "norm2", "norm_c", "norm_h", "norm_e", "final_norm",
                "q_norm", "kv_norm", "ln_x"}
RWKV_SMALL = {"w0", "mu", "u", "w_lora_a", "w_lora_b"}
COL_PARENTS = {"wq", "wk", "wv", "gate", "up", "wq_b", "wk_b", "wv_b", "in_proj"}
ROW_PARENTS = {"wo", "down", "out_proj"}


def _path_names(path):
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return out


def axis_sizes(mesh):
    sizes = getattr(mesh, "axis_sizes", None)   # AbstractMesh
    if sizes is not None:
        return dict(zip(mesh.axis_names, sizes))
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def abstract_mesh(shape, axes):
    """Device-free mesh for spec construction/testing, across the
    AbstractMesh signature change: older jax takes ``(shape, axis_names)``
    positionally; 0.4.35+ takes one ``((name, size), ...)`` tuple."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


class Policy:
    """``tuned=False`` is the naive paper-faithful baseline recorded in
    EXPERIMENTS.md §Roofline; ``tuned=True`` applies the §Perf hillclimb
    changes:
      * head-aware attention sharding: wq/wk/wv outputs shard on 'model'
        only when the *head count* divides the axis (a flat-divisible
        but head-splitting sharding makes GSPMD insert all-reduces
        inside the attention chunk loops);
      * 2D expert sharding: MoE expert dim shards over ('data','model')
        when E divides data*model (deepseek: 256 experts over 256 chips)
        — removes the FSDP gather of expert weights entirely.
    """

    def __init__(self, cfg, mesh, *, tuned: bool = False, strategy: str = "2d"):
        """strategy='2d': batch on ('pod','data'), tensor-parallel on
        'model' (+ ZeRO-3 on 'data' when cfg.fsdp_weights) — the
        baseline Megatron-style mapping.

        strategy='fsdp': batch on ('pod','data','model') and ALL weights
        ZeRO-3-sharded across both intra-pod axes — no tensor
        parallelism, so the per-layer Megatron activation all-reduces
        disappear entirely; weights are all-gathered per layer instead.
        §Perf iteration 2: wins whenever per-layer weight bytes <
        per-layer activation bytes x TP traffic (true for train_4k on
        every dense arch here). MoE experts keep the expert-parallel
        dimension (gathering full expert stacks would blow HBM)."""
        if strategy not in ("2d", "fsdp"):
            raise ValueError(strategy)
        self.cfg = cfg
        self.mesh = mesh
        self.tuned = tuned
        self.strategy = strategy
        self.sizes = axis_sizes(mesh)
        if strategy == "fsdp":
            self.dp = tuple(a for a in ("pod", "data", "model")
                            if a in self.sizes)
        else:
            self.dp = tuple(a for a in ("pod", "data") if a in self.sizes)
        self.fsdp = "data" if (cfg is not None and getattr(cfg, "fsdp_weights", False)
                               and "data" in self.sizes) else None
        model = self.sizes.get("model", 1)
        self.heads_ok = cfg is None or cfg.n_heads % model == 0
        self.kv_ok = cfg is None or cfg.n_kv_heads % model == 0
        dm = model * self.sizes.get("data", 1)
        self.experts_2d = (cfg is not None and cfg.n_experts
                           and cfg.n_experts % dm == 0)

    def dp_size(self):
        n = 1
        for a in self.dp:
            n *= self.sizes[a]
        return n

    def _fit(self, spec, shape):
        """Replace axes that don't divide their dim with None."""
        out = []
        for dim, ax in zip(shape, spec):
            if ax is None:
                out.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in axes:
                n *= self.sizes.get(a, 1)
            out.append(ax if dim % n == 0 else None)
        return P(*out)

    # ------------------------------------------------------------ params

    def _base_param_spec(self, names, shape):
        last = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        F = self.fsdp
        nd = len(shape)
        if parent in NORM_PARENTS or last in RWKV_SMALL or last == "gate_attn":
            return (None,) * nd
        if last == "emb":
            # vocab-sharded: GSPMD partitions the gather as masked
            # local-lookup + all-reduce (d-sharded tables break the
            # partitioner's gather path under grad).
            return (None, "model", None)
        if parent == "lm_head":
            return (None, "model") if last == "w" else ("model",)
        if parent in ("cond_proj",):
            return (None,) * nd
        if parent == "mlp" and nd == 3 and last in ("gate", "up"):
            if self.tuned and self.experts_2d:
                return (("data", "model"), None, None)
            return ("model", None, F)          # MoE experts
        if parent == "mlp" and nd == 3 and last == "down":
            if self.tuned and self.experts_2d:
                return (("data", "model"), None, None)
            return ("model", F, None)
        if last == "router":
            return (None, None)
        if last == "conv_w":
            return (None, "model")
        if last in ("conv_b", "dt_bias", "D"):
            return ("model",)
        if last == "A_log":
            return ("model", None)
        if parent == "x_proj":
            return ("model", None) if last == "w" else (None,)
        if parent == "dt_w":
            return (None, "model") if last == "w" else ("model",)
        if parent in COL_PARENTS:
            if self.tuned and parent in ("wk", "wv") and not self.kv_ok:
                return (F, None) if last == "w" else (None,)
            if self.tuned and parent == "wq" and not self.heads_ok:
                return (F, None) if last == "w" else (None,)
            return (F, "model") if last == "w" else ("model",)
        if parent in ROW_PARENTS:
            if self.tuned and parent == "wo" and not self.heads_ok:
                return (None, F) if last == "w" else (None,)
            return ("model", F) if last == "w" else (None,)
        if parent in ("wq_a", "wkv_a"):
            return (F, None) if last == "w" else (None,)
        if parent == "proj":                   # MTP projection
            return (F, None) if last == "w" else (None,)
        if last in ("wr", "wk", "wv", "wg") and nd == 2:   # rwkv matrices
            return (None, "model")
        if last == "wo" and nd == 2:
            return ("model", None)
        return (None,) * nd

    def param_spec(self, path, leaf):
        names = _path_names(path)
        shape = leaf.shape
        stacked = 1 if names and names[0] == "groups" else 0
        base = self._base_param_spec(names, shape[stacked:])
        if self.strategy == "fsdp":
            base = self._to_fsdp(names, base, shape[stacked:])
        spec = (None,) * stacked + tuple(base)
        return self._fit(spec, shape)

    def _to_fsdp(self, names, base, shape):
        """Rewrite a 2D spec for the pure-FSDP strategy: the former
        tensor-parallel ('model') placement is dropped and the largest
        dim is ZeRO-3-sharded over ('data','model'). MoE expert stacks
        keep the expert dim sharded (never gathered whole)."""
        last = names[-1]
        parent = names[-2] if len(names) > 1 else ""
        both = ("data", "model")
        if parent == "mlp" and len(shape) == 3 and last in ("gate", "up", "down"):
            if self.experts_2d:
                return (both, None, None)
            return ("model", "data" if shape[1] % self.sizes.get("data", 1) == 0
                    else None, None) if last != "down" else ("model", "data", None)
        if len(shape) < 2 or all(a is None for a in base):
            return tuple(None for _ in shape)
        # shard the largest dim over both axes
        big = max(range(len(shape)), key=lambda i: shape[i])
        return tuple(both if i == big else None for i in range(len(shape)))

    def param_pspecs(self, params):
        return jax.tree_util.tree_map_with_path(self.param_spec, params)

    # ------------------------------------------------------------ caches

    def cache_spec(self, path, leaf, *, long=False):
        names = _path_names(path)
        last = names[-1]
        shape = leaf.shape
        nd = len(shape)
        dp = self.dp
        # every cache leaf is stacked: (count, B, ...) except 'pos' (count, W)
        if last == "pos":
            return P(*(None,) * nd)
        if last in ("k", "v") and nd == 5:          # (count,B,W,KV,D) self-attn
            seq_ax = dp if long else None
            return self._fit((None, None if long else dp, seq_ax, "model", None), shape)
        if last in ("k", "v") and nd == 5:
            pass
        if last in ("ckv", "krope"):                # (count,B,W,r)
            seq_ax = dp if long else None
            return self._fit((None, None if long else dp, seq_ax, None), shape)
        if "cross" in names or "cond" in names:     # (count,B,Cs,KV,D)
            return self._fit((None, None if long else dp, None, "model", None), shape)
        if "ssm" in names and nd == 4 and shape[-1] != shape[-2]:
            # conv state (count,B,dc-1,di) or h (count,B,di,st)
            if shape[-2] > shape[-1]:
                return self._fit((None, None if long else dp, "model", None), shape)
            return self._fit((None, None if long else dp, None, "model"), shape)
        if last == "state" and nd == 5:             # rwkv (count,B,H,hd,hd)
            return self._fit((None, None if long else dp, "model", None, None), shape)
        if nd >= 2:
            return self._fit((None, None if long else dp) + (None,) * (nd - 2), shape)
        return P(*(None,) * nd)

    def cache_pspecs(self, cache, *, long=False):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: self.cache_spec(p, l, long=long), cache)

    # ------------------------------------------------------------- misc

    def batch_spec(self, leaf):
        return self._fit((self.dp,) + (None,) * (leaf.ndim - 1), leaf.shape)

    def batch_pspecs(self, batch):
        return jax.tree.map(self.batch_spec, batch)

    def opt_pspecs(self, params, opt_state):
        """Optimizer state mirrors param sharding (factored dims inherit)."""
        pspecs = self.param_pspecs(params)

        def match(path, leaf):
            names = _path_names(path)
            if names and names[-1] in ("step",):
                return P()
            # walk to the corresponding param spec by stripping m/v/vr/vc keys
            stripped = [n for n in names if n not in ("m", "v", "vr", "vc")]
            sub = pspecs
            for n in stripped:
                if isinstance(sub, dict) and n in sub:
                    sub = sub[n]
                elif isinstance(sub, (list, tuple)):
                    sub = sub[int(n)]
                else:
                    return P(*(None,) * leaf.ndim)
            if not isinstance(sub, P):
                return P(*(None,) * leaf.ndim)
            spec = tuple(sub)
            if names[-1] == "vr":       # param shape minus last dim
                spec = spec[:-1]
            elif names[-1] == "vc":     # param shape minus second-to-last
                spec = spec[:-2] + spec[-1:]
            spec = spec + (None,) * (leaf.ndim - len(spec))
            return self._fit(spec[:leaf.ndim], leaf.shape)

        return jax.tree_util.tree_map_with_path(match, opt_state)

    def shardings(self, pspecs):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), pspecs,
                            is_leaf=lambda x: isinstance(x, P))

    def constrain(self, x):
        """Activation constraint for (B, S, d) hiddens."""
        if x.ndim == 3:
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(self.mesh, P(self.dp, None, None)))
        return x


def abstract_params(cfg, init_fn):
    """Shape-only params via eval_shape (no allocation)."""
    return jax.eval_shape(lambda k: init_fn(cfg, k), jax.random.PRNGKey(0))
