"""TieredEMSServe: the tiered-placement construction of the unified
engine.

Glass<->edge split serving on simulated-clock tiers — live per-arrival
offload decisions through the heartbeat-quantized monitor, byte-
accounted in-order feature transport with an edge cache replica synced
by feature VERSION, and heartbeat-detected edge-crash failover to
on-glass with the <=1-step cache-staleness invariant asserted on every
re-fusion — all live in :class:`repro.serving.api.EMSServeEngine`
behind :class:`~repro.serving.api.PlacementPolicy`. This module is the
thin constructor shim preserving the historical surface; new code
should say::

    from repro.serving.api import build_engine
    eng = build_engine(models, params, "tiered",
                       profile=table, trace=trace, share_encoders=True)

and can compose streaming on top (``"stream+tiered"``): offloaded
arrivals then also emit an immediate on-glass provisional partial from
cached features while the edge computes the refreshed prediction —
the composition the sibling runtimes could never express.

The numerics always run through the real jitted ``SplitModel`` pieces
on this host — placement changes the clock, never the math, which is
what makes tiered outputs bit-comparable to the monolithic forward
(parity tier: tests/test_tiered_runtime.py, every placement incl.
post-crash).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.bucketing import Bucketer
from repro.core.offload import BandwidthTrace, ProfileTable
from repro.core.splitter import SplitModel
from repro.serving.api import (BatchPolicy, EMSServeEngine,  # noqa: F401
                               PlacementPolicy, SessionView, TieredRecord,
                               TierHost)

# historical name, now the canonical unified session type
TierSession = SessionView


class TieredEMSServe(EMSServeEngine):
    """Split-serving runtime over (glass, edge) simulated-clock tiers.

    ``profile`` is the one-time offline profiling result (seconds per
    submodule on this host, scaled to tiers via its factor table);
    ``trace`` drives both the heartbeat monitor (decisions) and the
    transport links (true wire bandwidth). ``force='glass'|'edge'`` pins
    placement for ablations; ``adaptive=False`` always offloads.

    ``tiers=("glass", "ph1", "edge64x")`` generalizes to N hosts (first
    entry local, per-host link traces via ``tier_traces``) and turns on
    contention-aware decisions and per-submodule tail placement by
    default; without it, the historical 2-tier contention-blind
    co-located behavior is preserved bit for bit.

    ``speculation`` (a :class:`~repro.core.offload.SpeculationPolicy`)
    arms speculative dual placement — deadline-pressured arrivals race
    glass against the best remote under the cancel-on-commit protocol;
    ``redispatch=True`` re-aims a flight lost to a tier crash at the
    next-best surviving remote instead of always re-running on glass.
    Both default off, preserving every historical timeline.
    """

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 profile: ProfileTable, trace: BandwidthTrace,
                 tiers=None, tier_traces=None,
                 glass_tier: str = "glass", edge_tier: str = "edge4c",
                 hb_period: float = 1.0, link_latency_s: float = 0.005,
                 adaptive: bool = True, force=None,
                 contention_aware: Optional[bool] = None,
                 tail_placement: Optional[bool] = None,
                 speculation=None, redispatch: bool = False,
                 share_encoders: bool = False,
                 bucketer: Optional[Bucketer] = None,
                 max_history: Optional[int] = 256,
                 tracer=None):
        super().__init__(
            models, params,
            batch=BatchPolicy(bucketer=bucketer),   # None: unbucketed, as ever
            stream=None,                            # legacy: no glass partials
            placement=PlacementPolicy(
                profile=profile, trace=trace, tiers=tiers,
                tier_traces=tier_traces, glass_tier=glass_tier,
                edge_tier=edge_tier, hb_period=hb_period,
                link_latency_s=link_latency_s, adaptive=adaptive,
                force=force, contention_aware=contention_aware,
                tail_placement=tail_placement, speculation=speculation,
                redispatch=redispatch),
            share_encoders=share_encoders,
            max_history=max_history, tracer=tracer)
