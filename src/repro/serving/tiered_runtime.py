"""TieredEMSServe: glass<->edge split-serving on simulated-clock tiers.

The paper's headline serving capability is that the *pieces* of a
modality-aware split model run on different hardware tiers — the smart
glasses themselves and an edge box (manpack) — with a live offloading
decision per submodule (``Δt + t^e < t^g``), feature transport over a
real link, and fault tolerance when the edge dies mid-incident. The
per-event ``core.engine.EMSServe`` only *scores* those decisions against
an offline rule; this runtime actually hosts the pieces:

  * **tier hosts** — ``glass`` and ``edge`` are :class:`TierHost`
    objects with their own busy-until simulated clocks; submodule
    compute times come from the one-time :class:`ProfileTable`
    (``core.offload.TIER_FACTORS``, paper Fig. 8/Table 2), so queueing
    and pipelining across tiers are modeled, not assumed away. The
    *numerics* always run through the real jitted ``SplitModel`` pieces
    on this host — placement changes the clock, never the math, which is
    what makes tiered outputs bit-comparable to the monolithic forward;
  * **live offload decisions** — every arrival consults the
    ``AdaptiveOffloadPolicy`` through the heartbeat-quantized
    ``HeartbeatMonitor``: decisions see the last heartbeat's bandwidth
    measurement while the transport pays the trace's true value;
  * **feature transport** — raw modality payloads go up, encoded
    features + head outputs (and the piggybacked feature cache, the
    paper's fault-tolerance mechanism) come back down through
    byte-accounting in-order :class:`~repro.serving.transport.TransportChannel`
    links; the edge keeps a cache replica so the uplink only re-ships
    features the edge doesn't already hold;
  * **edge-crash fault tolerance** — ``inject_edge_crash(t)`` kills the
    edge at simulated time ``t``. In-flight work is lost; the glasses
    detect the failure at the first missed heartbeat after the crash,
    fall back to on-glass execution, and resume from the versioned
    glass-side ``FeatureCache`` — whose ``max_staleness=1`` invariant is
    asserted live on every re-fusion (the edge returned the cache with
    every result, so the glasses are never more than one step behind).

``submit`` is per-arrival (the decision is per-event by construction);
``run_arrivals`` drives many concurrent sessions through the same
global arrival-order interleaving the streaming engine uses, so async
modality arrivals flow arrival -> decide tier -> encode there ->
transport -> cached re-fusion on glass.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.bucketing import Bucketer
from repro.core.episodes import Event, merge_arrivals
from repro.core.feature_cache import FeatureCache
from repro.core.offload import (AdaptiveOffloadPolicy, BandwidthTrace,
                                Decision, HeartbeatMonitor, ProfileTable)
from repro.core.splitter import SplitModel, select_model
from repro.serving.transport import TransportChannel, payload_nbytes


@dataclass
class TierHost:
    """One hardware tier with its own busy-until simulated clock."""
    name: str                   # display name ('glass' | 'edge')
    tier: str                   # key into ProfileTable.factors
    profile: ProfileTable
    free_at: float = 0.0
    busy_s: float = 0.0
    calls: int = 0

    def time(self, submodule: str) -> float:
        return self.profile.time(submodule, self.tier)

    def occupy(self, duration: float, t_start: float) -> Tuple[float, float]:
        """Book ``duration`` seconds of compute no earlier than
        ``t_start``; returns (start, done) on the simulated clock."""
        start = max(t_start, self.free_at)
        done = start + duration
        self.free_at = done
        self.busy_s += duration
        self.calls += 1
        return start, done


@dataclass
class TieredRecord:
    """Timeline of one arrival through the tiered runtime."""
    sid: str
    index: int
    modality: str
    model: Optional[str]
    tier: str                   # where the work actually ran
    kind: str                   # 'partial' | 'final'
    t_arrival: float
    t_start: float              # when the glasses picked the event up
    t_emit: float               # when the prediction reached the glasses
    uplink_s: float = 0.0       # payload + cache-sync transfer time
    downlink_s: float = 0.0     # feature + outputs return transfer time
    compute_s: float = 0.0
    fallback: bool = False      # edge crashed mid-flight; re-ran on glass
    detect_s: float = 0.0       # stall waiting on missed-heartbeat detection
    decision: Optional[Decision] = None
    outputs: Optional[dict] = None

    @property
    def latency_s(self) -> float:
        return self.t_emit - self.t_arrival


@dataclass
class TierSession:
    sid: str
    inputs: Dict[str, object] = field(default_factory=dict)
    input_step: Dict[str, int] = field(default_factory=dict)
    step: int = 0
    ready_at: float = 0.0       # per-session in-order processing
    records: List[TieredRecord] = field(default_factory=list)
    t_first_arrival: Optional[float] = None   # survives record trimming
    t_first_emit: Optional[float] = None
    t_final_emit: Optional[float] = None


class TieredEMSServe:
    """Split-serving runtime over (glass, edge) simulated-clock tiers.

    ``profile`` is the one-time offline profiling result (seconds per
    submodule on this host, scaled to tiers via its factor table);
    ``trace`` drives both the heartbeat monitor (decisions) and the
    transport links (true wire bandwidth). ``force='glass'|'edge'`` pins
    placement for ablations; ``adaptive=False`` always offloads.
    """

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 profile: ProfileTable, trace: BandwidthTrace,
                 glass_tier: str = "glass", edge_tier: str = "edge4c",
                 hb_period: float = 1.0, link_latency_s: float = 0.005,
                 adaptive: bool = True, force: Optional[str] = None,
                 share_encoders: bool = False,
                 bucketer: Optional[Bucketer] = None,
                 max_history: Optional[int] = 256):
        self.models = models
        self.params = params
        self.profile = profile
        self.monitor = HeartbeatMonitor(trace, period=hb_period)
        self.policy = AdaptiveOffloadPolicy(
            profile, self.monitor, glass_tier=glass_tier,
            edge_tier=edge_tier, adaptive=adaptive, force=force)
        self.glass = TierHost("glass", glass_tier, profile)
        self.edge = TierHost("edge", edge_tier, profile)
        self.uplink = TransportChannel(trace, latency_s=link_latency_s,
                                       name="glass->edge")
        self.downlink = TransportChannel(trace, latency_s=link_latency_s,
                                         name="edge->glass")
        self.share_encoders = share_encoders
        self.bucketer = bucketer
        self.cache = FeatureCache(max_staleness=1)   # glass-side replica
        # edge replica freshness: (cache key, modality) -> feature VERSION
        # the edge holds (versions only bump on real re-encodes; steps get
        # re-stamped by every touch, which would force spurious re-ships)
        self._edge_versions: Dict[Tuple[str, str], int] = {}
        self.sessions: Dict[str, TierSession] = {}
        self.full_set = frozenset(m for sm in models.values()
                                  for m in sm.modalities())
        self.records: List[TieredRecord] = []
        self.max_history = max_history
        # ---- fault injection / detection state
        self.crash_at: Optional[float] = None
        self.detect_at: Optional[float] = None
        self.edge_known_dead = False
        # ---- lifetime counters
        self.events_total = 0
        self.fallback_count = 0
        self.offloaded_count = 0
        self.on_glass_count = 0
        self._total_latency = 0.0

    # ------------------------------------------------------------ faults

    def inject_edge_crash(self, t: float):
        """The edge box dies at simulated time ``t``. The glasses learn
        of it at the first missed heartbeat strictly after ``t``."""
        self.crash_at = t
        period = self.monitor.period
        self.detect_at = (math.floor(t / period) + 1) * period

    def _mark_edge_dead(self):
        self.edge_known_dead = True
        self.policy.force = "glass"       # all future decisions: on-glass
        self._edge_versions.clear()       # the edge replica is gone

    def _edge_usable(self, now: float) -> bool:
        if self.edge_known_dead:
            return False
        if self.detect_at is not None and now >= self.detect_at:
            # a background heartbeat already went unanswered
            self._mark_edge_dead()
            return False
        return True

    # ------------------------------------------------------------ intake

    def session(self, sid: str) -> TierSession:
        st = self.sessions.get(sid)
        if st is None:
            st = self.sessions[sid] = TierSession(sid)
        return st

    def _cache_key(self, sid: str, model_name: str) -> str:
        return sid if self.share_encoders else f"{sid}:{model_name}"

    def _consumers(self, m: str):
        return [(n, sm) for n, sm in self.models.items()
                if m in sm.modalities()]

    def _payload_bytes(self, m: str, payload) -> int:
        """Raw sensor bytes for the uplink: the module's declared size
        (audio clip / camera frame, not the tokenized tensor) when
        available, else the actual array bytes."""
        for _n, sm in self._consumers(m):
            b = sm.module.payload_bytes.get(m)
            if b:
                return b
        return payload_nbytes(payload)

    def _enc_duration(self, m: str, n_runners: int, host: TierHost) -> float:
        """Simulated seconds the tier spends encoding modality ``m`` for
        ``n_runners`` consuming models: expensive text encoders run in
        parallel, cheap ones serially (paper Fig. 8-right — matching
        ``core.engine.EMSServe``)."""
        per = host.time(f"enc:{m}")
        return per if m == "text" else per * n_runners

    # ----------------------------------------------------- real numerics
    #
    # The numerics are split into run / commit phases so the edge fault
    # path can execute the real jitted calls (placement never changes
    # the math) yet leave the glass-side cache untouched when the edge
    # dies before its result makes it back.

    def _run_encoders(self, st: TierSession, m: str) -> Dict[str, object]:
        """Real jitted encoder run(s) for the arriving modality; returns
        ``{model_name: feature}`` WITHOUT touching the cache."""
        consumers = self._consumers(m)
        if not consumers:
            return {}
        runners = consumers[:1] if self.share_encoders else consumers
        enc_in = (self.bucketer.fit(m, st.inputs[m]) if self.bucketer
                  else st.inputs[m])
        return {name: sm.encoders[m](self.params[name], enc_in)
                for name, sm in runners}

    def _commit_features(self, st: TierSession, m: str, feats, tier: str):
        for name, feat in feats.items():
            self.cache.put(self._cache_key(st.sid, name), m, feat,
                           step=st.step, tier=tier)

    def _gather(self, st: TierSession, model_name: str, m: str, feats):
        """The selected model's input features — the arriving modality
        from the fresh (possibly uncommitted) ``feats``, everything else
        from the glass cache with the <=1-step staleness invariant
        asserted on every read. None while the subset is incomplete."""
        sm = self.models[model_name]
        key = self._cache_key(st.sid, model_name)
        fresh = (next(iter(feats.values()), None) if self.share_encoders
                 else feats.get(model_name))
        out = {}
        for mm in sm.modalities():
            if mm == m and fresh is not None:
                out[mm] = fresh
                continue
            e = self.cache.get(key, mm, input_step=st.input_step.get(mm))
            if e is None:
                return None
            out[mm] = e.feature
        return out

    def _touch_consumed(self, st: TierSession, model_name: str):
        """The result carries the cache back (paper fault tolerance):
        re-stamp every consumed entry at this step."""
        key = self._cache_key(st.sid, model_name)
        for mm in self.models[model_name].modalities():
            self.cache.touch(key, mm, st.step)

    # ------------------------------------------------------------- event

    def submit(self, sid: str, event: Event, payload, *,
               aggregate=None) -> TieredRecord:
        """Process one arriving datum end to end: decide tier, encode
        there, transport, re-fuse on glass, emit."""
        st = self.session(sid)
        st.step += 1
        m = event.modality
        old = st.inputs.get(m)
        st.inputs[m] = aggregate(old, payload) if aggregate else payload
        st.input_step[m] = st.step
        self.events_total += 1

        t_a = event.arrival_time
        if st.t_first_arrival is None:
            st.t_first_arrival = t_a
        now = max(t_a, st.ready_at)
        model_name = select_model(self.models, st.inputs)
        payload_b = self._payload_bytes(m, st.inputs[m])
        dec = self.policy.decide(f"enc:{m}", payload_b, now)

        if dec.tier == "edge" and self._edge_usable(now):
            rec = self._edge_event(st, event, model_name, payload_b,
                                   now, dec)
        else:
            rec = self._glass_event(st, event, model_name, now, dec)

        st.ready_at = rec.t_emit
        st.records.append(rec)
        self.records.append(rec)
        if self.max_history is not None:
            del st.records[:-self.max_history]
            del self.records[:-self.max_history]
        self._total_latency += rec.latency_s
        if rec.outputs is not None:
            if st.t_first_emit is None:
                st.t_first_emit = rec.t_emit
            if rec.kind == "final" and st.t_final_emit is None:
                st.t_final_emit = rec.t_emit
        return rec

    def _kind(self, model_name: Optional[str]) -> str:
        if model_name is None:
            return "partial"
        mods = frozenset(self.models[model_name].modalities())
        return "final" if mods == self.full_set else "partial"

    def _glass_event(self, st: TierSession, event: Event,
                     model_name: Optional[str], now: float, dec: Decision,
                     *, fallback: bool = False,
                     detect_s: float = 0.0) -> TieredRecord:
        m = event.modality
        feats = self._run_encoders(st, m)
        self._commit_features(st, m, feats, tier="glass")
        outputs = None
        if model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)
                self._touch_consumed(st, model_name)
        dur = (self._enc_duration(m, len(feats), self.glass)
               if feats else 0.0)
        if outputs is not None:
            dur += self.glass.time("tail")
        start, done = self.glass.occupy(dur, now)
        self.on_glass_count += 1
        if fallback:
            self.fallback_count += 1
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier="glass", kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=start, t_emit=done,
            compute_s=dur, fallback=fallback, detect_s=detect_s,
            decision=dec, outputs=outputs)

    def _edge_event(self, st: TierSession, event: Event,
                    model_name: Optional[str], payload_b: int,
                    now: float, dec: Decision) -> TieredRecord:
        m = event.modality
        # ---- uplink: raw payload + any features the edge replica lacks
        sync_b, synced = 0, []
        if model_name is not None:
            key = self._cache_key(st.sid, model_name)
            for mm in self.models[model_name].modalities():
                if mm == m:
                    continue
                e = self.cache.peek(key, mm)
                if e is not None and \
                        self._edge_versions.get((key, mm), -1) < e.version:
                    sync_b += payload_nbytes(e.feature)
                    synced.append(((key, mm), e.version))
        up = self.uplink.send(payload_b + sync_b, now)

        # ---- real numerics (uncommitted) + simulated edge compute
        feats = self._run_encoders(st, m)
        outputs = None
        if model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)
        dur = self._enc_duration(m, len(feats), self.edge) if feats else 0.0
        if outputs is not None:
            dur += self.edge.time("tail")
        _start, t_done = self.edge.occupy(dur, up.t_deliver)

        # ---- downlink payload: fresh feature(s) + head outputs + the
        # piggybacked cache re-stamp (an empty-feature result still
        # ships a small ack frame)
        down_b = sum(payload_nbytes(f) for f in feats.values())
        if outputs is not None:
            down_b += payload_nbytes(outputs)

        # ---- crash window: the edge must survive through the END of
        # its downlink transmission, not just its compute — a death
        # mid-transfer loses the result exactly like one mid-encode
        if self.crash_at is not None \
                and self.crash_at < self.downlink.eta(down_b, t_done):
            t_detect = max(now, self.detect_at)
            self._mark_edge_dead()
            return self._glass_event(st, event, model_name, t_detect, dec,
                                     fallback=True,
                                     detect_s=max(0.0, t_detect - now))

        # ---- success: commit to the glass cache, ship the bytes
        self._commit_features(st, m, feats, tier="edge")
        if outputs is not None:
            self._touch_consumed(st, model_name)
        down = self.downlink.send(down_b, t_done)
        # the edge replica now holds everything it consumed or produced
        for k, version in synced:
            self._edge_versions[k] = version
        for name in feats:
            key = self._cache_key(st.sid, name)
            e = self.cache.peek(key, m)
            if e is not None:
                self._edge_versions[(key, m)] = e.version
        self.offloaded_count += 1
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier="edge", kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=up.t_send,
            t_emit=down.t_deliver,
            uplink_s=up.t_deliver - up.t_send,
            downlink_s=down.t_deliver - t_done,
            compute_s=dur, decision=dec, outputs=outputs)

    # --------------------------------------------------------- episodes

    def run_arrivals(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None,
                     crash_at: Optional[float] = None):
        """Drive concurrent sessions through the global arrival-order
        interleaving (``core.episodes.merge_arrivals``), optionally
        killing the edge at simulated time ``crash_at``."""
        if crash_at is not None:
            self.inject_edge_crash(crash_at)
        for _t, sid, ev in merge_arrivals(episodes):
            self.submit(sid, ev, payload_fn(sid, ev), aggregate=aggregate)
        return self.records

    # -------------------------------------- event-loop driver interface

    def poll(self, now: Optional[float] = None):
        """Per-event runtime: nothing buffers, so polling is a no-op
        (present for ``serving.event_loop`` driver compatibility)."""
        return None

    def drain(self):
        return None

    def pending_count(self) -> int:
        return 0

    # ------------------------------------------------------------- stats

    def total_latency_s(self) -> float:
        """Cumulative serving latency (sum of per-arrival t_emit -
        t_arrival) — the Fig. 15 comparison metric."""
        return self._total_latency

    def makespan_s(self) -> float:
        return max((r.t_emit for r in self.records), default=0.0)

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def time_to_first_prediction(self, sid: str) -> Optional[float]:
        st = self.sessions[sid]
        if st.t_first_emit is None or st.t_first_arrival is None:
            return None
        return st.t_first_emit - st.t_first_arrival

    def transport_stats(self) -> dict:
        return {"uplink": self.uplink.stats(),
                "downlink": self.downlink.stats()}

    def placement_counts(self) -> dict:
        return {"edge": self.offloaded_count, "glass": self.on_glass_count,
                "fallbacks": self.fallback_count}
