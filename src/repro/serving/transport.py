"""Byte-accounting feature transport between hardware tiers.

The tiered runtime ships real serialized payloads between the glasses
and the edge box: raw modality data up, encoded features + head outputs
(and the piggybacked feature cache, per the paper's fault-tolerance
design) back down. A :class:`TransportChannel` models one direction of
that link on the simulated clock:

  * **payload sizing** — message sizes come from the actual device
    arrays being shipped (``payload_nbytes`` walks the pytree and sums
    ``size * itemsize``) plus a small fixed framing overhead;
  * **per-link latency** — every message pays a constant propagation /
    stack-traversal latency on top of its serialization time
    ``nbytes / bandwidth(t)``, with the bandwidth read from the same
    :class:`~repro.core.offload.BandwidthTrace` that drives the offload
    decisions (decisions see the *heartbeat-quantized* measurement; the
    wire sees the true instantaneous value — the gap between the two is
    exactly the staleness a real heartbeat monitor suffers);
  * **in-order delivery** — a TCP-like stream: a message never overtakes
    an earlier one, so a delivery time is clamped to be >= the previous
    message's (head-of-line blocking under a bandwidth dip is modeled,
    not wished away);
  * **cancellable flights** — every send is a *flight* with a unique id
    (fabric-wide when the channel belongs to a :class:`TierFabric`). A
    flight cancelled before its delivery instant NEVER delivers: the
    receiver never sees the bytes, and if the flight was the in-order
    frontier the wire frees at the cancel instant instead of the
    phantom full-delivery time. Speculative dual placement leans on
    this: the losing racer's in-flight transfer is cancelled at the
    winner's commit, so a stale result cannot arrive later and clobber
    a newer cache version (cancel-on-commit).

Lifetime byte/message counters make the transport cost auditable in
benchmark reports (``BENCH_tiered.json`` breaks them out per link).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

from repro.core.offload import BandwidthTrace
# THE byte-sizing rule lives in core (the benchmarks report with it);
# re-exported here because it is also the transport's charging rule.
from repro.core.splitter import payload_nbytes  # noqa: F401
from repro.obs import Metrics, Tracer


@dataclass
class Delivery:
    """Receipt for one message pushed through a channel."""
    t_send: float               # when the sender handed the bytes over
    t_deliver: float            # when the receiver has the full message
    nbytes: int
    transfer_s: float           # serialization time (nbytes / bandwidth)
    queued_s: float             # extra wait behind earlier in-flight messages
    flight: int = -1            # per-flight id (unique within its fabric)
    cancelled: bool = False     # cancel-on-commit: never delivers

    @property
    def delivered_at(self) -> Optional[float]:
        """Delivery instant, or None — a cancelled flight never
        delivers."""
        return None if self.cancelled else self.t_deliver


@dataclass
class TransportChannel:
    """One direction of a glass<->edge link on the simulated clock."""
    trace: BandwidthTrace
    latency_s: float = 0.005            # per-message propagation latency
    overhead_bytes: int = 64            # framing / header per message
    name: str = "link"
    # lifetime byte/message accounting lives on the (possibly shared)
    # metrics registry under "transport.<name>.*"; the historical
    # attributes survive as read-through properties below
    metrics: Optional[Metrics] = None
    tracer: Optional[Tracer] = None
    _last_deliver: float = field(default=0.0, repr=False)
    deliveries: List[Delivery] = field(default_factory=list, repr=False)
    max_history: Optional[int] = 256
    # flight-id allocator; a TierFabric injects ONE shared counter into
    # every channel it creates so ids are unique fabric-wide
    fids: Iterator[int] = field(default_factory=itertools.count,
                                repr=False)
    _flights: Dict[int, Delivery] = field(default_factory=dict,
                                          repr=False)

    def __post_init__(self):
        if self.metrics is None:
            self.metrics = Metrics()
        if self.tracer is None:
            self.tracer = Tracer.disabled

    # ---- legacy counter attributes (read-through to the registry)
    def _key(self, leaf: str) -> str:
        return f"transport.{self.name}.{leaf}"

    @property
    def bytes_sent(self) -> int:
        return int(self.metrics.get(self._key("bytes")))

    @property
    def msgs_sent(self) -> int:
        return int(self.metrics.get(self._key("msgs")))

    @property
    def busy_s(self) -> float:
        """Total serialization seconds."""
        return float(self.metrics.get(self._key("busy_s")))

    @property
    def cancelled_msgs(self) -> int:
        return int(self.metrics.get(self._key("cancelled_msgs")))

    @property
    def cancelled_bytes(self) -> int:
        return int(self.metrics.get(self._key("cancelled_bytes")))

    def eta(self, nbytes: int, t: float) -> float:
        """Delivery time a ``send(nbytes, t)`` WOULD produce, without
        mutating the channel — lets the fault path ask whether a sender
        would still be alive when its transmission completes."""
        transfer = (int(nbytes) + self.overhead_bytes) / self.trace.at(t)
        return max(t + self.latency_s + transfer, self._last_deliver)

    def send(self, nbytes: int, t: float) -> Delivery:
        """Ship ``nbytes`` at simulated time ``t``; returns the receipt.

        Transfer time uses the trace's true bandwidth at the send
        instant (piecewise-constant over the transfer — the traces the
        benchmarks sweep change on a ~1 s grid, coarser than any single
        message here). Delivery is in-order: never earlier than the
        previous message's delivery.
        """
        nbytes = int(nbytes) + self.overhead_bytes
        transfer = nbytes / self.trace.at(t)
        arrival = t + self.latency_s + transfer
        queued = max(0.0, self._last_deliver - arrival)
        d = Delivery(t_send=t, t_deliver=arrival + queued, nbytes=nbytes,
                     transfer_s=transfer, queued_s=queued,
                     flight=next(self.fids))
        self._last_deliver = d.t_deliver
        self.metrics.inc(self._key("bytes"), nbytes)
        self.metrics.inc(self._key("msgs"))
        self.metrics.inc(self._key("busy_s"), transfer)
        if self.tracer:
            self.tracer.span(
                "transport.flight", "transport", d.t_send, d.t_deliver,
                track=f"link:{self.name}", flight=d.flight,
                channel=self.name, nbytes=d.nbytes, t_send=d.t_send,
                t_deliver=d.t_deliver, queued_s=d.queued_s)
        self.deliveries.append(d)
        self._flights[d.flight] = d
        if self.max_history is not None:
            del self.deliveries[:-self.max_history]
            if len(self._flights) > 4 * self.max_history:
                # Only flights already settled by the current clock —
                # delivered (t_deliver <= t) or cancelled — may be
                # dropped from the cancel index. A long-queued flight
                # whose t_deliver is still in the future must stay
                # cancellable no matter how many sends pass it.
                keep = {x.flight for x in self.deliveries}
                self._flights = {f: x for f, x in self._flights.items()
                                 if f in keep
                                 or (not x.cancelled and x.t_deliver > t)}
        return d

    def cancel(self, flight: int, t: Optional[float] = None) -> bool:
        """Abort an in-flight delivery (cancel-on-commit). Returns True
        iff the flight was live and got cancelled; a flight already
        delivered by ``t`` is past the commit point and cannot be
        recalled (False). A cancelled flight never delivers. If the
        flight was the in-order frontier, the wire frees at the cancel
        instant instead of the phantom full-delivery time."""
        d = self._flights.get(flight)
        if d is None or d.cancelled:
            return False
        if t is not None and t >= d.t_deliver:
            return False                # already delivered — too late
        d.cancelled = True
        self.metrics.inc(self._key("cancelled_msgs"))
        self.metrics.inc(self._key("cancelled_bytes"), d.nbytes)
        if self.tracer:
            self.tracer.instant(
                "transport.cancel", "transport",
                t if t is not None else d.t_send,
                track=f"link:{self.name}", flight=d.flight,
                channel=self.name, nbytes=d.nbytes,
                t=t if t is not None else d.t_send)
        if self._last_deliver == d.t_deliver:
            prev = max((x.t_deliver for x in self.deliveries
                        if not x.cancelled), default=0.0)
            self._last_deliver = max(prev, t if t is not None
                                     else d.t_send)
        return True

    def completed(self) -> List[Delivery]:
        """Deliveries that actually reached the receiver (cancelled
        flights never deliver)."""
        return [d for d in self.deliveries if not d.cancelled]

    def stats(self) -> dict:
        return {"name": self.name, "msgs": self.msgs_sent,
                "bytes": self.bytes_sent, "busy_s": self.busy_s,
                "cancelled_msgs": self.cancelled_msgs,
                "cancelled_bytes": self.cancelled_bytes}


# ======================================================================
# N-tier link fabric
# ======================================================================

@dataclass
class MinTrace:
    """Bandwidth of a remote<->remote path: each remote tier owns one
    radio link to the incident-local network, so a transfer between two
    remotes traverses both links and the slower one bottlenecks.
    Duck-types the ``at(t)`` surface :class:`TransportChannel` needs."""
    a: object
    b: object

    def at(self, t: float) -> float:
        return min(self.a.at(t), self.b.at(t))


class TierFabric:
    """Directional transport channels between any pair of tiers.

    ``traces`` maps each remote host name to the :class:`BandwidthTrace`
    of ITS radio link; the local tier (the glasses) terminates every
    link it participates in, so a local<->remote channel runs at the
    remote's trace and a remote<->remote channel at the min of the two
    (:class:`MinTrace`). Channels are created on demand and cached, so
    per-link in-order delivery state and byte accounting live exactly
    once per (src, dst) direction.
    """

    def __init__(self, local: str, traces: dict, *,
                 latency_s: float = 0.005, overhead_bytes: int = 64,
                 metrics: Optional[Metrics] = None,
                 tracer: Optional[Tracer] = None):
        self.local = local
        self.traces = dict(traces)
        self.latency_s = latency_s
        self.overhead_bytes = overhead_bytes
        self.metrics = metrics if metrics is not None else Metrics()
        self.tracer = tracer if tracer is not None else Tracer.disabled
        self._channels = {}
        # ONE flight-id space across every channel: a flight id names
        # its transfer unambiguously fabric-wide (cancel-on-commit
        # passes ids around without caring which link carries them)
        self._fids = itertools.count()

    def trace(self, src: str, dst: str):
        remotes = [t for t in (src, dst) if t != self.local]
        if not remotes:
            raise ValueError("no wire between a tier and itself")
        if len(remotes) == 1:
            return self.traces[remotes[0]]
        return MinTrace(self.traces[remotes[0]], self.traces[remotes[1]])

    def channel(self, src: str, dst: str) -> TransportChannel:
        key = (src, dst)
        ch = self._channels.get(key)
        if ch is None:
            ch = self._channels[key] = TransportChannel(
                self.trace(src, dst), latency_s=self.latency_s,
                overhead_bytes=self.overhead_bytes, name=f"{src}->{dst}",
                fids=self._fids, metrics=self.metrics,
                tracer=self.tracer)
        return ch

    def cancel(self, flight: int, t: Optional[float] = None) -> bool:
        """Cancel a flight by its fabric-wide id, whichever link carries
        it."""
        return any(ch.cancel(flight, t) for ch in self._channels.values())

    def cancelled_msgs(self) -> int:
        return sum(ch.cancelled_msgs for ch in self._channels.values())

    def stats(self) -> dict:
        return {f"{s}->{d}": ch.stats()
                for (s, d), ch in sorted(self._channels.items())}
