"""BatchedEMSServe: multi-session, shape-bucketed, dispatch-async serving.

The per-event ``core.engine.EMSServe`` is faithful to the paper's
single-responder trace: one session, one XLA call per submodule, a
``block_until_ready`` host sync after every call, and a fresh compile
whenever the growing vitals stream changes shape. An edge box at a real
incident serves many responders at once (CognitiveEMS-style), so this
engine turns the same split models + feature cache into a throughput
path:

  * **cross-session coalescing** — events from all sessions accumulate
    between flushes; at flush, all pending encoder work for one
    (modality, bucketed shape) becomes ONE batched jitted call whose
    rows are then scattered back into each session's ``FeatureCache``
    entry (lazy row slices — no copy, no sync);
  * **shape bucketing** — every variable-length input is padded by the
    ``core.bucketing.Bucketer`` and the coalesced batch axis is padded
    to a power of two, so the set of compiled programs is bounded and
    the compile count plateaus after warmup even as vitals streams grow;
  * **dispatch-async** — inside a flush nothing blocks; XLA calls are
    dispatched back to back and the host syncs ONCE on the flush's final
    outputs. ``real_time`` latency is therefore only meaningful at flush
    boundaries, which is what ``FlushReport`` records.

The cache keys (``"{sid}:{model}"``), staleness invariants, and model
selection rule are shared with the per-event engine, so a single-session
BatchedEMSServe flushed once per event produces the same
recommendations (tested in tests/test_batch_serving.py).
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax

from repro.core.bucketing import Bucketer, next_pow2, stack_bucketed
from repro.core.episodes import Event
from repro.core.feature_cache import FeatureCache
from repro.core.splitter import SplitModel, select_model


@dataclass
class SessionState:
    sid: str
    inputs: Dict[str, object] = field(default_factory=dict)
    input_step: Dict[str, int] = field(default_factory=dict)
    step: int = 0
    dirty: set = field(default_factory=set)      # modalities changed since flush
    last_recommendation: Optional[dict] = None
    events_seen: int = 0


@dataclass
class FlushReport:
    n_events: int                  # events drained by this flush
    n_encoder_calls: int           # batched XLA encoder dispatches
    n_tail_calls: int              # batched tail dispatches
    wall_s: float                  # dispatch + single sync
    latencies: Dict[Tuple[str, int], float]      # (sid, event idx) -> seconds
    recommendations: Dict[str, dict]             # sid -> head outputs


class BatchedEMSServe:
    """Coalescing multi-session engine over the same ``SplitModel`` zoo.

    ``models``/``params`` are shared across sessions (one weight copy on
    the edge box). ``max_coalesce`` caps a single batched call's row
    count; bigger pending groups split into chunks of that size.
    """

    def __init__(self, models: Dict[str, SplitModel], params: Dict[str, dict],
                 *, bucketer: Optional[Bucketer] = None,
                 max_coalesce: int = 64, batch_bucket_min: int = 1):
        self.models = models
        self.params = params
        if bucketer is None:
            # derive hard caps from the models (e.g. the text positional
            # table) so the default never pads past what they accept
            limits: Dict[str, int] = {}
            for sm in models.values():
                for m, n in sm.module.max_lengths.items():
                    limits[m] = min(limits.get(m, n), n)
            bucketer = Bucketer(max_buckets=limits)
        self.bucketer = bucketer
        self.max_coalesce = max_coalesce
        # floor for the coalesced batch axis: padding every group to at
        # least this many rows trades wasted rows for a single static
        # batch shape (set to the expected session count for serving)
        self.batch_bucket_min = batch_bucket_min
        self.cache = FeatureCache(max_staleness=1)
        self.sessions: Dict[str, SessionState] = {}
        self._pending: List[Tuple[str, int, float]] = []  # (sid, idx, t_submit)
        self.flushes: List[FlushReport] = []
        self.events_total = 0

    # ------------------------------------------------------------ intake

    def session(self, sid: str) -> SessionState:
        st = self.sessions.get(sid)
        if st is None:
            st = self.sessions[sid] = SessionState(sid)
        return st

    def submit(self, sid: str, event: Event, payload, *, aggregate=None):
        """Record one arriving datum; no compute happens until flush().
        ``aggregate(old, new) -> input`` merges into the modality's
        aggregated input (default: replace)."""
        st = self.session(sid)
        st.step += 1
        m = event.modality
        old = st.inputs.get(m)
        st.inputs[m] = aggregate(old, payload) if aggregate else payload
        st.input_step[m] = st.step
        st.dirty.add(m)
        st.events_seen += 1
        self.events_total += 1
        self._pending.append((sid, event.index, time.perf_counter()))

    # ------------------------------------------------------------- flush

    def _bucket_rows(self, n: int) -> int:
        return max(self.batch_bucket_min, next_pow2(n))

    def _encode_groups(self):
        """Group dirty (session, modality) work by identical post-bucket
        shape so each group is one stacked encoder call per consumer."""
        groups = defaultdict(list)       # (modality, shape_key) -> [(sid, payload)]
        for st in self.sessions.values():
            for m in sorted(st.dirty):
                p = self.bucketer.fit(m, st.inputs[m])
                shape = (tuple(p["x"].shape) if isinstance(p, dict)
                         else tuple(p.shape))
                groups[(m, shape)].append((st.sid, p))
        return groups

    def flush(self) -> FlushReport:
        """Run all pending work: one batched encoder call per
        (modality, bucket[, chunk]) per consuming model, then one batched
        tail call per selected model, then a single host sync."""
        t0 = time.perf_counter()
        n_enc = n_tail = 0
        sync_targets = []

        # ---- batched encode + scatter rows into the feature cache
        for (m, _shape), items in self._encode_groups().items():
            consumers = [(n, sm) for n, sm in self.models.items()
                         if m in sm.modalities()]
            if not consumers:
                continue
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = stack_bucketed([p for _, p in chunk],
                                         self._bucket_rows(len(chunk)))
                for name, sm in consumers:
                    feats = sm.encoders[m](self.params[name], stacked)
                    n_enc += 1
                    sync_targets.append(feats)
                    for i, sid in enumerate(sids):
                        st = self.sessions[sid]
                        self.cache.put(f"{sid}:{name}", m, feats[i:i + 1],
                                       step=st.step, tier="glass")

        # ---- batched tails, grouped by selected model
        tail_groups = defaultdict(list)  # model name -> [(sid, feats)]
        for st in self.sessions.values():
            if not st.dirty:
                continue
            st.dirty.clear()
            name = select_model(self.models, st.inputs)
            if name is None:
                continue
            sm = self.models[name]
            feats = self.cache.features(f"{st.sid}:{name}", sm.modalities(),
                                        input_steps=st.input_step)
            if feats is not None:
                tail_groups[name].append((st.sid, feats))

        recommendations = {}
        for name, items in tail_groups.items():
            sm = self.models[name]
            mods = sm.modalities()
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = {mm: stack_bucketed([f[mm] for _, f in chunk],
                                              self._bucket_rows(len(chunk)))
                           for mm in mods}
                outs = sm.tail(self.params[name], stacked)
                n_tail += 1
                sync_targets.append(outs)
                for i, sid in enumerate(sids):
                    st = self.sessions[sid]
                    rec = jax.tree.map(lambda a: a[i:i + 1], outs)
                    recommendations[sid] = rec
                    st.last_recommendation = rec
                    for mm in mods:
                        self.cache.touch(f"{sid}:{name}", mm, st.step)

        # ---- the ONE host sync of this flush
        jax.block_until_ready(sync_targets)
        t1 = time.perf_counter()

        latencies = {(sid, idx): t1 - ts for sid, idx, ts in self._pending}
        report = FlushReport(
            n_events=len(self._pending), n_encoder_calls=n_enc,
            n_tail_calls=n_tail, wall_s=t1 - t0, latencies=latencies,
            recommendations=recommendations)
        self._pending.clear()
        self.flushes.append(report)
        return report

    # ------------------------------------------------------------- stats

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def event_latencies(self) -> List[float]:
        return [lat for f in self.flushes for lat in f.latencies.values()]

    def total_wall_s(self) -> float:
        return sum(f.wall_s for f in self.flushes)

    # --------------------------------------------------------- episodes

    def run_episodes(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, events_per_flush: int = 1):
        """Drive concurrent sessions tick by tick: at tick t every session
        submits its t-th event; flush every ``events_per_flush`` ticks.
        ``payload_fn(sid, event) -> payload``."""
        horizon = max((len(ev) for ev in episodes.values()), default=0)
        for t in range(horizon):
            for sid, evs in episodes.items():
                if t < len(evs):
                    self.submit(sid, evs[t], payload_fn(sid, evs[t]),
                                aggregate=aggregate)
            if (t + 1) % events_per_flush == 0:
                self.flush()
        if self._pending:
            self.flush()
        return self.flushes
