"""BatchedEMSServe: the batch-only construction of the unified engine.

Everything this runtime used to implement — cross-session coalescing
into one batched XLA call per (modality, bucketed shape) per consumer,
power-of-two batch rows, dispatch-async flushes with ONE host sync —
now lives in :class:`repro.serving.api.EMSServeEngine` behind
:class:`~repro.serving.api.BatchPolicy`. This module is the thin
constructor shim that preserves the historical surface
(``submit``/``flush``/``run_episodes``, ``FlushReport.recommendations``,
per-model cache keys, unbounded flush history) for existing callers and
the parity tier; new code should say::

    from repro.serving.api import build_engine
    eng = build_engine(models, params, "batch")

The cache keys (``"{sid}:{model}"``), staleness invariants, and model
selection rule are shared with the per-event ``core.engine.EMSServe``,
so a single-session BatchedEMSServe flushed once per event produces the
same recommendations (tested in tests/test_batch_serving.py).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.bucketing import Bucketer
from repro.core.splitter import SplitModel
from repro.serving.api import (_AUTO, BatchPolicy,  # noqa: F401
                               EMSServeEngine, FlushReport, SessionView)

# historical names, now one canonical session type
SessionState = SessionView


class BatchedEMSServe(EMSServeEngine):
    """Coalescing multi-session engine over the same ``SplitModel`` zoo.

    ``models``/``params`` are shared across sessions (one weight copy on
    the edge box). ``max_coalesce`` caps a single batched call's row
    count; bigger pending groups split into chunks of that size.
    Flushing is entirely caller-driven (``deadline_s=None``): ``submit``
    never computes, ``flush`` drains everything pending.
    """

    def __init__(self, models: Dict[str, SplitModel], params: Dict[str, dict],
                 *, bucketer: Optional[Bucketer] = None,
                 max_coalesce: int = 64, batch_bucket_min: int = 1):
        super().__init__(
            models, params,
            batch=BatchPolicy(
                bucketer=bucketer if bucketer is not None else _AUTO,
                max_coalesce=max_coalesce,
                batch_bucket_min=batch_bucket_min),
            stream=None, placement=None,
            share_encoders=False,       # per-model cache keys, like EMSServe
            max_history=None)           # benchmarks sum over all flushes
