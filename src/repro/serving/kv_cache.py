"""Serving cache policy: per-arch cache length / sliding-window decisions.

`long_500k` (S=524,288 decode) policy, per DESIGN.md:
  * SSM / RWKV layers: constant-size state — nothing to bound.
  * MLA (deepseek): full latent cache (compressed, ~9x smaller than GQA
    KV), sequence-sharded on the data axis.
  * hybrid (jamba): its 4 attention layers keep full KV (cheap enough),
    sequence-sharded.
  * plain GQA layers of dense/audio/vlm/gqa-MoE archs: sliding-window
    ring buffer of ``cfg.long_context_window`` — the sub-quadratic
    variant required for long-context decode.
"""
from __future__ import annotations

from repro.configs.base import InputShape, ModelConfig

FULL_ATTN_LONG = {"hybrid"}          # arch types that keep full KV at 500k


def has_mixer(cfg: ModelConfig, mixer: str) -> bool:
    return any(s.mixer == mixer for specs, _ in cfg.groups for s in specs)


def uses_window(cfg: ModelConfig, shape: InputShape) -> bool:
    """Do plain-attn layers switch to the sliding window for this shape?"""
    if shape.name != "long_500k":
        return False
    if not has_mixer(cfg, "attn"):
        return False
    return cfg.arch_type not in FULL_ATTN_LONG


def cache_plan(cfg: ModelConfig, shape: InputShape):
    """Returns (cache_len, window_attn) for decode at this shape.

    cache_len is the ring-buffer length for attention-style caches;
    window_attn is the mask window applied to plain-attn layers
    (0 = full causal).
    """
    if uses_window(cfg, shape):
        w = cfg.long_context_window
        return w, w
    return shape.seq_len, 0
