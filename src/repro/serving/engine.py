"""LLM serving engine: batched prefill + decode with prefix caching.

This is the assigned-architecture analogue of EMSServe's per-modality
feature cache (DESIGN.md §4): a request whose prompt extends an already-
served prefix (system prompt, cached vision conditioning, an earlier
turn) reuses the stored decode cache instead of re-encoding the prefix —
the same redundant-computation elimination, applied to autoregressive
state. Works for every cache family in the zoo (KV ring buffers, MLA
latents, SSM/RWKV states).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


@dataclass
class Request:
    rid: str
    prompt: np.ndarray                  # (S,) or (S, ncb) int32
    max_new_tokens: int = 16
    cond: Optional[np.ndarray] = None   # modality-frontend embeddings
    eos_id: Optional[int] = None


@dataclass
class Result:
    rid: str
    tokens: np.ndarray
    prefix_hit: bool
    prefill_tokens: int                 # tokens actually encoded


def _h(arr: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(arr).tobytes()).hexdigest()[:16]


class LLMServer:
    """Static-batch greedy server for one architecture."""

    def __init__(self, cfg, params, *, batch_size: int = 1,
                 cache_len: int = 256, window: int = 0,
                 enable_prefix_cache: bool = True):
        self.cfg = cfg
        self.params = params
        self.B = batch_size
        self.cache_len = cache_len
        self.window = window
        self.enable_prefix_cache = enable_prefix_cache
        self.prefix_cache: Dict[Tuple[str, int], Tuple[dict, int]] = {}
        self.stats = {"prefix_hits": 0, "prefix_misses": 0,
                      "prefill_tokens": 0, "decode_steps": 0}

        self._prefill = jax.jit(partial(
            T.prefill, cfg=cfg, cache_len=cache_len, window_attn=window),
            static_argnames=())
        self._decode = jax.jit(partial(
            T.decode_step, cfg=cfg, window_attn=window))

    # -------------------------------------------------------------- util

    def _greedy(self, logits):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (B,1[,ncb])
        return tok

    def _lookup_prefix(self, prompt: np.ndarray):
        """Longest stored prefix of ``prompt`` (length quantized by whole
        prompts we've served)."""
        if not self.enable_prefix_cache:
            return None
        for plen in range(len(prompt), 0, -1):
            key = (_h(prompt[:plen]), plen)
            if key in self.prefix_cache:
                return plen, *self.prefix_cache[key]
        return None

    # ---------------------------------------------------------- serving

    def serve_one(self, req: Request) -> Result:
        """Single-request path (B=1) with prefix reuse."""
        cfg = self.cfg
        prompt = np.asarray(req.prompt)
        S = len(prompt)
        hit = self._lookup_prefix(prompt)
        batchify = lambda a: jnp.asarray(a)[None]
        cond = batchify(req.cond) if req.cond is not None else None

        if hit is not None and hit[0] >= 1:
            plen, cache, _ = hit
            self.stats["prefix_hits"] += 1
            # feed remaining prompt tokens through decode steps
            t = plen
            logits = None
            for i in range(plen, S):
                tok = batchify(prompt[i:i + 1]) if prompt.ndim == 1 else \
                    batchify(prompt[i:i + 1])
                logits, cache = self._decode(self.params, tokens=tok,
                                             cache=cache, t=jnp.int32(i))
                t = i + 1
            if logits is None:   # prompt identical to cached prefix
                # re-decode last prompt token to get logits (cheap)
                i = S - 1
                tok = batchify(prompt[i:i + 1])
                logits, cache = self._decode(self.params, tokens=tok,
                                             cache=cache, t=jnp.int32(i))
            encoded = S - plen
        else:
            self.stats["prefix_misses"] += 1
            logits, cache = self._prefill(self.params, tokens=batchify(prompt),
                                          cond=cond)
            encoded = S
        self.stats["prefill_tokens"] += encoded

        if self.enable_prefix_cache:
            self.prefix_cache[(_h(prompt), S)] = (cache, S)

        out = []
        tok = self._greedy(logits)
        out.append(np.asarray(tok)[0, 0])
        for step in range(1, req.max_new_tokens):
            t = S + step - 1
            logits, cache = self._decode(self.params, tokens=tok,
                                         cache=cache, t=jnp.int32(t))
            tok = self._greedy(logits)
            self.stats["decode_steps"] += 1
            val = np.asarray(tok)[0, 0]
            out.append(val)
            if req.eos_id is not None and np.all(val == req.eos_id):
                break
        return Result(rid=req.rid, tokens=np.stack(out), prefix_hit=hit is not None,
                      prefill_tokens=encoded)

    def serve(self, requests: List[Request]) -> List[Result]:
        return [self.serve_one(r) for r in requests]
