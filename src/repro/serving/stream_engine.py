"""StreamingEMSServe: progressive predictions over asynchronously
arriving modalities, across many concurrent sessions.

The paper's field reality (§EMSServe) is that text, vitals, and scene
features reach the glasses at different times — yet the EMT needs a
best-effort recommendation *immediately*, refined as modalities land.
The per-event ``core.engine.EMSServe`` serves one session synchronously
and ``serving.batch_engine.BatchedEMSServe`` flushes complete batches;
neither upgrades a partial-modality prediction in place. This runtime
does:

  * **out-of-order intake** — per-modality arrivals from any session,
    in any order; each session tracks which modalities it has observed;
  * **progressive predictions** — every flush emits, per touched
    session, the prediction of the best model for its *observed subset*
    (``core.splitter.select_model``), tagged ``partial`` until the
    subset covers every modality any model consumes, then ``final``;
  * **encoders never re-run** — a modality is encoded only when its
    aggregated input changed since the last flush; re-fusion after a
    later arrival reads the other modalities straight from the
    ``core.feature_cache.FeatureCache`` (with ``share_encoders=True``,
    for zoos built by ``core.modular.emsnet_zoo`` whose subset models
    share one parameter pytree, a feature is also encoded once *total*,
    not once per consuming model);
  * **deadline-driven coalesced flushes** — arrivals buffer until a
    deadline expires (or ``deadline_s=0``: every submit flushes), then
    all pending encoder work for one (modality, bucketed shape) becomes
    ONE batched XLA call through the same shape-bucketed machinery as
    the batch engine (``core.bucketing``), with a single host sync per
    flush.

Cache keys are session-level (``sid``) under ``share_encoders=True`` and
``"{sid}:{model}"`` otherwise — the latter matching the per-event and
batched engines bit for bit.
"""
from __future__ import annotations

import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.core.bucketing import Bucketer, next_pow2, stack_bucketed
from repro.core.episodes import Event, merge_arrivals
from repro.core.feature_cache import FeatureCache
from repro.core.splitter import SplitModel, select_model


@dataclass
class Prediction:
    """One progressive prediction emitted for a session by a flush."""
    sid: str
    step: int                       # session step it reflects
    model: str                      # selected model name
    modalities: Tuple[str, ...]     # fused subset, canonical order
    kind: str                       # "partial" | "final"
    outputs: dict                   # head outputs (batch row for sid)
    flush_id: int
    t_emit: float                   # time_fn() after the flush's sync


@dataclass
class StreamSession:
    sid: str
    inputs: Dict[str, object] = field(default_factory=dict)
    input_step: Dict[str, int] = field(default_factory=dict)
    step: int = 0
    dirty: set = field(default_factory=set)   # modalities changed since flush
    events_seen: int = 0
    t_first_submit: Optional[float] = None
    t_first_prediction: Optional[float] = None
    t_final_prediction: Optional[float] = None
    t_last_activity: Optional[float] = None   # last submit or emission
    finalized: bool = False                   # has emitted a final prediction
    predictions: List[Prediction] = field(default_factory=list)


@dataclass
class StreamFlushReport:
    flush_id: int
    n_events: int                  # arrivals drained by this flush
    n_encoder_calls: int           # batched encoder XLA dispatches
    n_tail_calls: int              # batched tail XLA dispatches
    wall_s: float                  # dispatch + the single sync
    predictions: List[Prediction]
    latencies: Dict[Tuple[str, int], float]   # (sid, event idx) -> seconds


class StreamingEMSServe:
    """Event-driven multi-session runtime with progressive re-fusion.

    ``deadline_s`` controls flush cadence: ``0`` flushes on every submit
    (minimum time-to-first-prediction), ``> 0`` buffers arrivals until
    the oldest pending one is that many wall-seconds old (checked on
    every submit and via ``poll()``), ``None`` leaves flushing entirely
    to the caller (useful when the caller batches by simulated time).
    ``time_fn`` is injectable so tests can drive a fake clock.

    Cross-incident eviction: an edge box at one incident after another
    accumulates sessions (and their cached device features) forever
    unless finished incidents leave. With ``idle_timeout_s`` set, a
    session with no pending work that has been inactive that long is
    evicted (its ``FeatureCache`` entries dropped with it); with
    ``max_sessions`` set, the table is further trimmed LRU —
    finalized sessions first — down to the cap. ``evicted_count``
    counts lifetime evictions; eviction runs after every flush and on
    ``poll()``. An evicted session that speaks again simply starts
    fresh (a new incident for the same responder id).

    The runtime is meant to run indefinitely, so per-flush reports and
    per-session predictions (which hold device arrays) are retained
    only up to ``max_history`` each; lifetime totals live in running
    counters (``events_total``, ``flushes_total``,
    ``encoder_calls_total()``, ``tail_calls_total()``). Pass
    ``max_history=None`` to keep everything (tests/benchmarks).
    """

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 bucketer: Optional[Bucketer] = None,
                 deadline_s: Optional[float] = 0.0,
                 max_coalesce: int = 64, batch_bucket_min: int = 1,
                 share_encoders: bool = False,
                 max_history: Optional[int] = 256,
                 idle_timeout_s: Optional[float] = None,
                 max_sessions: Optional[int] = None,
                 time_fn: Callable[[], float] = time.perf_counter):
        self.models = models
        self.params = params
        if bucketer is None:
            limits: Dict[str, int] = {}
            for sm in models.values():
                for m, n in sm.module.max_lengths.items():
                    limits[m] = min(limits.get(m, n), n)
            bucketer = Bucketer(max_buckets=limits)
        self.bucketer = bucketer
        self.deadline_s = deadline_s
        self.max_coalesce = max_coalesce
        self.batch_bucket_min = batch_bucket_min
        self.share_encoders = share_encoders
        self.time_fn = time_fn
        self.cache = FeatureCache(max_staleness=1)
        self.sessions: Dict[str, StreamSession] = {}
        # every modality ANY model consumes: a prediction fusing all of
        # them cannot be refined further -> tagged "final"
        self.full_set = frozenset(m for sm in models.values()
                                  for m in sm.modalities())
        self.max_history = max_history
        self.idle_timeout_s = idle_timeout_s
        self.max_sessions = max_sessions
        self.evicted_count = 0
        self._pending: List[Tuple[str, int, float]] = []  # (sid, idx, t_submit)
        self.flushes: List[StreamFlushReport] = []        # bounded window
        self.events_total = 0
        self.flushes_total = 0
        self._enc_calls_total = 0
        self._tail_calls_total = 0

    # ------------------------------------------------------------ intake

    def session(self, sid: str) -> StreamSession:
        st = self.sessions.get(sid)
        if st is None:
            st = self.sessions[sid] = StreamSession(sid)
        return st

    def submit(self, sid: str, event: Event, payload, *,
               aggregate=None) -> Optional[StreamFlushReport]:
        """Record one arriving datum; flush if the deadline policy says
        so (returns the flush report when one ran, else None)."""
        now = self.time_fn()
        st = self.session(sid)
        st.step += 1
        m = event.modality
        old = st.inputs.get(m)
        st.inputs[m] = aggregate(old, payload) if aggregate else payload
        st.input_step[m] = st.step
        st.dirty.add(m)
        st.events_seen += 1
        st.t_last_activity = now
        if st.t_first_submit is None:
            st.t_first_submit = now
        self.events_total += 1
        self._pending.append((sid, event.index, now))
        if self.deadline_s is None:
            return None
        if self.deadline_s <= 0.0:
            return self.flush()
        if now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        return None

    def poll(self, now: Optional[float] = None) -> Optional[StreamFlushReport]:
        """Flush if the oldest pending arrival has exceeded the deadline;
        also the idle hook where session eviction runs."""
        now = self.time_fn() if now is None else now
        if self._pending and self.deadline_s is not None \
                and now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        self.evict_sessions(now)
        return None

    def drain(self) -> Optional[StreamFlushReport]:
        """Flush whatever is pending, deadline or not."""
        return self.flush() if self._pending else None

    def pending_count(self) -> int:
        """Arrivals buffered but not yet flushed (the event-loop driver
        pumps poll() until this reaches zero)."""
        return len(self._pending)

    # ------------------------------------------------------------- flush

    def _cache_key(self, sid: str, model_name: str) -> str:
        return sid if self.share_encoders else f"{sid}:{model_name}"

    def _bucket_rows(self, n: int) -> int:
        return max(self.batch_bucket_min, next_pow2(n))

    def _consumers(self, m: str):
        return [(n, sm) for n, sm in self.models.items()
                if m in sm.modalities()]

    def _encode_groups(self, sids):
        """Dirty (session, modality) work grouped by identical
        post-bucket shape: each group is one stacked encoder call."""
        groups = defaultdict(list)     # (modality, shape) -> [(sid, payload)]
        for sid in sids:
            st = self.sessions[sid]
            for m in sorted(st.dirty):
                p = self.bucketer.fit(m, st.inputs[m])
                shape = (tuple(p["x"].shape) if isinstance(p, dict)
                         else tuple(p.shape))
                groups[(m, shape)].append((st.sid, p))
        return groups

    def flush(self) -> StreamFlushReport:
        """Encode everything dirty (batched per (modality, bucket)),
        re-fuse every touched session from cache, emit progressive
        predictions, sync the host ONCE."""
        t0 = self.time_fn()
        n_enc = n_tail = 0
        sync_targets = []
        # every dirty marking comes with a _pending entry, so only the
        # pending sessions can have work — never scan the whole (ever-
        # growing) session table on the latency-critical path
        touched = sorted({sid for sid, _, _ in self._pending})

        # ---- batched encode + scatter rows into the feature cache.
        # share_encoders: subset zoos share one parameter pytree, so one
        # encoder call serves every consumer; otherwise one per model
        # (matching BatchedEMSServe).
        for (m, _shape), items in self._encode_groups(touched).items():
            consumers = self._consumers(m)
            if not consumers:
                continue
            runners = consumers[:1] if self.share_encoders else consumers
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = stack_bucketed([p for _, p in chunk],
                                         self._bucket_rows(len(chunk)))
                for name, sm in runners:
                    feats = sm.encoders[m](self.params[name], stacked)
                    n_enc += 1
                    sync_targets.append(feats)
                    for i, sid in enumerate(sids):
                        st = self.sessions[sid]
                        self.cache.put(self._cache_key(sid, name), m,
                                       feats[i:i + 1], step=st.step,
                                       tier="glass")

        # ---- progressive re-fusion: batched tails per selected model
        tail_groups = defaultdict(list)    # model name -> [(sid, feats)]
        for sid in touched:
            st = self.sessions[sid]
            if not st.dirty:
                continue
            st.dirty.clear()
            name = select_model(self.models, st.inputs)
            if name is None:
                continue
            sm = self.models[name]
            feats = self.cache.features(self._cache_key(st.sid, name),
                                        sm.modalities(),
                                        input_steps=st.input_step)
            if feats is not None:
                tail_groups[name].append((st.sid, feats))

        emitted = []      # (sid, name, modalities, outputs, step)
        for name, items in tail_groups.items():
            sm = self.models[name]
            mods = sm.modalities()
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = {mm: stack_bucketed([f[mm] for _, f in chunk],
                                              self._bucket_rows(len(chunk)))
                           for mm in mods}
                outs = sm.tail(self.params[name], stacked)
                n_tail += 1
                sync_targets.append(outs)
                for i, sid in enumerate(sids):
                    st = self.sessions[sid]
                    row = jax.tree.map(lambda a: a[i:i + 1], outs)
                    emitted.append((sid, name, tuple(mods), row, st.step))
                    for mm in mods:
                        self.cache.touch(self._cache_key(sid, name), mm,
                                         st.step)

        # ---- the ONE host sync of this flush
        jax.block_until_ready(sync_targets)
        t1 = self.time_fn()

        flush_id = self.flushes_total
        predictions = []
        for sid, name, mods, row, step in emitted:
            kind = "final" if frozenset(mods) == self.full_set else "partial"
            pred = Prediction(sid=sid, step=step, model=name,
                              modalities=mods, kind=kind, outputs=row,
                              flush_id=flush_id, t_emit=t1)
            st = self.sessions[sid]
            st.predictions.append(pred)
            if self.max_history is not None:
                del st.predictions[:-self.max_history]
            predictions.append(pred)
            st.t_last_activity = t1
            if kind == "final":
                st.finalized = True
                if st.t_final_prediction is None:
                    st.t_final_prediction = t1
            if st.t_first_prediction is None:
                st.t_first_prediction = t1

        latencies = {(sid, idx): t1 - ts for sid, idx, ts in self._pending}
        report = StreamFlushReport(
            flush_id=flush_id, n_events=len(self._pending),
            n_encoder_calls=n_enc, n_tail_calls=n_tail, wall_s=t1 - t0,
            predictions=predictions, latencies=latencies)
        self._pending.clear()
        self.flushes.append(report)
        if self.max_history is not None:
            del self.flushes[:-self.max_history]
        self.flushes_total += 1
        self._enc_calls_total += n_enc
        self._tail_calls_total += n_tail
        self.evict_sessions(t1)
        return report

    # ---------------------------------------------------------- eviction

    def _evict(self, sid: str):
        for key in ([sid] if self.share_encoders
                    else [f"{sid}:{n}" for n in self.models]):
            self.cache.drop_session(key)
        del self.sessions[sid]
        self.evicted_count += 1

    def evict_sessions(self, now: Optional[float] = None) -> int:
        """Cross-incident eviction sweep; returns how many sessions
        left. A session is evictable only when it has no pending
        arrivals and no un-flushed dirty modalities — eviction never
        drops work. Idle timeout first, then LRU down to
        ``max_sessions``: least-recently-active leaves first, so a
        finalized incident that is still streaming updates outlives an
        abandoned partial one (finalized only breaks activity ties)."""
        if self.idle_timeout_s is None and self.max_sessions is None:
            return 0
        now = self.time_fn() if now is None else now
        pending_sids = {sid for sid, _, _ in self._pending}
        evictable = [st for sid, st in self.sessions.items()
                     if sid not in pending_sids and not st.dirty]
        n0 = self.evicted_count
        if self.idle_timeout_s is not None:
            for st in list(evictable):
                last = (st.t_last_activity if st.t_last_activity is not None
                        else st.t_first_submit)
                if last is not None and now - last >= self.idle_timeout_s:
                    self._evict(st.sid)
                    evictable.remove(st)
        if self.max_sessions is not None \
                and len(self.sessions) > self.max_sessions:
            evictable.sort(key=lambda st: (st.t_last_activity or 0.0,
                                           not st.finalized))
            excess = len(self.sessions) - self.max_sessions
            for st in evictable[:excess]:
                self._evict(st.sid)
        return self.evicted_count - n0

    # ------------------------------------------------------------- stats

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def encoder_calls_total(self) -> int:
        return self._enc_calls_total

    def tail_calls_total(self) -> int:
        return self._tail_calls_total

    def time_to_first_prediction(self, sid: str) -> Optional[float]:
        st = self.sessions[sid]
        if st.t_first_prediction is None or st.t_first_submit is None:
            return None
        return st.t_first_prediction - st.t_first_submit

    def time_to_final_prediction(self, sid: str) -> Optional[float]:
        st = self.sessions[sid]
        if st.t_final_prediction is None or st.t_first_submit is None:
            return None
        return st.t_final_prediction - st.t_first_submit

    # --------------------------------------------------------- episodes

    def run_arrivals(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, sim_window: Optional[float] = None):
        """Drive sessions through their episodes in GLOBAL arrival-time
        order (the field regime: one incident, many responders, one
        interleaved stream — ``core.episodes.merge_arrivals``).
        ``payload_fn(sid, event) -> payload``.

        Flushing: with ``sim_window=None``, the engine's wall-clock
        deadline policy applies. With ``sim_window`` set, the deadline
        rule runs on EPISODE time instead (same semantics, different
        clock): after each submit, flush iff the oldest pending
        arrival's episode time is >= ``sim_window`` seconds behind the
        current one — so ``sim_window=0`` flushes per arrival. A final
        ``drain`` runs either way."""
        arrivals = merge_arrivals(episodes)
        if sim_window is None:
            for _t, sid, ev in arrivals:
                self.submit(sid, ev, payload_fn(sid, ev),
                            aggregate=aggregate)
        else:
            saved, self.deadline_s = self.deadline_s, None
            try:
                oldest = None
                for t, sid, ev in arrivals:
                    self.submit(sid, ev, payload_fn(sid, ev),
                                aggregate=aggregate)
                    oldest = t if oldest is None else oldest
                    if t - oldest >= sim_window:
                        self.flush()
                        oldest = None
            finally:
                self.deadline_s = saved
        self.drain()
        return self.flushes
