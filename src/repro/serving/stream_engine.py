"""StreamingEMSServe: the batch+stream construction of the unified
engine.

Progressive partial->final predictions over asynchronously arriving
modalities, deadline-driven coalesced flushes, cached re-fusion with
zero encoder re-runs, and cross-incident session eviction all live in
:class:`repro.serving.api.EMSServeEngine` behind
:class:`~repro.serving.api.BatchPolicy` +
:class:`~repro.serving.api.StreamPolicy`. This module is the thin
constructor shim preserving the historical surface; new code should
say::

    from repro.serving.api import build_engine
    eng = build_engine(models, params, "batch+stream",
                       share_encoders=True, deadline_s=0.05)

``deadline_s`` controls flush cadence: ``0`` flushes on every submit
(minimum time-to-first-prediction), ``> 0`` buffers arrivals until the
oldest pending one is that many wall-seconds old (checked on every
submit and via ``poll()``), ``None`` leaves flushing entirely to the
caller. ``idle_timeout_s``/``max_sessions`` drive cross-incident
eviction; ``time_fn`` is injectable so tests can drive a fake clock.
Cache keys are session-level (``sid``) under ``share_encoders=True``
(zoos from ``core.modular.emsnet_zoo`` sharing one parameter pytree:
a feature is encoded once *total*) and ``"{sid}:{model}"`` otherwise —
the latter matching the per-event and batched engines bit for bit.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.core.bucketing import Bucketer
from repro.core.splitter import SplitModel
from repro.serving.api import (_AUTO, BatchPolicy,  # noqa: F401
                               EMSServeEngine, FlushReport, Prediction,
                               SessionView, StreamPolicy)

# historical names, now the canonical unified types
StreamFlushReport = FlushReport
StreamSession = SessionView


class StreamingEMSServe(EMSServeEngine):
    """Event-driven multi-session runtime with progressive re-fusion —
    see the module and ``api.EMSServeEngine`` docstrings for semantics."""

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 bucketer: Optional[Bucketer] = None,
                 deadline_s: Optional[float] = 0.0,
                 max_coalesce: int = 64, batch_bucket_min: int = 1,
                 share_encoders: bool = False,
                 max_history: Optional[int] = 256,
                 idle_timeout_s: Optional[float] = None,
                 max_sessions: Optional[int] = None,
                 time_fn: Callable[[], float] = time.perf_counter):
        super().__init__(
            models, params,
            batch=BatchPolicy(
                bucketer=bucketer if bucketer is not None else _AUTO,
                max_coalesce=max_coalesce,
                batch_bucket_min=batch_bucket_min),
            stream=StreamPolicy(deadline_s=deadline_s,
                                idle_timeout_s=idle_timeout_s,
                                max_sessions=max_sessions),
            placement=None,
            share_encoders=share_encoders,
            max_history=max_history, time_fn=time_fn)
