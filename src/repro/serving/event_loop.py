"""Wall-clock serving loop: deadline flushing without manual tick().

``StreamingEMSServe`` buffers arrivals until a deadline expires, but by
itself only re-checks that deadline when something happens to call
``submit``/``poll`` — fine for episode-time replays, wrong for a live
deployment where the *last* arrivals of a lull must still flush on
time. This driver closes that ROADMAP rung: it replays a timed arrival
stream against a **monotonic clock** and pumps the engine's ``poll()``
between arrivals and through trailing lulls, so deadline-driven flushes
fire from real time with no manual ``tick()`` calls.

Works against any engine exposing ``submit(sid, event, payload)`` /
``poll()`` / ``drain()`` — i.e. both ``StreamingEMSServe`` (poll
triggers its deadline flushes and eviction sweeps) and
``TieredEMSServe`` (per-arrival, poll is a no-op); the ``--stream`` /
``--tiered`` launcher modes run through it with ``--wall-clock``.

``clock``/``sleep_fn`` are injectable so tests can drive simulated
wall time deterministically; ``speed`` scales episode seconds to wall
seconds (e.g. ``speed=60`` replays a one-minute incident in a second).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.episodes import Event, merge_arrivals


@dataclass
class LoopStats:
    arrivals: int = 0
    polls: int = 0
    flushes_fired: int = 0      # flushes triggered by this loop's polls
    wall_s: float = 0.0


class WallClockDriver:
    """Pumps an engine's deadline flushes from a monotonic clock while
    replaying a timed arrival stream."""

    def __init__(self, engine, *, speed: float = 1.0,
                 poll_interval_s: float = 0.005,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Callable[[float], None] = time.sleep):
        self.engine = engine
        self.speed = speed
        self.poll_interval_s = poll_interval_s
        self.clock = clock
        self.sleep_fn = sleep_fn
        self.stats = LoopStats()

    def _pending(self) -> int:
        count = getattr(self.engine, "pending_count", None)
        return count() if callable(count) else 0

    def _pump_until(self, t0: float, episode_t: Optional[float]):
        """Poll the engine until wall time reaches episode time
        ``episode_t`` (None: until nothing is pending any more)."""
        while True:
            now_ep = (self.clock() - t0) * self.speed
            if episode_t is not None and now_ep >= episode_t:
                return
            if episode_t is None and (
                    not self._pending()
                    or getattr(self.engine, "deadline_s", None) is None):
                # nothing left to flush, or flushing is caller-driven
                # (deadline_s=None) — the trailing drain() handles it
                return
            if self.engine.poll() is not None:
                self.stats.flushes_fired += 1
            self.stats.polls += 1
            if episode_t is None:
                wait = self.poll_interval_s
            else:
                wait = min(self.poll_interval_s,
                           max(0.0, (episode_t - now_ep) / self.speed))
            self.sleep_fn(wait)

    def run(self, episodes: Dict[str, List[Event]], payload_fn, *,
            aggregate=None):
        """Replay ``episodes`` in global arrival order on the wall
        clock; returns the loop stats. Trailing pending arrivals are
        pumped until their deadline fires (never force-drained early —
        the deadline policy stays in charge; a final ``drain`` only
        catches engines with no deadline at all)."""
        t_start = self.clock()
        for t, sid, ev in merge_arrivals(episodes):
            self._pump_until(t_start, t)
            self.engine.submit(sid, ev, payload_fn(sid, ev),
                               aggregate=aggregate)
            self.stats.arrivals += 1
        self._pump_until(t_start, None)
        self.engine.drain()
        self.stats.wall_s = self.clock() - t_start
        return self.stats
