"""One EMSServe: the unified session-engine API.

The serving layer used to be four sibling runtimes (`core.engine.EMSServe`
per-event reference, `BatchedEMSServe`, `StreamingEMSServe`,
`TieredEMSServe`) with duplicated session/flush/report machinery and
mutually exclusive launcher modes. This module replaces the three
multi-session runtimes with ONE :class:`EMSServeEngine` whose behavior is
assembled from orthogonal, composable policy objects:

  * :class:`BatchPolicy` — cross-session coalescing: shape-bucketed
    inputs (``core.bucketing``), power-of-two batch rows, chunked
    batched XLA calls, one host sync per flush;
  * :class:`StreamPolicy` — progressive partial->final predictions,
    wall-clock flush deadlines, cross-incident session eviction
    (idle timeout + LRU cap), and — under tiered placement — on-glass
    provisional partials while the edge computes the refreshed result;
  * :class:`PlacementPolicy` — glass<->edge tier hosts on simulated
    busy-clocks, live per-arrival offload decisions through the
    heartbeat-quantized monitor, byte-accounted in-order feature
    transport, and heartbeat-detected edge-crash failover from the
    versioned feature cache.

Engines are built from a config spec by :func:`build_engine` (xFormers
factory idiom: the spec is data, the factory types it):

    eng = build_engine(models, params, "batch+stream")
    eng = build_engine(models, params, "stream+tiered",
                       profile=table, trace=trace, share_encoders=True)
    eng = build_engine(models, params, {"batch": {"max_coalesce": 32},
                                        "stream": {"deadline_s": 0.05}})

The canonical exchange types — :class:`Arrival` in, :class:`Prediction` /
:class:`FlushReport` / :class:`TieredRecord` out, :class:`SessionView`
for per-session state — are shared by every composition, so batching,
streaming, and tiering can be enabled *together*: the legacy engines are
thin constructor shims over this class (``serving.batch_engine``,
``serving.stream_engine``, ``serving.tiered_runtime``).

`core.engine.EMSServe` remains the single-session per-event *reference*
engine (the paper's Table-6 trace and every benchmark's baseline); the
parity tiers assert this engine agrees with it output-for-output.

Semantics of composition:

  * ``batch`` alone — caller-driven flushes (``deadline_s=None``), one
    batched encoder call per (modality, bucket) per consumer model, one
    batched tail per selected model, ``FlushReport.recommendations``
    per touched session (the BatchedEMSServe contract);
  * ``stream`` adds deadline-driven flushing, ``partial``/``final``
    tagging on every emitted :class:`Prediction`, and eviction;
  * ``tiered`` switches intake to per-arrival placement on the
    simulated tier clocks (offload decisions are per-event by
    construction, so batch coalescing degrades to shape bucketing
    there — the bucketer still bounds compile counts);
  * ``stream+tiered`` — the composition none of the siblings could
    express: when an arrival offloads, the glasses immediately re-fuse
    the cached (<=1-step stale, asserted live) features into an
    on-glass provisional partial while the edge computes the refreshed
    prediction, so the EMT always has the freshest answer the glass can
    produce *now* and the refined one the moment the downlink lands.
"""
from __future__ import annotations

import math
import time
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax

from repro.core.bucketing import Bucketer, next_pow2, stack_bucketed
from repro.core.episodes import Event, merge_arrivals
from repro.core.feature_cache import FeatureCache
from repro.core.offload import (AdaptiveOffloadPolicy, BandwidthTrace,
                                Decision, HeartbeatMonitor, ProfileTable)
from repro.core.splitter import SplitModel, select_model
from repro.serving.transport import TransportChannel, payload_nbytes

__all__ = [
    "Arrival", "Prediction", "FlushReport", "SessionView", "TieredRecord",
    "TierHost", "BatchPolicy", "StreamPolicy", "PlacementPolicy",
    "EngineSpec", "EMSServeEngine", "build_engine", "parse_spec",
]


# ======================================================================
# Canonical exchange types
# ======================================================================

@dataclass(frozen=True)
class Arrival:
    """One datum entering the engine: which session, which event, what
    payload. ``EMSServeEngine.ingest`` consumes these; ``submit`` is the
    unpacked form the drivers and legacy callers use."""
    sid: str
    event: Event
    payload: Any = None

    @property
    def modality(self) -> str:
        return self.event.modality

    @property
    def arrival_time(self) -> float:
        return self.event.arrival_time

    @property
    def index(self) -> int:
        return self.event.index


@dataclass
class Prediction:
    """One progressive prediction emitted for a session.

    Flush-mode predictions carry the flush that produced them in
    ``flush_id``; tiered-mode (per-arrival) predictions carry ``-1``
    there and stamp ``t_emit`` on the simulated tier clock instead of
    the engine's ``time_fn``."""
    sid: str
    step: int                       # session step it reflects
    model: str                      # selected model name
    modalities: Tuple[str, ...]     # fused subset, canonical order
    kind: str                       # "partial" | "final"
    outputs: dict                   # head outputs (batch row for sid)
    flush_id: int
    t_emit: float


@dataclass
class FlushReport:
    """What one flush did: arrivals drained, XLA dispatches, the single
    host sync's wall time, per-arrival latencies, and the emissions —
    ``predictions`` (tagged partial/final) and the last fused head
    outputs per touched session in ``recommendations`` (the batch-mode
    contract; identical rows, different indexing)."""
    flush_id: int
    n_events: int
    n_encoder_calls: int
    n_tail_calls: int
    wall_s: float
    latencies: Dict[Tuple[str, int], float]     # (sid, event idx) -> s
    predictions: List[Prediction] = field(default_factory=list)
    recommendations: Dict[str, dict] = field(default_factory=dict)


@dataclass
class SessionView:
    """Per-session state, one shape for every composition. Flush-mode
    engines use the intake/prediction fields; tiered placement adds the
    simulated-clock fields (``ready_at``, ``records``, ``t_*_emit``)."""
    sid: str
    inputs: Dict[str, object] = field(default_factory=dict)
    input_step: Dict[str, int] = field(default_factory=dict)
    step: int = 0
    dirty: set = field(default_factory=set)   # modalities changed since flush
    events_seen: int = 0
    last_recommendation: Optional[dict] = None
    predictions: List["Prediction"] = field(default_factory=list)
    finalized: bool = False                   # has emitted a final prediction
    t_first_submit: Optional[float] = None    # time_fn clock
    t_first_prediction: Optional[float] = None
    t_final_prediction: Optional[float] = None
    t_last_activity: Optional[float] = None   # last submit or emission
    # ---- tiered placement (simulated episode clock)
    ready_at: float = 0.0                     # per-session in-order processing
    records: List["TieredRecord"] = field(default_factory=list)
    t_first_arrival: Optional[float] = None   # survives record trimming
    t_first_emit: Optional[float] = None
    t_final_emit: Optional[float] = None


@dataclass
class TierHost:
    """One hardware tier with its own busy-until simulated clock."""
    name: str                   # display name ('glass' | 'edge')
    tier: str                   # key into ProfileTable.factors
    profile: ProfileTable
    free_at: float = 0.0
    busy_s: float = 0.0
    calls: int = 0

    def time(self, submodule: str) -> float:
        return self.profile.time(submodule, self.tier)

    def occupy(self, duration: float, t_start: float) -> Tuple[float, float]:
        """Book ``duration`` seconds of compute no earlier than
        ``t_start``; returns (start, done) on the simulated clock."""
        start = max(t_start, self.free_at)
        done = start + duration
        self.free_at = done
        self.busy_s += duration
        self.calls += 1
        return start, done


@dataclass
class TieredRecord:
    """Timeline of one arrival through tiered placement."""
    sid: str
    index: int
    modality: str
    model: Optional[str]
    tier: str                   # where the work actually ran
    kind: str                   # 'partial' | 'final'
    t_arrival: float
    t_start: float              # when the glasses picked the event up
    t_emit: float               # when the prediction reached the glasses
    uplink_s: float = 0.0       # payload + cache-sync transfer time
    downlink_s: float = 0.0     # feature + outputs return transfer time
    compute_s: float = 0.0
    fallback: bool = False      # edge crashed mid-flight; re-ran on glass
    detect_s: float = 0.0       # stall waiting on missed-heartbeat detection
    decision: Optional[Decision] = None
    outputs: Optional[dict] = None
    # stream x tiered composition: the on-glass provisional prediction
    # emitted from cached features while this offload was in flight
    glass_partial: Optional[Prediction] = None

    @property
    def latency_s(self) -> float:
        return self.t_emit - self.t_arrival


# ======================================================================
# Composable policies
# ======================================================================

_AUTO = "auto"      # BatchPolicy.bucketer sentinel: derive from the models


@dataclass
class BatchPolicy:
    """Cross-session coalescing knobs.

    ``bucketer="auto"`` derives per-modality length caps from the
    models' declared ``max_lengths`` (so padding never exceeds e.g. a
    positional table); pass an explicit :class:`Bucketer` to control the
    grid, or ``None`` to disable shape bucketing (tiered default).
    ``batch_bucket_min`` floors the coalesced batch axis so a steady
    session count compiles ONE batch shape."""
    bucketer: Union[Bucketer, None, str] = _AUTO
    max_coalesce: int = 64
    batch_bucket_min: int = 1


@dataclass
class StreamPolicy:
    """Progressive-prediction and liveness knobs.

    ``deadline_s``: 0 flushes on every submit, > 0 buffers arrivals until
    the oldest pending one is that old, None leaves flushing entirely to
    the caller. ``idle_timeout_s``/``max_sessions`` drive cross-incident
    eviction — swept after every flush and ``poll()`` (wall clock), or
    after every arrival under tiered placement (simulated clock, where
    the wall-clock ``poll()`` must not sweep). ``glass_partials``
    (tiered composition only): emit an on-glass provisional partial
    from cached features while an offloaded arrival is in flight."""
    deadline_s: Optional[float] = 0.0
    idle_timeout_s: Optional[float] = None
    max_sessions: Optional[int] = None
    glass_partials: bool = True


@dataclass
class PlacementPolicy:
    """Glass<->edge tier placement knobs. ``profile`` is the one-time
    offline profiling result; ``trace`` drives both the heartbeat
    monitor (decisions) and the transport links (true wire bandwidth).
    ``force='glass'|'edge'`` pins placement for ablations;
    ``adaptive=False`` always offloads."""
    profile: ProfileTable
    trace: BandwidthTrace
    glass_tier: str = "glass"
    edge_tier: str = "edge4c"
    hb_period: float = 1.0
    link_latency_s: float = 0.005
    adaptive: bool = True
    force: Optional[str] = None


@dataclass
class EngineSpec:
    """A fully-typed engine recipe: which policies are on, plus the
    engine-wide options. Produced from strings/dicts by
    :func:`parse_spec`; consumed by :func:`build_engine`."""
    batch: Optional[BatchPolicy] = None
    stream: Optional[StreamPolicy] = None
    placement: Optional[PlacementPolicy] = None
    share_encoders: bool = False
    max_history: Optional[int] = 256

    def enabled(self) -> Tuple[str, ...]:
        out = []
        if self.batch is not None:
            out.append("batch")
        if self.stream is not None:
            out.append("stream")
        if self.placement is not None:
            out.append("tiered")
        return tuple(out)


# ======================================================================
# The unified engine
# ======================================================================

class EMSServeEngine:
    """The one multi-session serving runtime over a ``SplitModel`` zoo.

    ``models``/``params`` are shared across sessions (one weight copy).
    Behavior composes from the policy objects — see the module docstring
    for the composition semantics. All public surface of the three
    legacy engines is preserved: ``submit``/``flush``/``poll``/``drain``
    /``run_episodes``/``run_arrivals``, the stats accessors, and the
    per-session views under ``sessions``.

    ``share_encoders=True`` is for zoos built by ``core.modular
    .emsnet_zoo`` whose subset models share one parameter pytree: a
    feature is encoded once *total* (cache keys are session-level)
    instead of once per consuming model (``"{sid}:{model}"`` keys, the
    per-event engine's discipline). ``time_fn`` is injectable so tests
    drive a fake wall clock; tiered placement runs on the simulated
    episode clock instead.
    """

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 batch: Optional[BatchPolicy] = None,
                 stream: Optional[StreamPolicy] = None,
                 placement: Optional[PlacementPolicy] = None,
                 share_encoders: bool = False,
                 max_history: Optional[int] = 256,
                 time_fn: Callable[[], float] = time.perf_counter):
        self.models = models
        self.params = params
        self.batch_policy = batch or BatchPolicy()
        self.stream_policy = stream
        self.placement_policy = placement
        self.share_encoders = share_encoders
        self.max_history = max_history
        self.time_fn = time_fn

        # ---- batch policy -> coalescing state
        bucketer = self.batch_policy.bucketer
        if bucketer == _AUTO:
            # default grid only for flush-mode engines; tiered placement
            # historically runs unbucketed unless explicitly configured
            bucketer = (self._derive_bucketer(models)
                        if placement is None else None)
        self.bucketer: Optional[Bucketer] = bucketer
        self.max_coalesce = self.batch_policy.max_coalesce
        self.batch_bucket_min = self.batch_policy.batch_bucket_min

        # ---- stream policy -> deadline / eviction state
        sp = stream
        self.deadline_s = sp.deadline_s if sp is not None else None
        self.idle_timeout_s = sp.idle_timeout_s if sp is not None else None
        self.max_sessions = sp.max_sessions if sp is not None else None
        self.glass_partials = bool(sp is not None and sp.glass_partials
                                   and placement is not None)

        # ---- shared session/cache state
        self.cache = FeatureCache(max_staleness=1)
        self.sessions: Dict[str, SessionView] = {}
        # every modality ANY model consumes: a prediction fusing all of
        # them cannot be refined further -> tagged "final"
        self.full_set = frozenset(m for sm in models.values()
                                  for m in sm.modalities())
        self.evicted_count = 0
        self._pending: List[Tuple[str, int, float]] = []  # (sid, idx, t_submit)
        self.flushes: List[FlushReport] = []              # bounded window
        self.events_total = 0
        self.flushes_total = 0
        self._enc_calls_total = 0
        self._tail_calls_total = 0

        # ---- placement policy -> tier hosts, transport, fault state
        self.records: List[TieredRecord] = []
        if placement is not None:
            pp = placement
            self.profile = pp.profile
            self.monitor = HeartbeatMonitor(pp.trace, period=pp.hb_period)
            self.policy = AdaptiveOffloadPolicy(
                pp.profile, self.monitor, glass_tier=pp.glass_tier,
                edge_tier=pp.edge_tier, adaptive=pp.adaptive, force=pp.force)
            self.glass = TierHost("glass", pp.glass_tier, pp.profile)
            self.edge = TierHost("edge", pp.edge_tier, pp.profile)
            self.uplink = TransportChannel(pp.trace,
                                           latency_s=pp.link_latency_s,
                                           name="glass->edge")
            self.downlink = TransportChannel(pp.trace,
                                             latency_s=pp.link_latency_s,
                                             name="edge->glass")
            # edge replica freshness: (cache key, modality) -> feature
            # VERSION the edge holds (versions only bump on real
            # re-encodes; steps get re-stamped by every touch, which
            # would force spurious re-ships)
            self._edge_versions: Dict[Tuple[str, str], int] = {}
            # fault injection / detection
            self.crash_at: Optional[float] = None
            self.detect_at: Optional[float] = None
            self.edge_known_dead = False
            self.fallback_count = 0
            self.offloaded_count = 0
            self.on_glass_count = 0
            self._total_latency = 0.0

    # ------------------------------------------------------------ setup

    @staticmethod
    def _derive_bucketer(models: Dict[str, SplitModel]) -> Bucketer:
        """Hard caps from the models (e.g. the text positional table) so
        the default grid never pads past what they accept."""
        limits: Dict[str, int] = {}
        for sm in models.values():
            for m, n in sm.module.max_lengths.items():
                limits[m] = min(limits.get(m, n), n)
        return Bucketer(max_buckets=limits)

    @property
    def tiered(self) -> bool:
        return self.placement_policy is not None

    # ------------------------------------------------------------ intake

    def session(self, sid: str) -> SessionView:
        st = self.sessions.get(sid)
        if st is None:
            st = self.sessions[sid] = SessionView(sid)
        return st

    def ingest(self, arrival: Arrival, *, aggregate=None):
        """Canonical-typed intake: unpacks an :class:`Arrival`."""
        return self.submit(arrival.sid, arrival.event, arrival.payload,
                           aggregate=aggregate)

    def submit(self, sid: str, event: Event, payload, *, aggregate=None):
        """Record one arriving datum. ``aggregate(old, new) -> input``
        merges it into the modality's aggregated input (default:
        replace).

        Flush-mode (no placement): buffers the arrival and flushes if
        the deadline policy says so — returns the :class:`FlushReport`
        when one ran, else None. Tiered placement: processes the arrival
        end to end on the decided tier and returns its
        :class:`TieredRecord`."""
        if self.tiered:
            return self._submit_tiered(sid, event, payload,
                                       aggregate=aggregate)
        now = self.time_fn()
        st = self._intake(sid, event, payload, aggregate)
        st.t_last_activity = now
        if st.t_first_submit is None:
            st.t_first_submit = now
        self._pending.append((sid, event.index, now))
        if self.deadline_s is None:
            return None
        if self.deadline_s <= 0.0:
            return self.flush()
        if now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        return None

    def _intake(self, sid: str, event: Event, payload,
                aggregate) -> SessionView:
        """Shared input-aggregation bookkeeping for both modes."""
        st = self.session(sid)
        st.step += 1
        m = event.modality
        old = st.inputs.get(m)
        st.inputs[m] = aggregate(old, payload) if aggregate else payload
        st.input_step[m] = st.step
        st.dirty.add(m)
        st.events_seen += 1
        self.events_total += 1
        return st

    def poll(self, now: Optional[float] = None) -> Optional[FlushReport]:
        """Flush if the oldest pending arrival has exceeded the
        deadline; also the idle hook where session eviction runs. No-op
        under tiered placement (nothing buffers there)."""
        if self.tiered:
            return None
        now = self.time_fn() if now is None else now
        if self._pending and self.deadline_s is not None \
                and now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        self.evict_sessions(now)
        return None

    def drain(self) -> Optional[FlushReport]:
        """Flush whatever is pending, deadline or not."""
        if self.tiered:
            return None
        return self.flush() if self._pending else None

    def pending_count(self) -> int:
        """Arrivals buffered but not yet flushed (the event-loop driver
        pumps poll() until this reaches zero)."""
        return len(self._pending)

    # ------------------------------------------------------------- flush

    def _cache_key(self, sid: str, model_name: str) -> str:
        return sid if self.share_encoders else f"{sid}:{model_name}"

    def _bucket_rows(self, n: int) -> int:
        return max(self.batch_bucket_min, next_pow2(n))

    def _consumers(self, m: str):
        return [(n, sm) for n, sm in self.models.items()
                if m in sm.modalities()]

    def _bucketed(self, m: str, x):
        return self.bucketer.fit(m, x) if self.bucketer else x

    def _encode_groups(self, sids):
        """Dirty (session, modality) work grouped by identical
        post-bucket shape: each group is one stacked encoder call."""
        groups = defaultdict(list)     # (modality, shape) -> [(sid, payload)]
        for sid in sids:
            st = self.sessions[sid]
            for m in sorted(st.dirty):
                p = self._bucketed(m, st.inputs[m])
                shape = (tuple(p["x"].shape) if isinstance(p, dict)
                         else tuple(p.shape))
                groups[(m, shape)].append((st.sid, p))
        return groups

    def flush(self) -> FlushReport:
        """Run all pending work: one batched encoder call per
        (modality, bucket[, chunk]) per consuming model (ONE total with
        ``share_encoders``), scatter rows into the feature cache, one
        batched tail per selected model, emit progressive predictions,
        sync the host ONCE."""
        if self.tiered:
            raise RuntimeError(
                "flush() is a flush-mode operation; tiered placement "
                "processes each arrival in submit()")
        t0 = self.time_fn()
        n_enc = n_tail = 0
        sync_targets = []
        # every dirty marking comes with a _pending entry, so only the
        # pending sessions can have work — never scan the whole (ever-
        # growing) session table on the latency-critical path
        touched = sorted({sid for sid, _, _ in self._pending})

        # ---- batched encode + scatter rows into the feature cache
        for (m, _shape), items in self._encode_groups(touched).items():
            consumers = self._consumers(m)
            if not consumers:
                continue
            runners = consumers[:1] if self.share_encoders else consumers
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = stack_bucketed([p for _, p in chunk],
                                         self._bucket_rows(len(chunk)))
                for name, sm in runners:
                    feats = sm.encoders[m](self.params[name], stacked)
                    n_enc += 1
                    sync_targets.append(feats)
                    for i, sid in enumerate(sids):
                        st = self.sessions[sid]
                        self.cache.put(self._cache_key(sid, name), m,
                                       feats[i:i + 1], step=st.step,
                                       tier="glass")

        # ---- progressive re-fusion: batched tails per selected model
        tail_groups = defaultdict(list)    # model name -> [(sid, feats)]
        for sid in touched:
            st = self.sessions[sid]
            if not st.dirty:
                continue
            st.dirty.clear()
            name = select_model(self.models, st.inputs)
            if name is None:
                continue
            sm = self.models[name]
            feats = self.cache.features(self._cache_key(st.sid, name),
                                        sm.modalities(),
                                        input_steps=st.input_step)
            if feats is not None:
                tail_groups[name].append((st.sid, feats))

        emitted = []      # (sid, name, modalities, outputs, step)
        for name, items in tail_groups.items():
            sm = self.models[name]
            mods = sm.modalities()
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = {mm: stack_bucketed([f[mm] for _, f in chunk],
                                              self._bucket_rows(len(chunk)))
                           for mm in mods}
                outs = sm.tail(self.params[name], stacked)
                n_tail += 1
                sync_targets.append(outs)
                for i, sid in enumerate(sids):
                    st = self.sessions[sid]
                    row = jax.tree.map(lambda a: a[i:i + 1], outs)
                    emitted.append((sid, name, tuple(mods), row, st.step))
                    for mm in mods:   # the result carries the cache back
                        self.cache.touch(self._cache_key(sid, name), mm,
                                         st.step)

        # ---- the ONE host sync of this flush
        jax.block_until_ready(sync_targets)
        t1 = self.time_fn()

        flush_id = self.flushes_total
        predictions, recommendations = [], {}
        for sid, name, mods, row, step in emitted:
            kind = "final" if frozenset(mods) == self.full_set else "partial"
            pred = Prediction(sid=sid, step=step, model=name,
                              modalities=mods, kind=kind, outputs=row,
                              flush_id=flush_id, t_emit=t1)
            st = self.sessions[sid]
            self._record_prediction(st, pred)
            predictions.append(pred)
            recommendations[sid] = row

        latencies = {(sid, idx): t1 - ts for sid, idx, ts in self._pending}
        report = FlushReport(
            flush_id=flush_id, n_events=len(self._pending),
            n_encoder_calls=n_enc, n_tail_calls=n_tail, wall_s=t1 - t0,
            latencies=latencies, predictions=predictions,
            recommendations=recommendations)
        self._pending.clear()
        self.flushes.append(report)
        if self.max_history is not None:
            del self.flushes[:-self.max_history]
        self.flushes_total += 1
        self._enc_calls_total += n_enc
        self._tail_calls_total += n_tail
        self.evict_sessions(t1)
        return report

    def _record_prediction(self, st: SessionView, pred: Prediction):
        """Session-side bookkeeping shared by flush- and tiered-mode
        emissions."""
        st.predictions.append(pred)
        if self.max_history is not None:
            del st.predictions[:-self.max_history]
        st.last_recommendation = pred.outputs
        st.t_last_activity = pred.t_emit if self.tiered else self.time_fn()
        if pred.kind == "final":
            st.finalized = True
            if st.t_final_prediction is None:
                st.t_final_prediction = pred.t_emit
        if st.t_first_prediction is None:
            st.t_first_prediction = pred.t_emit

    # ---------------------------------------------------------- eviction

    def _evict(self, sid: str):
        keys = ([sid] if self.share_encoders
                else [f"{sid}:{n}" for n in self.models])
        for key in keys:
            self.cache.drop_session(key)
        if self.tiered:
            # forget the edge replica's versions too: a re-created
            # session restarts its version counters at 0, and a stale
            # high-water mark would wrongly skip re-shipping features
            dropped = set(keys)
            self._edge_versions = {k: v for k, v in
                                   self._edge_versions.items()
                                   if k[0] not in dropped}
        del self.sessions[sid]
        self.evicted_count += 1

    def evict_sessions(self, now: Optional[float] = None) -> int:
        """Cross-incident eviction sweep; returns how many sessions
        left. A session is evictable only when it has no pending
        arrivals and no un-flushed dirty modalities — eviction never
        drops work. Idle timeout first, then LRU down to
        ``max_sessions``: least-recently-active leaves first, so a
        finalized incident that is still streaming updates outlives an
        abandoned partial one (finalized only breaks activity ties)."""
        if self.idle_timeout_s is None and self.max_sessions is None:
            return 0
        now = self.time_fn() if now is None else now
        pending_sids = {sid for sid, _, _ in self._pending}
        evictable = [st for sid, st in self.sessions.items()
                     if sid not in pending_sids and not st.dirty]
        n0 = self.evicted_count
        if self.idle_timeout_s is not None:
            for st in list(evictable):
                last = (st.t_last_activity if st.t_last_activity is not None
                        else st.t_first_submit)
                if last is not None and now - last >= self.idle_timeout_s:
                    self._evict(st.sid)
                    evictable.remove(st)
        if self.max_sessions is not None \
                and len(self.sessions) > self.max_sessions:
            evictable.sort(key=lambda st: (st.t_last_activity or 0.0,
                                           not st.finalized))
            excess = len(self.sessions) - self.max_sessions
            for st in evictable[:excess]:
                self._evict(st.sid)
        return self.evicted_count - n0

    # ==================================================================
    # Tiered placement path (per-arrival on the simulated tier clocks)
    # ==================================================================

    def inject_edge_crash(self, t: float):
        """The edge box dies at simulated time ``t``. The glasses learn
        of it at the first missed heartbeat strictly after ``t``."""
        self.crash_at = t
        period = self.monitor.period
        self.detect_at = (math.floor(t / period) + 1) * period

    def _mark_edge_dead(self):
        self.edge_known_dead = True
        self.policy.force = "glass"       # all future decisions: on-glass
        self._edge_versions.clear()       # the edge replica is gone

    def _edge_usable(self, now: float) -> bool:
        if self.edge_known_dead:
            return False
        if self.detect_at is not None and now >= self.detect_at:
            # a background heartbeat already went unanswered
            self._mark_edge_dead()
            return False
        return True

    def _payload_bytes(self, m: str, payload) -> int:
        """Raw sensor bytes for the uplink: the module's declared size
        (audio clip / camera frame, not the tokenized tensor) when
        available, else the actual array bytes."""
        for _n, sm in self._consumers(m):
            b = sm.module.payload_bytes.get(m)
            if b:
                return b
        return payload_nbytes(payload)

    def _enc_duration(self, m: str, n_runners: int, host: TierHost) -> float:
        """Simulated seconds the tier spends encoding modality ``m`` for
        ``n_runners`` consuming models: expensive text encoders run in
        parallel, cheap ones serially (paper Fig. 8-right — matching
        ``core.engine.EMSServe``)."""
        per = host.time(f"enc:{m}")
        return per if m == "text" else per * n_runners

    # ----------------------------------------------------- real numerics
    #
    # The numerics are split into run / commit phases so the edge fault
    # path can execute the real jitted calls (placement never changes
    # the math) yet leave the glass-side cache untouched when the edge
    # dies before its result makes it back.

    def _run_encoders(self, st: SessionView, m: str) -> Dict[str, object]:
        """Real jitted encoder run(s) for the arriving modality; returns
        ``{model_name: feature}`` WITHOUT touching the cache."""
        consumers = self._consumers(m)
        if not consumers:
            return {}
        runners = consumers[:1] if self.share_encoders else consumers
        enc_in = self._bucketed(m, st.inputs[m])
        return {name: sm.encoders[m](self.params[name], enc_in)
                for name, sm in runners}

    def _commit_features(self, st: SessionView, m: str, feats, tier: str):
        for name, feat in feats.items():
            self.cache.put(self._cache_key(st.sid, name), m, feat,
                           step=st.step, tier=tier)

    def _gather(self, st: SessionView, model_name: str, m: str, feats):
        """The selected model's input features — the arriving modality
        from the fresh (possibly uncommitted) ``feats``, everything else
        from the glass cache with the <=1-step staleness invariant
        asserted on every read. None while the subset is incomplete."""
        sm = self.models[model_name]
        key = self._cache_key(st.sid, model_name)
        fresh = (next(iter(feats.values()), None) if self.share_encoders
                 else feats.get(model_name))
        out = {}
        for mm in sm.modalities():
            if mm == m and fresh is not None:
                out[mm] = fresh
                continue
            e = self.cache.get(key, mm, input_step=st.input_step.get(mm))
            if e is None:
                return None
            out[mm] = e.feature
        return out

    def _touch_consumed(self, st: SessionView, model_name: str):
        """The result carries the cache back (paper fault tolerance):
        re-stamp every consumed entry at this step."""
        key = self._cache_key(st.sid, model_name)
        for mm in self.models[model_name].modalities():
            self.cache.touch(key, mm, st.step)

    # ------------------------------------------------------------- event

    def _submit_tiered(self, sid: str, event: Event, payload, *,
                       aggregate=None) -> TieredRecord:
        """Process one arriving datum end to end: decide tier, encode
        there, transport, re-fuse on glass, emit. With the stream
        policy's ``glass_partials``, an offloaded arrival also yields an
        immediate on-glass provisional partial from cached features."""
        prev_observed = set(self.session(sid).inputs)
        st = self._intake(sid, event, payload, aggregate)
        st.dirty.clear()        # per-arrival mode: nothing buffers

        t_a = event.arrival_time
        if st.t_first_arrival is None:
            st.t_first_arrival = t_a
        now = max(t_a, st.ready_at)
        model_name = select_model(self.models, st.inputs)
        payload_b = self._payload_bytes(event.modality, st.inputs[event.modality])
        dec = self.policy.decide(f"enc:{event.modality}", payload_b, now)

        partial = None
        if dec.tier == "edge" and self._edge_usable(now):
            if self.glass_partials:
                partial = self._glass_provisional(st, prev_observed, now)
            rec = self._edge_event(st, event, model_name, payload_b,
                                   now, dec)
        else:
            rec = self._glass_event(st, event, model_name, now, dec)
        rec.glass_partial = partial

        st.ready_at = rec.t_emit
        st.t_last_activity = rec.t_emit        # simulated clock
        st.records.append(rec)
        self.records.append(rec)
        if self.max_history is not None:
            del st.records[:-self.max_history]
            del self.records[:-self.max_history]
        self._total_latency += rec.latency_s
        if rec.outputs is not None:
            if st.t_first_emit is None:
                st.t_first_emit = rec.t_emit
            if rec.kind == "final" and st.t_final_emit is None:
                st.t_final_emit = rec.t_emit
            if self.stream_policy is not None:
                self._record_prediction(st, Prediction(
                    sid=st.sid, step=st.step, model=rec.model,
                    modalities=tuple(self.models[rec.model].modalities()),
                    kind=rec.kind, outputs=rec.outputs, flush_id=-1,
                    t_emit=rec.t_emit))
        # cross-incident eviction on the SIMULATED clock (every activity
        # timestamp in this mode is a t_emit, so wall-clock poll() must
        # not sweep here — the per-arrival hook is the only safe one)
        self.evict_sessions(rec.t_emit)
        return rec

    def _glass_provisional(self, st: SessionView, prev_observed: set,
                           now: float) -> Optional[Prediction]:
        """Stream x tiered composition: while the edge refreshes the
        arriving modality, the glasses immediately re-fuse what they
        already hold — every feature read from the cache with the
        <=1-step staleness invariant asserted (the arriving modality's
        cached feature is exactly one step behind its input now, the
        paper's tolerated bound). Tagged ``partial`` always: it never
        reflects the newest datum. No cache touch — provisional serving
        must not mask real staleness from later reads."""
        name = select_model(self.models, prev_observed)
        if name is None:
            return None
        sm = self.models[name]
        feats = self.cache.features(self._cache_key(st.sid, name),
                                    sm.modalities(),
                                    input_steps=st.input_step)
        if feats is None:
            return None
        outputs = sm.tail(self.params[name], feats)
        _start, done = self.glass.occupy(self.glass.time("tail"), now)
        pred = Prediction(sid=st.sid, step=st.step, model=name,
                          modalities=tuple(sm.modalities()), kind="partial",
                          outputs=outputs, flush_id=-1, t_emit=done)
        self._record_prediction(st, pred)
        if st.t_first_emit is None or done < st.t_first_emit:
            st.t_first_emit = done
        return pred

    def _kind(self, model_name: Optional[str]) -> str:
        if model_name is None:
            return "partial"
        mods = frozenset(self.models[model_name].modalities())
        return "final" if mods == self.full_set else "partial"

    def _glass_event(self, st: SessionView, event: Event,
                     model_name: Optional[str], now: float, dec: Decision,
                     *, fallback: bool = False,
                     detect_s: float = 0.0) -> TieredRecord:
        m = event.modality
        feats = self._run_encoders(st, m)
        self._commit_features(st, m, feats, tier="glass")
        outputs = None
        if model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)
                self._touch_consumed(st, model_name)
        dur = (self._enc_duration(m, len(feats), self.glass)
               if feats else 0.0)
        if outputs is not None:
            dur += self.glass.time("tail")
        start, done = self.glass.occupy(dur, now)
        self.on_glass_count += 1
        if fallback:
            self.fallback_count += 1
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier="glass", kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=start, t_emit=done,
            compute_s=dur, fallback=fallback, detect_s=detect_s,
            decision=dec, outputs=outputs)

    def _edge_event(self, st: SessionView, event: Event,
                    model_name: Optional[str], payload_b: int,
                    now: float, dec: Decision) -> TieredRecord:
        m = event.modality
        # ---- uplink: raw payload + any features the edge replica lacks
        sync_b, synced = 0, []
        if model_name is not None:
            key = self._cache_key(st.sid, model_name)
            for mm in self.models[model_name].modalities():
                if mm == m:
                    continue
                e = self.cache.peek(key, mm)
                if e is not None and \
                        self._edge_versions.get((key, mm), -1) < e.version:
                    sync_b += payload_nbytes(e.feature)
                    synced.append(((key, mm), e.version))
        up = self.uplink.send(payload_b + sync_b, now)

        # ---- real numerics (uncommitted) + simulated edge compute
        feats = self._run_encoders(st, m)
        outputs = None
        if model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)
        dur = self._enc_duration(m, len(feats), self.edge) if feats else 0.0
        if outputs is not None:
            dur += self.edge.time("tail")
        _start, t_done = self.edge.occupy(dur, up.t_deliver)

        # ---- downlink payload: fresh feature(s) + head outputs + the
        # piggybacked cache re-stamp (an empty-feature result still
        # ships a small ack frame)
        down_b = sum(payload_nbytes(f) for f in feats.values())
        if outputs is not None:
            down_b += payload_nbytes(outputs)

        # ---- crash window: the edge must survive through the END of
        # its downlink transmission, not just its compute — a death
        # mid-transfer loses the result exactly like one mid-encode
        if self.crash_at is not None \
                and self.crash_at < self.downlink.eta(down_b, t_done):
            t_detect = max(now, self.detect_at)
            self._mark_edge_dead()
            return self._glass_event(st, event, model_name, t_detect, dec,
                                     fallback=True,
                                     detect_s=max(0.0, t_detect - now))

        # ---- success: commit to the glass cache, ship the bytes
        self._commit_features(st, m, feats, tier="edge")
        if outputs is not None:
            self._touch_consumed(st, model_name)
        down = self.downlink.send(down_b, t_done)
        # the edge replica now holds everything it consumed or produced
        for k, version in synced:
            self._edge_versions[k] = version
        for name in feats:
            key = self._cache_key(st.sid, name)
            e = self.cache.peek(key, m)
            if e is not None:
                self._edge_versions[(key, m)] = e.version
        self.offloaded_count += 1
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier="edge", kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=up.t_send,
            t_emit=down.t_deliver,
            uplink_s=up.t_deliver - up.t_send,
            downlink_s=down.t_deliver - t_done,
            compute_s=dur, decision=dec, outputs=outputs)

    # --------------------------------------------------------- episodes

    def run_arrivals(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, sim_window: Optional[float] = None,
                     crash_at: Optional[float] = None):
        """Drive sessions through their episodes in GLOBAL arrival-time
        order (the field regime: one incident, many responders, one
        interleaved stream — ``core.episodes.merge_arrivals``).
        ``payload_fn(sid, event) -> payload``.

        Tiered placement: per-arrival, optionally killing the edge at
        simulated time ``crash_at``; returns the records. Flush-mode:
        with ``sim_window=None`` the engine's wall-clock deadline policy
        applies; with ``sim_window`` set, the deadline rule runs on
        EPISODE time instead (same semantics, different clock): after
        each submit, flush iff the oldest pending arrival's episode time
        is >= ``sim_window`` seconds behind the current one — so
        ``sim_window=0`` flushes per arrival. A final ``drain`` runs
        either way; returns the flush reports."""
        arrivals = merge_arrivals(episodes)
        if self.tiered:
            if crash_at is not None:
                self.inject_edge_crash(crash_at)
            for _t, sid, ev in arrivals:
                self.submit(sid, ev, payload_fn(sid, ev),
                            aggregate=aggregate)
            return self.records
        if crash_at is not None:
            raise ValueError("crash_at requires tiered placement")
        if sim_window is None:
            for _t, sid, ev in arrivals:
                self.submit(sid, ev, payload_fn(sid, ev),
                            aggregate=aggregate)
        else:
            saved, self.deadline_s = self.deadline_s, None
            try:
                oldest = None
                for t, sid, ev in arrivals:
                    self.submit(sid, ev, payload_fn(sid, ev),
                                aggregate=aggregate)
                    oldest = t if oldest is None else oldest
                    if t - oldest >= sim_window:
                        self.flush()
                        oldest = None
            finally:
                self.deadline_s = saved
        self.drain()
        return self.flushes

    def run_episodes(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, events_per_flush: int = 1):
        """Tick-driven batch serving: at tick t every session submits
        its t-th event; flush every ``events_per_flush`` ticks.
        ``payload_fn(sid, event) -> payload``."""
        if self.tiered:
            raise RuntimeError("run_episodes is a flush-mode driver; "
                               "tiered placement uses run_arrivals")
        horizon = max((len(ev) for ev in episodes.values()), default=0)
        for t in range(horizon):
            for sid, evs in episodes.items():
                if t < len(evs):
                    self.submit(sid, evs[t], payload_fn(sid, evs[t]),
                                aggregate=aggregate)
            if (t + 1) % events_per_flush == 0:
                self.flush()
        if self._pending:
            self.flush()
        return self.flushes

    # ------------------------------------------------------------- stats

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def encoder_calls_total(self) -> int:
        return self._enc_calls_total

    def tail_calls_total(self) -> int:
        return self._tail_calls_total

    def event_latencies(self) -> List[float]:
        return [lat for f in self.flushes for lat in f.latencies.values()]

    def total_wall_s(self) -> float:
        return sum(f.wall_s for f in self.flushes)

    def time_to_first_prediction(self, sid: str) -> Optional[float]:
        """Flush-mode: wall seconds from first submit to first emitted
        prediction. Tiered: simulated seconds from first arrival to the
        first emission (a glass provisional counts — it IS the first
        thing the EMT sees)."""
        st = self.sessions[sid]
        if self.tiered:
            if st.t_first_emit is None or st.t_first_arrival is None:
                return None
            return st.t_first_emit - st.t_first_arrival
        if st.t_first_prediction is None or st.t_first_submit is None:
            return None
        return st.t_first_prediction - st.t_first_submit

    def time_to_final_prediction(self, sid: str) -> Optional[float]:
        st = self.sessions[sid]
        if self.tiered:
            if st.t_final_emit is None or st.t_first_arrival is None:
                return None
            return st.t_final_emit - st.t_first_arrival
        if st.t_final_prediction is None or st.t_first_submit is None:
            return None
        return st.t_final_prediction - st.t_first_submit

    # ----- tiered stats (meaningful only with placement enabled)

    def total_latency_s(self) -> float:
        """Cumulative serving latency (sum of per-arrival t_emit -
        t_arrival) — the Fig. 15 comparison metric."""
        return self._total_latency

    def makespan_s(self) -> float:
        return max((r.t_emit for r in self.records), default=0.0)

    def transport_stats(self) -> dict:
        return {"uplink": self.uplink.stats(),
                "downlink": self.downlink.stats()}

    def placement_counts(self) -> dict:
        return {"edge": self.offloaded_count, "glass": self.on_glass_count,
                "fallbacks": self.fallback_count}


# ======================================================================
# Spec parsing + factory
# ======================================================================

_SPEC_TOKENS = {
    "batch": "batch", "batched": "batch",
    "stream": "stream", "streaming": "stream",
    "tiered": "tiered", "tier": "tiered", "placement": "tiered",
}

# canonical sections -> (policy class, EngineSpec field); section names
# are pre-canonicalized through _SPEC_TOKENS
_SECTIONS = {
    "batch": (BatchPolicy, "batch"),
    "stream": (StreamPolicy, "stream"),
    "tiered": (PlacementPolicy, "placement"),
}


def parse_spec(spec, **overrides) -> EngineSpec:
    """Normalize an engine spec into a typed :class:`EngineSpec`.

    ``spec`` may be:
      * a string of '+'-joined policy tokens: ``"batch"``, ``"stream"``,
        ``"batch+stream"``, ``"stream+tiered"``, ``"batch+stream+tiered"``
        (aliases: batched/streaming/tier/placement);
      * a dict with sections ``batch`` / ``stream`` / ``tiered`` (each
        True or a kwargs dict) plus engine-wide keys ``share_encoders``
        and ``max_history``;
      * an :class:`EngineSpec` (returned as-is, overrides applied to
        copies of its policies is NOT supported — pass a fresh spec).

    ``overrides`` are routed by name: policy-constructor fields go to
    their policy (e.g. ``deadline_s`` -> StreamPolicy, ``profile``/
    ``trace`` -> PlacementPolicy, ``bucketer`` -> BatchPolicy), and
    ``share_encoders``/``max_history`` to the engine; an override beats
    the same key in a dict-spec section. Tiered specs REQUIRE
    ``profile`` and ``trace`` (there is no meaningful default
    hardware)."""
    if isinstance(spec, EngineSpec):
        if overrides:
            raise ValueError("overrides are not applied to a pre-built "
                             "EngineSpec; pass tokens or a dict instead")
        return spec

    sections: Dict[str, dict] = {}
    engine_kw: Dict[str, Any] = {}
    if isinstance(spec, str):
        for tok in filter(None, (t.strip() for t in spec.split("+"))):
            canon = _SPEC_TOKENS.get(tok.lower())
            if canon is None:
                raise ValueError(
                    f"unknown engine spec token {tok!r}; expected "
                    f"'+'-joined subset of batch/stream/tiered")
            sections[canon] = {}
    elif isinstance(spec, dict):
        for key, val in spec.items():
            if key in ("share_encoders", "max_history"):
                engine_kw[key] = val
                continue
            canon = _SPEC_TOKENS.get(str(key).lower())
            if canon is None:
                raise ValueError(f"unknown engine spec section {key!r}")
            if val is False or val is None:
                continue
            sections[canon] = {} if val is True else dict(val)
    else:
        raise TypeError(f"engine spec must be str, dict, or EngineSpec; "
                        f"got {type(spec).__name__}")

    if not sections:
        raise ValueError("empty engine spec: enable at least one of "
                         "batch/stream/tiered")

    # route the keyword overrides to their policy (or the engine)
    fields_of = {
        "batch": set(BatchPolicy.__dataclass_fields__),
        "stream": set(StreamPolicy.__dataclass_fields__),
        "tiered": set(PlacementPolicy.__dataclass_fields__),
    }
    for k, v in overrides.items():
        if k in ("share_encoders", "max_history"):
            engine_kw[k] = v
            continue
        owner = next((sec for sec in sections if k in fields_of[sec]), None)
        if owner is None and k in fields_of["batch"]:
            # the coalescing machinery exists in every flush-mode engine,
            # so its knobs (bucketer, batch_bucket_min, ...) are always
            # addressable — an explicit "batch" token is only needed to
            # *enable* coalescing semantics in the spec's own vocabulary
            owner = "batch"
            sections.setdefault("batch", {})
        if owner is None:
            enabled = "+".join(sections) or "(none)"
            raise ValueError(f"override {k!r} does not match any enabled "
                             f"policy ({enabled})")
        sections[owner][k] = v        # overrides WIN over dict-spec values

    policies: Dict[str, Any] = {}
    for sec, kw in sections.items():
        cls, target = _SECTIONS[sec]
        unknown = set(kw) - fields_of[sec]
        if unknown:
            raise ValueError(f"unknown {sec} policy option(s): "
                             f"{sorted(unknown)}")
        if cls is PlacementPolicy and not {"profile", "trace"} <= set(kw):
            raise ValueError("tiered placement requires 'profile' "
                             "(ProfileTable) and 'trace' (BandwidthTrace)")
        policies[target] = cls(**kw)
    return EngineSpec(**policies, **engine_kw)


def build_engine(models: Dict[str, SplitModel], params: Dict[str, dict],
                 spec, *, time_fn: Callable[[], float] = time.perf_counter,
                 **overrides) -> EMSServeEngine:
    """THE factory: assemble an :class:`EMSServeEngine` from a spec.

    ``build_engine(models, params, "batch")`` is the batched
    fast path; ``"stream"`` the progressive-prediction runtime;
    ``"stream+tiered"`` streams partials on-glass while the edge
    computes finals. See :func:`parse_spec` for the spec grammar and
    override routing."""
    es = parse_spec(spec, **overrides)
    return EMSServeEngine(models, params, batch=es.batch, stream=es.stream,
                          placement=es.placement,
                          share_encoders=es.share_encoders,
                          max_history=es.max_history, time_fn=time_fn)
