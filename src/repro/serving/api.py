"""One EMSServe: the unified session-engine API.

The serving layer used to be four sibling runtimes (`core.engine.EMSServe`
per-event reference, `BatchedEMSServe`, `StreamingEMSServe`,
`TieredEMSServe`) with duplicated session/flush/report machinery and
mutually exclusive launcher modes. This module replaces the three
multi-session runtimes with ONE :class:`EMSServeEngine` whose behavior is
assembled from orthogonal, composable policy objects:

  * :class:`BatchPolicy` — cross-session coalescing: shape-bucketed
    inputs (``core.bucketing``), power-of-two batch rows, chunked
    batched XLA calls, one host sync per flush;
  * :class:`StreamPolicy` — progressive partial->final predictions,
    wall-clock flush deadlines, cross-incident session eviction
    (idle timeout + LRU cap), and — under tiered placement — on-glass
    provisional partials while the edge computes the refreshed result;
  * :class:`PlacementPolicy` — N tier hosts on simulated busy-clocks
    (the legacy glass<->edge pair, or an ordered ``tiers`` list like
    ``("glass", "ph1", "edge64x")``), live per-arrival decisions
    through per-link heartbeat-quantized monitors with each host's
    queueing delay in the estimate, per-submodule placement (the
    fusion tail may run on a different tier than its encoder),
    byte-accounted in-order per-link transport, heartbeat-detected
    crash failover from the versioned feature cache, and tier
    restart/rejoin with replica re-warm.

Engines are built from a config spec by :func:`build_engine` (xFormers
factory idiom: the spec is data, the factory types it):

    eng = build_engine(models, params, "batch+stream")
    eng = build_engine(models, params, "stream+tiered",
                       profile=table, trace=trace, share_encoders=True)
    eng = build_engine(models, params, {"batch": {"max_coalesce": 32},
                                        "stream": {"deadline_s": 0.05}})

The canonical exchange types — :class:`Arrival` in, :class:`Prediction` /
:class:`FlushReport` / :class:`TieredRecord` out, :class:`SessionView`
for per-session state — are shared by every composition, so batching,
streaming, and tiering can be enabled *together*: the legacy engines are
thin constructor shims over this class (``serving.batch_engine``,
``serving.stream_engine``, ``serving.tiered_runtime``).

`core.engine.EMSServe` remains the single-session per-event *reference*
engine (the paper's Table-6 trace and every benchmark's baseline); the
parity tiers assert this engine agrees with it output-for-output.

Semantics of composition:

  * ``batch`` alone — caller-driven flushes (``deadline_s=None``), one
    batched encoder call per (modality, bucket) per consumer model, one
    batched tail per selected model, ``FlushReport.recommendations``
    per touched session (the BatchedEMSServe contract);
  * ``stream`` adds deadline-driven flushing, ``partial``/``final``
    tagging on every emitted :class:`Prediction`, and eviction;
  * ``tiered`` switches intake to per-arrival placement on the
    simulated tier clocks (offload decisions are per-event by
    construction, so batch coalescing degrades to shape bucketing
    there — the bucketer still bounds compile counts);
  * ``stream+tiered`` — the composition none of the siblings could
    express: when an arrival offloads, the glasses immediately re-fuse
    the cached (<=1-step stale, asserted live) features into an
    on-glass provisional partial while the edge computes the refreshed
    prediction, so the EMT always has the freshest answer the glass can
    produce *now* and the refined one the moment the downlink lands.
"""
from __future__ import annotations

import math
import time
from collections import defaultdict, deque
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.bucketing import (Bucketer, RaggedBatch, next_pow2,
                                  stack_bucketed)
from repro.core.episodes import Event, merge_arrivals
from repro.core.feature_cache import FeatureCache
from repro.core.offload import (BandwidthTrace, HeartbeatMonitor,
                                MultiTierPolicy, ProfileTable, TierDecision,
                                SpeculationPolicy)
from repro.core.splitter import SplitModel, select_model
from repro.models.quantized import dequantize_feature, quantize_feature
from repro.obs import Metrics, Tracer
from repro.serving.transport import TierFabric, payload_nbytes

__all__ = [
    "Arrival", "Prediction", "FlushReport", "SessionView", "TieredRecord",
    "TierHost", "BatchPolicy", "StreamPolicy", "PlacementPolicy",
    "SpeculationPolicy", "EngineSpec", "EMSServeEngine", "build_engine",
    "parse_spec",
]


# ======================================================================
# Canonical exchange types
# ======================================================================

@dataclass(frozen=True)
class Arrival:
    """One datum entering the engine: which session, which event, what
    payload. ``EMSServeEngine.ingest`` consumes these; ``submit`` is the
    unpacked form the drivers and legacy callers use."""
    sid: str
    event: Event
    payload: Any = None

    @property
    def modality(self) -> str:
        return self.event.modality

    @property
    def arrival_time(self) -> float:
        return self.event.arrival_time

    @property
    def index(self) -> int:
        return self.event.index


@dataclass
class Prediction:
    """One progressive prediction emitted for a session.

    Flush-mode predictions carry the flush that produced them in
    ``flush_id``; tiered-mode (per-arrival) predictions carry ``-1``
    there and stamp ``t_emit`` on the simulated tier clock instead of
    the engine's ``time_fn``."""
    sid: str
    step: int                       # session step it reflects
    model: str                      # selected model name
    modalities: Tuple[str, ...]     # fused subset, canonical order
    kind: str                       # "partial" | "final"
    outputs: dict                   # head outputs (batch row for sid)
    flush_id: int
    t_emit: float


@dataclass
class FlushReport:
    """What one flush did: arrivals drained, XLA dispatches, the single
    host sync's wall time, per-arrival latencies, and the emissions —
    ``predictions`` (tagged partial/final) and the last fused head
    outputs per touched session in ``recommendations`` (the batch-mode
    contract; identical rows, different indexing)."""
    flush_id: int
    n_events: int
    n_encoder_calls: int
    n_tail_calls: int
    wall_s: float
    latencies: Dict[Tuple[str, int], float]     # (sid, event idx) -> s
    predictions: List[Prediction] = field(default_factory=list)
    recommendations: Dict[str, dict] = field(default_factory=dict)
    # padding-tax accounting: weighted position counts this flush's XLA
    # calls spent on real data vs bucket/batch padding (weights are each
    # submodule's parameter count — a MAC-proportional estimate, not a
    # hardware FLOP counter)
    flops_useful: float = 0.0
    flops_padded: float = 0.0

    @property
    def padded_flop_frac(self) -> float:
        total = self.flops_useful + self.flops_padded
        return self.flops_padded / total if total else 0.0


@dataclass
class SessionView:
    """Per-session state, one shape for every composition. Flush-mode
    engines use the intake/prediction fields; tiered placement adds the
    simulated-clock fields (``ready_at``, ``records``, ``t_*_emit``)."""
    sid: str
    inputs: Dict[str, object] = field(default_factory=dict)
    input_step: Dict[str, int] = field(default_factory=dict)
    step: int = 0
    dirty: set = field(default_factory=set)   # modalities changed since flush
    events_seen: int = 0
    last_recommendation: Optional[dict] = None
    predictions: List["Prediction"] = field(default_factory=list)
    finalized: bool = False                   # has emitted a final prediction
    t_first_submit: Optional[float] = None    # time_fn clock
    t_first_prediction: Optional[float] = None
    t_final_prediction: Optional[float] = None
    t_last_activity: Optional[float] = None   # last submit or emission
    # ---- tiered placement (simulated episode clock)
    ready_at: float = 0.0                     # per-session in-order processing
    records: List["TieredRecord"] = field(default_factory=list)
    t_first_arrival: Optional[float] = None   # survives record trimming
    t_first_emit: Optional[float] = None
    t_final_emit: Optional[float] = None


@dataclass
class TierHost:
    """One hardware tier with its own busy-until simulated clock."""
    name: str                   # display name ('glass' | 'edge')
    tier: str                   # key into ProfileTable.factors
    profile: ProfileTable
    free_at: float = 0.0
    busy_s: float = 0.0
    calls: int = 0
    tracer: Optional[Tracer] = None

    def __post_init__(self):
        if self.tracer is None:
            self.tracer = Tracer.disabled

    def time(self, submodule: str) -> float:
        return self.profile.time(submodule, self.tier)

    def occupy(self, duration: float, t_start: float,
               label: Optional[str] = None) -> Tuple[float, float]:
        """Book ``duration`` seconds of compute no earlier than
        ``t_start``; returns (start, done) on the simulated clock."""
        start = max(t_start, self.free_at)
        done = start + duration
        self.free_at = done
        self.busy_s += duration
        self.calls += 1
        if self.tracer:
            self.tracer.span(label or f"compute@{self.name}", "compute",
                             start, done, track=f"host:{self.name}",
                             host=self.name, queued_s=start - t_start)
        return start, done

    def release(self, start: float, done: float, t: float):
        """Unwind the un-run tail of the MOST RECENT booking: a
        speculative racer cancelled at commit instant ``t`` frees the
        host from ``max(start, t)`` on (cancel-on-commit — the loser
        stops computing the moment the winner's result lands). A no-op
        if something was booked after, so only the latest racer may be
        released."""
        if self.free_at != done:
            return
        cut = max(start, min(t, done))
        self.busy_s -= done - cut
        self.free_at = cut
        if self.tracer:
            self.tracer.instant("host.release", "speculation", t,
                                track=f"host:{self.name}", host=self.name,
                                freed_s=done - cut)


@dataclass
class _TierFault:
    """Crash / detection / restart state of one remote tier."""
    crash_at: Optional[float] = None     # when the box actually dies
    detect_at: Optional[float] = None    # first missed heartbeat after it
    rejoin_at: Optional[float] = None    # when a restarted box comes back
    dead: bool = False                   # the glasses KNOW it is gone


@dataclass
class TieredRecord:
    """Timeline of one arrival through tiered placement. The schema is
    tier-count-agnostic: ``tier`` names whichever host ran the encoder
    (any of the N configured hosts, not just 'glass'/'edge'), and
    per-submodule placement is broken out in ``enc_tier``/``tail_tier``
    (the tail may run on a third host, or nowhere when the modality
    subset is still incomplete)."""
    sid: str
    index: int
    modality: str
    model: Optional[str]
    tier: str                   # host that ran the encoder (bulk compute)
    kind: str                   # 'partial' | 'final'
    t_arrival: float
    t_start: float              # when the glasses picked the event up
    t_emit: float               # when the prediction reached the glasses
    uplink_s: float = 0.0       # payload + cache-sync transfer time
    downlink_s: float = 0.0     # feature + outputs return transfer time
    compute_s: float = 0.0
    fallback: bool = False      # a tier crashed mid-flight; re-ran on glass
    detect_s: float = 0.0       # stall waiting on missed-heartbeat detection
    decision: Optional[TierDecision] = None
    outputs: Optional[dict] = None
    # per-submodule placement (tail may differ from the encoder's host)
    enc_tier: Optional[str] = None
    tail_tier: Optional[str] = None             # None: no fusion ran
    tail_decision: Optional[TierDecision] = None
    # stream x tiered composition: the on-glass provisional prediction
    # emitted from cached features while this offload was in flight
    glass_partial: Optional[Prediction] = None
    # speculative dual placement: this arrival raced glass against the
    # best remote; the winner's timeline is the record's, the loser's
    # would-have-emitted instant is kept for the win-margin analysis
    speculative: bool = False
    race_winner: Optional[str] = None
    race_loser_emit: Optional[float] = None
    # numeric precision the encoder flight ran at ("fp32" | "int8") —
    # int8 means the sidecar-quantized encoder computed the feature and
    # the cache/wire carry its packed {"q", "scale"} form
    precision: str = "fp32"

    @property
    def latency_s(self) -> float:
        return self.t_emit - self.t_arrival


# ======================================================================
# Composable policies
# ======================================================================

_AUTO = "auto"      # BatchPolicy.bucketer sentinel: derive from the models


@dataclass
class BatchPolicy:
    """Cross-session coalescing knobs.

    ``bucketer="auto"`` derives per-modality length caps from the
    models' declared ``max_lengths`` (so padding never exceeds e.g. a
    positional table); pass an explicit :class:`Bucketer` to control the
    grid, or ``None`` to disable shape bucketing (tiered default).
    ``batch_bucket_min`` floors the coalesced batch axis so a steady
    session count compiles ONE batch shape.

    ``ragged=True`` switches variable-length modalities (text, vitals)
    from per-bucket stacked calls to the concatenated ragged layout
    (``core.bucketing.RaggedBatch``): ONE encoder call per modality per
    flush regardless of live length buckets, and ONE grouped fusion-tail
    call across all pending sessions and modality subsets (possible
    when the zoo shares one parameter pytree — ``share_encoders`` zoos;
    engines with per-model parameters keep the per-model tail loop).
    ``ragged_align`` must equal the model's text ``flash_block`` (packed
    rows start on flash-block boundaries — the bit-parity requirement);
    bit parity against the unbucketed reference additionally needs the
    model config run with ``use_flash_text=True, flash_segments=True``
    on both sides. Defaults OFF: the bucketed path stays the default
    fast path."""
    bucketer: Union[Bucketer, None, str] = _AUTO
    max_coalesce: int = 64
    batch_bucket_min: int = 1
    ragged: bool = False
    ragged_align: int = 8


@dataclass
class StreamPolicy:
    """Progressive-prediction and liveness knobs.

    ``deadline_s``: 0 flushes on every submit, > 0 buffers arrivals until
    the oldest pending one is that old, None leaves flushing entirely to
    the caller. ``idle_timeout_s``/``max_sessions`` drive cross-incident
    eviction — swept after every flush and ``poll()`` (wall clock), or
    after every arrival under tiered placement (simulated clock, where
    the wall-clock ``poll()`` must not sweep). ``glass_partials``
    (tiered composition only): emit an on-glass provisional partial
    from cached features while an offloaded arrival is in flight."""
    deadline_s: Optional[float] = 0.0
    idle_timeout_s: Optional[float] = None
    max_sessions: Optional[int] = None
    glass_partials: bool = True


@dataclass
class PlacementPolicy:
    """Tier placement knobs — two named tiers by default (the historical
    glass<->edge pair), or an ordered N-tier list.

    ``profile`` is the one-time offline profiling result; ``trace``
    drives both the heartbeat monitors (decisions) and the transport
    links (true wire bandwidth). ``tiers`` generalizes: an ordered list
    of ``ProfileTable.factors`` keys (e.g. ``("glass", "ph1",
    "edge64x")``) whose FIRST entry is the local host (the glasses);
    each remote's radio link defaults to ``trace`` and can be overridden
    per host via ``tier_traces``. With ``tiers`` set the engine also
    turns on the two N-tier capabilities by default:

      * ``contention_aware`` — the decision rule adds each host's
        current work-queue delay to its estimate, so concurrent
        sessions spread across tiers instead of stampeding the fastest;
      * ``tail_placement`` — the fusion tail is placed separately from
        the encoder that feeds it (a scene encoder can run on the edge
        box while its tail runs on the phone), paying the feature
        transfer between the two placements.

    Both default to the paper-verbatim contention-blind, co-located
    behavior when ``tiers`` is None (the legacy pair), keeping every
    historical timeline bit-reproducible; pass True/False to override
    either way. ``force`` pins placement for ablations: a host name
    pins everything, a ``{submodule: host}`` dict pins per submodule.
    ``adaptive=False`` always offloads to the cheapest remote.

    The two robustness rungs (both OFF by default so every historical
    timeline stays bit-reproducible):

      * ``speculation`` — a :class:`SpeculationPolicy` arming
        speculative dual placement: an arrival whose estimated
        completion leaves less than the configured margin before the
        deadline races glass against the best remote, commits whichever
        returns first, and cancels the loser (cancel-on-commit);
      * ``redispatch`` — when a tier dies with a flight outstanding,
        re-dispatch the lost flight to the next-best SURVIVING remote
        (falling back to glass only when none exists) instead of
        always re-running on glass.

    ``precision`` arms the quantized tier rung (OFF by default —
    ``None`` keeps every timeline bit-identical to the precision-less
    engine): a ``{host: "int8"}`` dict declares which hosts may run the
    int8 sidecar-quantized encoders. The placement argmin then
    enumerates (tier, precision) candidates JOINTLY — an int8 candidate
    scales a tier's encoder compute by ``int8_compute_scale`` and its
    feature-return bytes by ``int8_bytes_scale`` (the estimate; real
    flights ship the real packed bytes) — so the engine sends quantized
    features exactly when the uplink is the bottleneck and raw ones
    when it isn't. int8 flights commit the packed ``{"q", "scale"}``
    feature form to the cache (staleness semantics unchanged); consuming
    tails dequantize at gather time. Every model in the zoo must
    declare a ``quantize_fn`` or the engine refuses to build."""
    profile: ProfileTable
    trace: BandwidthTrace
    tiers: Optional[Tuple[str, ...]] = None
    tier_traces: Optional[Dict[str, BandwidthTrace]] = None
    glass_tier: str = "glass"
    edge_tier: str = "edge4c"
    hb_period: float = 1.0
    link_latency_s: float = 0.005
    adaptive: bool = True
    force: Optional[Union[str, Dict[str, str]]] = None
    contention_aware: Optional[bool] = None     # None = on iff N-tier
    tail_placement: Optional[bool] = None       # None = on iff N-tier
    speculation: Optional[SpeculationPolicy] = None
    redispatch: bool = False
    precision: Optional[Dict[str, str]] = None  # host -> "fp32" | "int8"
    int8_compute_scale: float = 0.5
    int8_bytes_scale: float = 0.25


@dataclass
class EngineSpec:
    """A fully-typed engine recipe: which policies are on, plus the
    engine-wide options. Produced from strings/dicts by
    :func:`parse_spec`; consumed by :func:`build_engine`."""
    batch: Optional[BatchPolicy] = None
    stream: Optional[StreamPolicy] = None
    placement: Optional[PlacementPolicy] = None
    share_encoders: bool = False
    max_history: Optional[int] = 256

    def enabled(self) -> Tuple[str, ...]:
        out = []
        if self.batch is not None:
            out.append("batch")
        if self.stream is not None:
            out.append("stream")
        if self.placement is not None:
            out.append("tiered")
        return tuple(out)


# ======================================================================
# The unified engine
# ======================================================================

class EMSServeEngine:
    """The one multi-session serving runtime over a ``SplitModel`` zoo.

    ``models``/``params`` are shared across sessions (one weight copy).
    Behavior composes from the policy objects — see the module docstring
    for the composition semantics. All public surface of the three
    legacy engines is preserved: ``submit``/``flush``/``poll``/``drain``
    /``run_episodes``/``run_arrivals``, the stats accessors, and the
    per-session views under ``sessions``.

    ``share_encoders=True`` is for zoos built by ``core.modular
    .emsnet_zoo`` whose subset models share one parameter pytree: a
    feature is encoded once *total* (cache keys are session-level)
    instead of once per consuming model (``"{sid}:{model}"`` keys, the
    per-event engine's discipline). ``time_fn`` is injectable so tests
    drive a fake wall clock; tiered placement runs on the simulated
    episode clock instead.
    """

    def __init__(self, models: Dict[str, SplitModel],
                 params: Dict[str, dict], *,
                 batch: Optional[BatchPolicy] = None,
                 stream: Optional[StreamPolicy] = None,
                 placement: Optional[PlacementPolicy] = None,
                 share_encoders: bool = False,
                 max_history: Optional[int] = 256,
                 time_fn: Callable[[], float] = time.perf_counter,
                 tracer: Optional[Tracer] = None):
        self.models = models
        self.params = params
        self.batch_policy = batch or BatchPolicy()
        self.stream_policy = stream
        self.placement_policy = placement
        self.share_encoders = share_encoders
        self.max_history = max_history
        self.time_fn = time_fn

        # ---- observability: one metrics registry for the whole stack
        # (engine + cache + transport), and a span tracer defaulting to
        # the falsy no-op so historical timelines replay bit-identically
        self.metrics = Metrics()
        self.tracer = tracer if tracer is not None else Tracer.disabled
        if self.tracer and placement is None and self.tracer.clock is None:
            # flush-mode engines run on the injected wall clock; tiered
            # engines call set_time() at each simulated-clock arrival
            self.tracer.clock = self.time_fn
        self.metrics.gauge_fn("engine.sessions_live",
                              lambda: len(self.sessions))
        self.metrics.gauge_fn("cache.entries", lambda: len(self.cache))
        # source-step metadata of the most recent _gather, consumed by
        # the fuse trace point (tracer-gated; {} when tracing is off)
        self._last_consumed: dict = {}

        # ---- batch policy -> coalescing state
        bucketer = self.batch_policy.bucketer
        if bucketer == _AUTO:
            # default grid only for flush-mode engines; tiered placement
            # historically runs unbucketed unless explicitly configured
            bucketer = (self._derive_bucketer(models)
                        if placement is None else None)
        self.bucketer: Optional[Bucketer] = bucketer
        self.max_coalesce = self.batch_policy.max_coalesce
        self.batch_bucket_min = self.batch_policy.batch_bucket_min
        self.ragged: Optional[RaggedBatch] = None
        if self.batch_policy.ragged:
            limits: Dict[str, int] = {}
            for sm in models.values():
                for m, n in sm.module.max_lengths.items():
                    limits[m] = min(limits.get(m, n), n)
            self.ragged = RaggedBatch(
                align=self.batch_policy.ragged_align,
                min_rows=self.batch_policy.batch_bucket_min,
                max_lengths=limits)
        # per-(model, subtree) parameter counts, the flop-estimate
        # weights for FlushReport's padding-tax accounting
        self._flop_w: Dict[Tuple[str, str], float] = {}

        # ---- stream policy -> deadline / eviction state
        sp = stream
        self.deadline_s = sp.deadline_s if sp is not None else None
        self.idle_timeout_s = sp.idle_timeout_s if sp is not None else None
        self.max_sessions = sp.max_sessions if sp is not None else None
        self.glass_partials = bool(sp is not None and sp.glass_partials
                                   and placement is not None)

        # ---- shared session/cache state
        self.cache = FeatureCache(max_staleness=1, metrics=self.metrics,
                                  tracer=self.tracer)
        self.sessions: Dict[str, SessionView] = {}
        # every modality ANY model consumes: a prediction fusing all of
        # them cannot be refined further -> tagged "final"
        self.full_set = frozenset(m for sm in models.values()
                                  for m in sm.modalities())
        self._pending: List[Tuple[str, int, float]] = []  # (sid, idx, t_submit)
        self.flushes: List[FlushReport] = []              # bounded window
        self.events_total = 0
        self.flushes_total = 0
        self._enc_calls_total = 0
        self._tail_calls_total = 0

        # ---- placement policy -> tier hosts, link fabric, fault state
        self.records: List[TieredRecord] = []
        if placement is not None:
            pp = placement
            self.profile = pp.profile
            multi = pp.tiers is not None
            # host names double as ProfileTable factor keys in N-tier
            # mode; the legacy pair keeps its historical display names
            names = list(pp.tiers) if multi else ["glass", "edge"]
            keys = names if multi else [pp.glass_tier, pp.edge_tier]
            if len(names) < 2:
                raise ValueError("tiered placement needs the local host "
                                 "plus at least one remote tier")
            self.local_name = names[0]
            self.hosts: Dict[str, TierHost] = {
                n: TierHost(n, k, pp.profile, tracer=self.tracer)
                for n, k in zip(names, keys)}
            self.remote_names = names[1:]
            traces = {n: (pp.tier_traces or {}).get(n, pp.trace)
                      for n in self.remote_names}
            self.monitors = {n: HeartbeatMonitor(traces[n],
                                                 period=pp.hb_period)
                             for n in self.remote_names}
            self.fabric = TierFabric(self.local_name, traces,
                                     latency_s=pp.link_latency_s,
                                     metrics=self.metrics,
                                     tracer=self.tracer)
            # ---- quantized tier rung: validate the precision map up
            # front (a bad host name or a zoo without quantize_fn is a
            # configuration error, not a first-decision surprise), then
            # arm the policy's joint (tier, precision) enumeration only
            # when some host actually serves int8 — an all-fp32 map is
            # the legacy bit-identical rule
            prec_cfg = dict(pp.precision or {})
            for h, p in prec_cfg.items():
                if h not in names or p not in ("fp32", "int8"):
                    raise ValueError(
                        f"precision[{h!r}]={p!r}: unknown host or "
                        f"precision (hosts {sorted(names)}, "
                        "precisions fp32/int8)")
            int8_hosts = sorted(h for h, p in prec_cfg.items()
                                if p == "int8")
            if int8_hosts:
                for mname, sm in models.items():
                    if sm.module.quantize_fn is None:
                        raise ValueError(
                            f"precision={prec_cfg} needs an int8 variant "
                            f"of every model; {mname!r} declares no "
                            "quantize_fn")
            self.int8_compute_scale = pp.int8_compute_scale
            # fp32 pytree id() -> derived int8 sidecar pytree: derived
            # ONCE per distinct parameter pytree, so share_encoders zoos
            # (one pytree for the whole zoo) quantize exactly once
            self._qparams_cache: Dict[int, dict] = {}
            self.policy = MultiTierPolicy(
                pp.profile, self.monitors, local=self.local_name,
                tier_of={n: h.tier for n, h in self.hosts.items()},
                adaptive=pp.adaptive, force=pp.force,
                speculation=pp.speculation,
                precisions=({h: ("fp32", "int8") for h in int8_hosts}
                            if int8_hosts else None),
                int8_compute_scale=pp.int8_compute_scale,
                int8_bytes_scale=pp.int8_bytes_scale)
            self.redispatch = pp.redispatch
            # the fastest remote is the legacy 'edge' for the 2-tier
            # accessor surface (uplink/downlink/crash_at/...)
            self._primary = min(
                self.remote_names,
                key=lambda n: pp.profile.factors[self.hosts[n].tier])
            self.monitor = self.monitors[self._primary]
            # the two N-tier capabilities default on exactly when the
            # N-tier surface is used, so legacy timelines stay
            # bit-reproducible
            self.contention_aware = (multi if pp.contention_aware is None
                                     else pp.contention_aware)
            self.tail_placement = (multi if pp.tail_placement is None
                                   else pp.tail_placement)
            # per-tier replica freshness: (cache key, modality) ->
            # feature VERSION that host holds (versions only bump on
            # real re-encodes; steps get re-stamped by every touch,
            # which would force spurious re-ships)
            self._replica_versions: Dict[str, Dict[Tuple[str, str], int]] \
                = {n: {} for n in self.remote_names}
            # fault injection / detection / restart, per remote tier;
            # _schedule holds the not-yet-armed chaos cycles per tier
            self._faults: Dict[str, _TierFault] = {
                n: _TierFault() for n in self.remote_names}
            self._schedule: Dict[str, deque] = {}
            # placement / speculation tallies live on the metrics
            # registry; the historical attributes are read-through
            # properties (below) keyed off the host-name list
            self._host_names = list(names)
            self._total_latency = 0.0

    # ---- legacy counter attributes (read-through to the registry)
    @property
    def evicted_count(self) -> int:
        return int(self.metrics.get("engine.evicted_sessions"))

    @property
    def fallback_count(self) -> int:
        return int(self.metrics.get("placement.fallbacks"))

    @property
    def rejoin_count(self) -> int:
        return int(self.metrics.get("placement.rejoins"))

    @property
    def offloaded_count(self) -> int:
        return int(self.metrics.get("placement.offloaded"))

    @property
    def on_glass_count(self) -> int:
        return int(self.metrics.get("placement.on_glass"))

    @property
    def place_counts(self) -> Dict[str, int]:
        return {n: int(self.metrics.get(f"placement.enc.{n}"))
                for n in self._host_names}

    @property
    def tail_counts(self) -> Dict[str, int]:
        return {n: int(self.metrics.get(f"placement.tail.{n}"))
                for n in self._host_names}

    @property
    def spec_count(self) -> int:
        return int(self.metrics.get("speculation.races"))

    @property
    def spec_wins(self) -> Dict[str, int]:
        return {n: int(self.metrics.get(f"speculation.wins.{n}"))
                for n in self._host_names}

    @property
    def spec_crash_saves(self) -> int:
        return int(self.metrics.get("speculation.crash_saves"))

    @property
    def redispatch_count(self) -> int:
        return int(self.metrics.get("placement.redispatches"))

    def metrics_snapshot(self) -> dict:
        """One JSON-serializable snapshot of every counter, gauge, and
        latency histogram (p50/p95/p99) the stack accumulated."""
        return self.metrics.snapshot()

    # ------------------------------------------------------------ setup

    @staticmethod
    def _derive_bucketer(models: Dict[str, SplitModel]) -> Bucketer:
        """Hard caps from the models (e.g. the text positional table) so
        the default grid never pads past what they accept."""
        limits: Dict[str, int] = {}
        for sm in models.values():
            for m, n in sm.module.max_lengths.items():
                limits[m] = min(limits.get(m, n), n)
        return Bucketer(max_buckets=limits)

    @property
    def tiered(self) -> bool:
        return self.placement_policy is not None

    # ------------------------------------------------------------ intake

    def session(self, sid: str) -> SessionView:
        st = self.sessions.get(sid)
        if st is None:
            st = self.sessions[sid] = SessionView(sid)
        return st

    def ingest(self, arrival: Arrival, *, aggregate=None):
        """Canonical-typed intake: unpacks an :class:`Arrival`."""
        return self.submit(arrival.sid, arrival.event, arrival.payload,
                           aggregate=aggregate)

    def submit(self, sid: str, event: Event, payload, *, aggregate=None):
        """Record one arriving datum. ``aggregate(old, new) -> input``
        merges it into the modality's aggregated input (default:
        replace).

        Flush-mode (no placement): buffers the arrival and flushes if
        the deadline policy says so — returns the :class:`FlushReport`
        when one ran, else None. Tiered placement: processes the arrival
        end to end on the decided tier and returns its
        :class:`TieredRecord`."""
        if self.tiered:
            return self._submit_tiered(sid, event, payload,
                                       aggregate=aggregate)
        now = self.time_fn()
        st = self._intake(sid, event, payload, aggregate)
        st.t_last_activity = now
        if st.t_first_submit is None:
            st.t_first_submit = now
        if self.tracer:
            self.tracer.instant("arrival", "arrival", now,
                                track=f"session:{sid}", sid=sid,
                                index=event.index,
                                modality=event.modality, step=st.step)
        self._pending.append((sid, event.index, now))
        if self.deadline_s is None:
            return None
        if self.deadline_s <= 0.0:
            return self.flush()
        if now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        return None

    def _intake(self, sid: str, event: Event, payload,
                aggregate) -> SessionView:
        """Shared input-aggregation bookkeeping for both modes."""
        st = self.session(sid)
        st.step += 1
        m = event.modality
        old = st.inputs.get(m)
        st.inputs[m] = aggregate(old, payload) if aggregate else payload
        st.input_step[m] = st.step
        st.dirty.add(m)
        st.events_seen += 1
        self.events_total += 1
        return st

    def poll(self, now: Optional[float] = None) -> Optional[FlushReport]:
        """Flush if the oldest pending arrival has exceeded the
        deadline; also the idle hook where session eviction runs. No-op
        under tiered placement (nothing buffers there)."""
        if self.tiered:
            return None
        now = self.time_fn() if now is None else now
        if self._pending and self.deadline_s is not None \
                and now - self._pending[0][2] >= self.deadline_s:
            return self.flush()
        self.evict_sessions(now)
        return None

    def drain(self) -> Optional[FlushReport]:
        """Flush whatever is pending, deadline or not."""
        if self.tiered:
            return None
        return self.flush() if self._pending else None

    def pending_count(self) -> int:
        """Arrivals buffered but not yet flushed (the event-loop driver
        pumps poll() until this reaches zero)."""
        return len(self._pending)

    # ------------------------------------------------------------- flush

    def _cache_key(self, sid: str, model_name: str) -> str:
        return sid if self.share_encoders else f"{sid}:{model_name}"

    def _bucket_rows(self, n: int) -> int:
        return max(self.batch_bucket_min, next_pow2(n))

    def _consumers(self, m: str):
        return [(n, sm) for n, sm in self.models.items()
                if m in sm.modalities()]

    def _bucketed(self, m: str, x):
        return self.bucketer.fit(m, x) if self.bucketer else x

    def _encode_groups(self, sids):
        """Dirty (session, modality) work grouped by identical
        post-bucket shape: each group is one stacked encoder call.
        Modalities no model consumes are skipped BEFORE the bucketer
        sees them, so bucket/compile statistics count served groups
        only (an unconsumed modality used to inflate the histogram the
        bench reports)."""
        groups = defaultdict(list)  # (modality, shape) -> [(sid, payload, nat)]
        for sid in sids:
            st = self.sessions[sid]
            for m in sorted(st.dirty):
                if not self._consumers(m):
                    continue
                p = self._bucketed(m, st.inputs[m])
                shape = (tuple(p["x"].shape) if isinstance(p, dict)
                         else tuple(p.shape))
                groups[(m, shape)].append(
                    (st.sid, p, self._nat_len(st.inputs[m])))
        return groups

    @staticmethod
    def _nat_len(x) -> int:
        """Real (pre-padding) sequence length of a raw modality input:
        axis 1 for (B, S, ...) payloads, 1 for fixed-size vectors."""
        return int(x.shape[1]) if getattr(x, "ndim", 0) >= 2 else 1

    def _weight(self, name: str, key: str) -> float:
        """Parameter count of ``params[name][key]`` — the per-position
        weight of the padding-tax estimate in :class:`FlushReport`
        (1.0 when the subtree is not addressable)."""
        k = (name, key)
        w = self._flop_w.get(k)
        if w is None:
            p = self.params.get(name)
            sub = p.get(key) if isinstance(p, dict) else None
            w = float(sum(getattr(leaf, "size", 0)
                          for leaf in jax.tree_util.tree_leaves(sub))) or 1.0
            self._flop_w[k] = w
        return w

    def _run_encoder_chunk(self, m, sids, batch, upos, total_pos,
                           sync_targets):
        """Run every consuming model's encoder over one prepared batch
        (stacked or packed), scatter rows into the feature cache, and
        account the padding tax. Returns (n_calls, useful, padded)."""
        runners = (self._consumers(m)[:1] if self.share_encoders
                   else self._consumers(m))
        n, useful, padded = 0, 0.0, 0.0
        for name, sm in runners:
            feats = sm.encoders[m](self.params[name], batch)
            n += 1
            w = self._weight(name, m)
            useful += w * upos
            padded += w * (total_pos - upos)
            sync_targets.append(feats)
            for i, sid in enumerate(sids):
                st = self.sessions[sid]
                self.cache.put(self._cache_key(sid, name), m,
                               feats[i:i + 1], step=st.step, tier="glass")
        return n, useful, padded

    def _flush_encode(self, touched, sync_targets):
        """Bucketed encode: one stacked call per (modality, bucket[,
        chunk]) per consuming model."""
        n_enc, useful, padded = 0, 0.0, 0.0
        for (m, _shape), items in self._encode_groups(touched).items():
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                stacked = stack_bucketed([p for _, p, _ in chunk],
                                         self._bucket_rows(len(chunk)))
                lead = stacked["x"] if isinstance(stacked, dict) else stacked
                plen = lead.shape[1] if lead.ndim >= 2 else 1
                upos = sum(min(nat, plen) for _, _, nat in chunk)
                c, u, pd = self._run_encoder_chunk(
                    m, [sid for sid, _, _ in chunk], stacked, upos,
                    lead.shape[0] * plen, sync_targets)
                n_enc += c
                useful += u
                padded += pd
        return n_enc, useful, padded

    def _flush_encode_ragged(self, touched, sync_targets):
        """Ragged encode: ONE packed call per variable-length modality
        (per chunk, per consuming model) regardless of how many length
        buckets are live; fixed-size modalities keep the stacked path."""
        n_enc, useful, padded = 0, 0.0, 0.0
        ragged_mods = defaultdict(list)      # m -> [(sid, raw, nat)]
        fixed = defaultdict(list)            # (m, shape) -> [(sid, raw, nat)]
        for sid in touched:
            st = self.sessions[sid]
            for m in sorted(st.dirty):
                if not self._consumers(m):
                    continue
                x = st.inputs[m]
                if m in ("text", "vitals"):
                    ragged_mods[m].append((st.sid, x, self._nat_len(x)))
                else:
                    fixed[(m, tuple(x.shape))].append(
                        (st.sid, x, self._nat_len(x)))
        for m, items in sorted(ragged_mods.items()):
            cap = self.ragged.max_lengths.get(m)
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                packed = self.ragged.pack(m, [x for _, x, _ in chunk])
                total = (packed["tokens"] if m == "text"
                         else packed["x"]).shape[1]
                upos = sum(nat if cap is None else min(nat, cap)
                           for _, _, nat in chunk)
                c, u, pd = self._run_encoder_chunk(
                    m, [sid for sid, _, _ in chunk], packed, upos, total,
                    sync_targets)
                n_enc += c
                useful += u
                padded += pd
        for (m, _shape), items in sorted(fixed.items()):
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                stacked = stack_bucketed([x for _, x, _ in chunk],
                                         self._bucket_rows(len(chunk)))
                rows = (stacked["x"] if isinstance(stacked, dict)
                        else stacked).shape[0]
                c, u, pd = self._run_encoder_chunk(
                    m, [sid for sid, _, _ in chunk], stacked, len(chunk),
                    rows, sync_targets)
                n_enc += c
                useful += u
                padded += pd
        return n_enc, useful, padded

    def _flush_tails(self, tail_groups, sync_targets):
        """One batched tail call per selected model (per chunk)."""
        n_tail, useful, padded = 0, 0.0, 0.0
        emitted = []      # (sid, name, modalities, outputs, step)
        for name, items in tail_groups.items():
            sm = self.models[name]
            mods = sm.modalities()
            w = self._weight(name, "heads")
            for c0 in range(0, len(items), self.max_coalesce):
                chunk = items[c0:c0 + self.max_coalesce]
                sids = [sid for sid, _ in chunk]
                stacked = {mm: stack_bucketed([f[mm] for _, f in chunk],
                                              self._bucket_rows(len(chunk)))
                           for mm in mods}
                outs = sm.tail(self.params[name], stacked)
                n_tail += 1
                rows = next(iter(stacked.values())).shape[0]
                useful += w * len(chunk)
                padded += w * (rows - len(chunk))
                sync_targets.append(outs)
                for i, sid in enumerate(sids):
                    st = self.sessions[sid]
                    row = jax.tree.map(lambda a: a[i:i + 1], outs)
                    emitted.append((sid, name, tuple(mods), row, st.step))
                    for mm in mods:   # the result carries the cache back
                        self.cache.touch(self._cache_key(sid, name), mm,
                                         st.step)
        return n_tail, emitted, useful, padded

    def _grouped_tail_target(self, tail_groups) -> Optional[str]:
        """The ONE grouped tail is legal when a full-fusion model exists,
        declares its feature widths, and every pending model shares its
        parameter pytree (``share_encoders`` zoos): subset heads are
        then row-slices of the full heads, so a zero-filled slice for a
        missing modality contributes exactly zero to the fusion GEMM and
        the full tail reproduces every subset tail bit-for-bit. Returns
        the full model's name, or None to keep the per-model loop."""
        full_name = next((n for n, sm in self.models.items()
                          if frozenset(sm.modalities()) == self.full_set),
                         None)
        if full_name is None:
            return None
        dims = self.models[full_name].module.feature_dims
        if not all(m in dims for m in self.full_set):
            return None
        if not all(self.params[n] is self.params[full_name]
                   for n in tail_groups):
            return None
        return full_name

    def _flush_tails_grouped(self, tail_groups, full_name, sync_targets):
        """ONE stacked tail call for every pending (session, subset) —
        flush then issues O(modalities) + 1 kernels instead of
        O(modalities x buckets) + O(subsets). Each row is the full-width
        F_C with zeros in the slices of modalities outside that row's
        subset; the padding-tax account charges those zero slices as
        padding."""
        full_sm = self.models[full_name]
        full_mods = full_sm.modalities()
        dims = full_sm.module.feature_dims
        fullw = float(sum(dims[m] for m in full_mods))
        w = self._weight(full_name, "heads")
        rows = [(sid, name, f)
                for name, items in tail_groups.items()
                for sid, f in items]
        n_tail, useful, padded = 0, 0.0, 0.0
        emitted = []
        for c0 in range(0, len(rows), self.max_coalesce):
            chunk = rows[c0:c0 + self.max_coalesce]
            nb = self._bucket_rows(len(chunk))
            stacked = {
                m: stack_bucketed(
                    [f.get(m, jnp.zeros((1, dims[m]), jnp.float32))
                     for _, _, f in chunk], nb)
                for m in full_mods}
            outs = full_sm.tail(self.params[full_name], stacked)
            n_tail += 1
            sync_targets.append(outs)
            subw = sum(sum(dims[m] for m in self.models[name].modalities())
                       for _, name, _ in chunk) / fullw
            useful += w * subw
            padded += w * (nb - subw)
            for i, (sid, name, _f) in enumerate(chunk):
                st = self.sessions[sid]
                row = jax.tree.map(lambda a: a[i:i + 1], outs)
                mods = self.models[name].modalities()
                emitted.append((sid, name, tuple(mods), row, st.step))
                for mm in mods:
                    self.cache.touch(self._cache_key(sid, name), mm, st.step)
        return n_tail, emitted, useful, padded

    def flush(self) -> FlushReport:
        """Run all pending work: one batched encoder call per
        (modality, bucket[, chunk]) per consuming model (ONE total with
        ``share_encoders``), scatter rows into the feature cache, one
        batched tail per selected model, emit progressive predictions,
        sync the host ONCE."""
        if self.tiered:
            raise RuntimeError(
                "flush() is a flush-mode operation; tiered placement "
                "processes each arrival in submit()")
        t0 = self.time_fn()
        sync_targets = []
        # every dirty marking comes with a _pending entry, so only the
        # pending sessions can have work — never scan the whole (ever-
        # growing) session table on the latency-critical path
        touched = sorted({sid for sid, _, _ in self._pending})

        # ---- batched encode + scatter rows into the feature cache
        if self.ragged is not None:
            n_enc, enc_u, enc_p = self._flush_encode_ragged(touched,
                                                            sync_targets)
        else:
            n_enc, enc_u, enc_p = self._flush_encode(touched, sync_targets)

        # ---- progressive re-fusion: batched tails per selected model
        tail_groups = defaultdict(list)    # model name -> [(sid, feats)]
        consumed_meta: Dict[Tuple[str, str], dict] = {}
        for sid in touched:
            st = self.sessions[sid]
            if not st.dirty:
                continue
            st.dirty.clear()
            name = select_model(self.models, st.inputs)
            if name is None:
                continue
            sm = self.models[name]
            feats = self.cache.features(self._cache_key(st.sid, name),
                                        sm.modalities(),
                                        input_steps=st.input_step)
            if feats is not None:
                tail_groups[name].append((st.sid, feats))
                if self.tracer:
                    # snapshot source steps BEFORE the tail path
                    # re-stamps them via cache.touch
                    key = self._cache_key(st.sid, name)
                    consumed_meta[(st.sid, name)] = {
                        m: [self.cache.peek(key, m).step,
                            st.input_step.get(m, 0)]
                        for m in sm.modalities()}

        full_name = (self._grouped_tail_target(tail_groups)
                     if self.ragged is not None and tail_groups else None)
        if full_name is not None:
            n_tail, emitted, tail_u, tail_p = self._flush_tails_grouped(
                tail_groups, full_name, sync_targets)
        else:
            n_tail, emitted, tail_u, tail_p = self._flush_tails(
                tail_groups, sync_targets)

        # ---- the ONE host sync of this flush
        jax.block_until_ready(sync_targets)
        t1 = self.time_fn()

        flush_id = self.flushes_total
        predictions, recommendations = [], {}
        for sid, name, mods, row, step in emitted:
            kind = "final" if frozenset(mods) == self.full_set else "partial"
            pred = Prediction(sid=sid, step=step, model=name,
                              modalities=mods, kind=kind, outputs=row,
                              flush_id=flush_id, t_emit=t1)
            st = self.sessions[sid]
            self._record_prediction(st, pred)
            predictions.append(pred)
            recommendations[sid] = row
            if self.tracer:
                key = self._cache_key(sid, name)
                self.tracer.instant(
                    "fuse", "fusion", t1, track=f"session:{sid}",
                    sid=sid, key=key, model=name, step=step,
                    consumed=consumed_meta.get((sid, name), {}))
                self.tracer.instant(
                    "emit", "predict", t1, track=f"session:{sid}",
                    sid=sid, key=key, model=name, step=step, kind=kind,
                    modalities=sorted(mods))

        # keyed by arrival with the EARLIEST submit kept: a duplicate
        # submission of the same (sid, idx) used to overwrite the first
        # latency entry and double-count n_events
        arrived: Dict[Tuple[str, int], float] = {}
        for sid, idx, ts in self._pending:
            arrived.setdefault((sid, idx), ts)
        latencies = {key: t1 - ts for key, ts in arrived.items()}
        report = FlushReport(
            flush_id=flush_id, n_events=len(arrived),
            n_encoder_calls=n_enc, n_tail_calls=n_tail, wall_s=t1 - t0,
            latencies=latencies, predictions=predictions,
            recommendations=recommendations,
            flops_useful=enc_u + tail_u, flops_padded=enc_p + tail_p)
        if self.tracer:
            for (sid, idx), ts in arrived.items():
                self.tracer.span("queue.wait", "queue", ts, t0,
                                 track=f"session:{sid}", sid=sid,
                                 index=idx)
            self.tracer.span("flush", "flush", t0, t1, track="engine",
                             flush_id=flush_id, n_events=len(arrived),
                             n_encoder_calls=n_enc, n_tail_calls=n_tail)
        self.metrics.inc("engine.flushes")
        self.metrics.inc("engine.flush_events", len(arrived))
        self.metrics.observe("flush.wall_s", t1 - t0)
        for lat in latencies.values():
            self.metrics.observe("serve.latency_s", lat)
        self._pending.clear()
        self.flushes.append(report)
        if self.max_history is not None:
            del self.flushes[:-self.max_history]
        self.flushes_total += 1
        self._enc_calls_total += n_enc
        self._tail_calls_total += n_tail
        self.evict_sessions(t1)
        return report

    def _record_prediction(self, st: SessionView, pred: Prediction):
        """Session-side bookkeeping shared by flush- and tiered-mode
        emissions."""
        st.predictions.append(pred)
        if self.max_history is not None:
            del st.predictions[:-self.max_history]
        st.last_recommendation = pred.outputs
        st.t_last_activity = pred.t_emit if self.tiered else self.time_fn()
        if pred.kind == "final":
            st.finalized = True
            if st.t_final_prediction is None:
                st.t_final_prediction = pred.t_emit
        if st.t_first_prediction is None:
            st.t_first_prediction = pred.t_emit
            if not self.tiered and st.t_first_submit is not None:
                self.metrics.observe("serve.ttfp_s",
                                     pred.t_emit - st.t_first_submit)

    # ---------------------------------------------------------- eviction

    def _evict(self, sid: str):
        keys = ([sid] if self.share_encoders
                else [f"{sid}:{n}" for n in self.models])
        for key in keys:
            self.cache.drop_session(key)
        if self.tiered:
            # forget every tier replica's versions too: a re-created
            # session restarts its version counters at 0, and a stale
            # high-water mark would wrongly skip re-shipping features
            dropped = set(keys)
            for versions in self._replica_versions.values():
                for k in [k for k in versions if k[0] in dropped]:
                    del versions[k]
        del self.sessions[sid]
        self.metrics.inc("engine.evicted_sessions")
        if self.tracer:
            self.tracer.instant("evict", "session", track="engine",
                                sid=sid, keys=keys)

    def evict_sessions(self, now: Optional[float] = None) -> int:
        """Cross-incident eviction sweep; returns how many sessions
        left. A session is evictable only when it has no pending
        arrivals and no un-flushed dirty modalities — eviction never
        drops work. Idle timeout first, then LRU down to
        ``max_sessions``: least-recently-active leaves first, so a
        finalized incident that is still streaming updates outlives an
        abandoned partial one (finalized only breaks activity ties)."""
        if self.idle_timeout_s is None and self.max_sessions is None:
            return 0
        now = self.time_fn() if now is None else now
        pending_sids = {sid for sid, _, _ in self._pending}
        evictable = [st for sid, st in self.sessions.items()
                     if sid not in pending_sids and not st.dirty]
        n0 = self.evicted_count
        if self.idle_timeout_s is not None:
            for st in list(evictable):
                last = (st.t_last_activity if st.t_last_activity is not None
                        else st.t_first_submit)
                if last is not None and now - last >= self.idle_timeout_s:
                    self._evict(st.sid)
                    evictable.remove(st)
        if self.max_sessions is not None \
                and len(self.sessions) > self.max_sessions:
            evictable.sort(key=lambda st: (st.t_last_activity or 0.0,
                                           not st.finalized))
            excess = len(self.sessions) - self.max_sessions
            for st in evictable[:excess]:
                self._evict(st.sid)
        return self.evicted_count - n0

    # ==================================================================
    # Tiered placement path (per-arrival on the simulated tier clocks)
    # ==================================================================

    # ----- legacy 2-tier accessor surface (maps onto the fastest remote)

    @property
    def glass(self) -> TierHost:
        return self.hosts[self.local_name]

    @property
    def edge(self) -> TierHost:
        return self.hosts[self._primary]

    @property
    def uplink(self):
        return self.fabric.channel(self.local_name, self._primary)

    @property
    def downlink(self):
        return self.fabric.channel(self._primary, self.local_name)

    @property
    def crash_at(self) -> Optional[float]:
        return self._faults[self._primary].crash_at

    @property
    def detect_at(self) -> Optional[float]:
        return self._faults[self._primary].detect_at

    @property
    def edge_known_dead(self) -> bool:
        return self._faults[self._primary].dead

    @property
    def _edge_versions(self) -> Dict[Tuple[str, str], int]:
        return self._replica_versions[self._primary]

    # ----- fault injection / detection / rejoin

    def inject_crash(self, t: float, tier: Optional[str] = None, *,
                     rejoin_at: Optional[float] = None):
        """Tier ``tier`` (default: the fastest remote) dies at simulated
        time ``t``. The glasses learn of it at the first missed
        heartbeat strictly after ``t``. With ``rejoin_at``, a restarted
        box comes back at that time: it re-warms its feature-cache
        replica from the glass-side versioned cache and becomes eligible
        for placement again."""
        tier = self._primary if tier is None else tier
        f = self._faults[tier]
        f.crash_at = t
        period = self.monitors[tier].period
        f.detect_at = (math.floor(t / period) + 1) * period
        if self.tracer:
            self.tracer.instant("crash.inject", "fault", t,
                                track=f"host:{tier}", tier=tier,
                                detect_at=f.detect_at,
                                rejoin_at=rejoin_at)
        if rejoin_at is not None:
            self.schedule_rejoin(rejoin_at, tier)

    def inject_edge_crash(self, t: float):
        self.inject_crash(t)

    def inject_schedule(self, schedule):
        """Install a multi-cycle crash/rejoin schedule (an iterable of
        :class:`repro.serving.chaos.FaultEvent`, e.g. from
        ``chaos_schedule``). The first cycle of each tier arms
        immediately; each subsequent cycle arms when the previous one's
        rejoin completes, so repeated crash -> re-dispatch/fallback ->
        rejoin -> re-warm rounds replay on the simulated clock."""
        from repro.serving.chaos import validate_schedule
        entries = validate_schedule(list(schedule))
        unknown = {e.tier for e in entries} - set(self.remote_names)
        if unknown:
            raise ValueError(f"schedule names unknown tier(s) "
                             f"{sorted(unknown)}; remotes are "
                             f"{self.remote_names}")
        for e in entries:
            self._schedule.setdefault(e.tier, deque()).append(e)
        for n in list(self._schedule):
            if self._faults[n].crash_at is None:
                self._install_next_fault(n)

    def _install_next_fault(self, tier: str):
        q = self._schedule.get(tier)
        if q:
            e = q.popleft()
            self.inject_crash(e.crash_at, tier, rejoin_at=e.rejoin_at)

    def schedule_rejoin(self, t: float, tier: Optional[str] = None):
        tier = self._primary if tier is None else tier
        f = self._faults[tier]
        if f.crash_at is not None and t <= f.crash_at:
            raise ValueError(f"rejoin at {t} precedes the crash at "
                             f"{f.crash_at}")
        f.rejoin_at = t

    def _mark_dead(self, tier: str):
        self._faults[tier].dead = True
        self._replica_versions[tier].clear()   # that replica is gone
        self.metrics.inc("fault.crashes_detected")
        if self.tracer:
            f = self._faults[tier]
            self.tracer.instant(
                "crash.detect", "fault",
                f.detect_at if f.detect_at is not None else self.tracer.now(),
                track=f"host:{tier}", tier=tier, crash_at=f.crash_at)

    def _rejoin(self, tier: str, t: float):
        """A restarted tier comes back: fresh fault state, fresh busy
        clock, and a replica re-warm shipped from the glass-side
        versioned cache (one bulk message on its link at the rejoin
        instant), after which it is placement-eligible again."""
        self._faults[tier] = _TierFault()
        host = self.hosts[tier]
        # a restarted box boots idle: anything still on its clock is
        # phantom occupancy from flights the crash already lost
        host.free_at = t
        versions = self._replica_versions[tier]
        warm_b = 0
        for (key, m), e in self.cache.entries():
            if versions.get((key, m), -1) < e.version:
                warm_b += payload_nbytes(e.feature)
                versions[(key, m)] = e.version
        if warm_b:
            self.fabric.channel(self.local_name, tier).send(warm_b, t)
        self.metrics.inc("placement.rejoins")
        if self.tracer:
            self.tracer.instant("rejoin", "fault", t,
                                track=f"host:{tier}", tier=tier,
                                warm_bytes=warm_b)

    def _usable_remotes(self, now: float) -> List[str]:
        """Remote tiers a decision made at ``now`` may target, applying
        any heartbeat detection or restart the clock has crossed. Under
        a chaos schedule, a rejoin arms the tier's NEXT scheduled cycle
        and the loop re-checks — several crash/rejoin rounds may have
        elapsed between two arrivals."""
        out = []
        for n in self.remote_names:
            while True:
                f = self._faults[n]
                if not f.dead and f.detect_at is not None \
                        and now >= f.detect_at:
                    self._mark_dead(n)
                if f.dead and f.rejoin_at is not None \
                        and now >= f.rejoin_at:
                    self._rejoin(n, f.rejoin_at)
                    self._install_next_fault(n)
                    continue
                break
            if not self._faults[n].dead:
                out.append(n)
        return out

    def _dies_before(self, tier: str, t: float) -> bool:
        """Does ``tier`` crash before simulated time ``t``? (A sender
        must survive through the END of its own transmission.)"""
        f = self._faults.get(tier)
        return (f is not None and f.crash_at is not None
                and f.crash_at < t)

    def _queues(self, now: float) -> Optional[Dict[str, float]]:
        """Per-host queueing delay feeding contention-aware decisions
        (None = the contention-blind paper rule)."""
        if not self.contention_aware:
            return None
        return {n: max(0.0, h.free_at - now)
                for n, h in self.hosts.items()}

    def _payload_bytes(self, m: str, payload) -> int:
        """Raw sensor bytes for the uplink: the module's declared size
        (audio clip / camera frame, not the tokenized tensor) when
        available, else the actual array bytes."""
        for _n, sm in self._consumers(m):
            b = sm.module.payload_bytes.get(m)
            if b:
                return b
        return payload_nbytes(payload)

    def _enc_duration(self, m: str, n_runners: int, host: TierHost,
                      precision: str = "fp32") -> float:
        """Simulated seconds the tier spends encoding modality ``m`` for
        ``n_runners`` consuming models: expensive text encoders run in
        parallel, cheap ones serially (paper Fig. 8-right — matching
        ``core.engine.EMSServe``). int8 flights scale by the SAME
        ``int8_compute_scale`` the placement estimate used, so the
        decision and the booking agree."""
        per = host.time(f"enc:{m}")
        if precision == "int8":
            per *= self.int8_compute_scale
        return per if m == "text" else per * n_runners

    def _feat_bytes_est(self, m: str) -> int:
        """A-priori fp32 size of modality ``m``'s encoded feature (the
        declared feature width x 4 bytes) — what the joint precision
        enumeration scales by ``int8_bytes_scale`` BEFORE the encoder
        has run. Real flights then ship the real packed bytes."""
        for _n, sm in self._consumers(m):
            d = sm.module.feature_dims.get(m)
            if d:
                return 4 * int(d)
        return 0

    # ----------------------------------------------------- real numerics
    #
    # The numerics are split into run / commit phases so the edge fault
    # path can execute the real jitted calls (placement never changes
    # the math) yet leave the glass-side cache untouched when the edge
    # dies before its result makes it back.

    def _quantized_params(self, name: str) -> dict:
        """The int8 sidecar pytree for model ``name``, derived lazily
        and cached per DISTINCT fp32 pytree (id()-keyed): a
        share_encoders zoo whose subsets all alias one parameter pytree
        quantizes once total. The sidecar's fp32 leaves are shared by
        reference with the source, so nothing doubles in memory but the
        int8 weights themselves."""
        src = self.params[name]
        qp = self._qparams_cache.get(id(src))
        if qp is None:
            qp = self._qparams_cache[id(src)] = \
                self.models[name].quantize_params(src)
        return qp

    def _run_encoders(self, st: SessionView, m: str,
                      precision: str = "fp32") -> Dict[str, object]:
        """Real jitted encoder run(s) for the arriving modality; returns
        ``{model_name: feature}`` WITHOUT touching the cache. An int8
        flight runs the SAME jitted encoder over the sidecar pytree
        (``layers.dense`` dispatches on the leaf form) and returns the
        packed ``{"q", "scale"}`` wire form — what the cache commits
        and the downlink sizes."""
        consumers = self._consumers(m)
        if not consumers:
            return {}
        runners = consumers[:1] if self.share_encoders else consumers
        enc_in = self._bucketed(m, st.inputs[m])
        if precision == "int8":
            return {name: quantize_feature(
                        sm.encoders[m](self._quantized_params(name), enc_in))
                    for name, sm in runners}
        return {name: sm.encoders[m](self.params[name], enc_in)
                for name, sm in runners}

    def _commit_features(self, st: SessionView, m: str, feats, tier: str):
        for name, feat in feats.items():
            self.cache.put(self._cache_key(st.sid, name), m, feat,
                           step=st.step, tier=tier)

    def _gather(self, st: SessionView, model_name: str, m: str, feats):
        """The selected model's input features — the arriving modality
        from the fresh (possibly uncommitted) ``feats``, everything else
        from the glass cache with the <=1-step staleness invariant
        asserted on every read. None while the subset is incomplete."""
        sm = self.models[model_name]
        key = self._cache_key(st.sid, model_name)
        fresh = (next(iter(feats.values()), None) if self.share_encoders
                 else feats.get(model_name))
        out = {}
        consumed = {}
        # packed int8 features (fresh or cached) unpack here, at the
        # consuming tier, right before fusion; raw features pass through
        # untouched (dequantize_feature is the identity on them)
        for mm in sm.modalities():
            if mm == m and fresh is not None:
                out[mm] = dequantize_feature(fresh)
                # the fresh feature carries this very step; its commit
                # lands before the fuse is recorded
                consumed[mm] = [st.step, st.input_step.get(mm, st.step)]
                continue
            e = self.cache.get(key, mm, input_step=st.input_step.get(mm))
            if e is None:
                return None
            out[mm] = dequantize_feature(e.feature)
            consumed[mm] = [e.step, st.input_step.get(mm, e.step)]
        if self.tracer:
            self._last_consumed = consumed
        return out

    def _touch_consumed(self, st: SessionView, model_name: str):
        """The result carries the cache back (paper fault tolerance):
        re-stamp every consumed entry at this step."""
        key = self._cache_key(st.sid, model_name)
        for mm in self.models[model_name].modalities():
            self.cache.touch(key, mm, st.step)

    # ------------------------------------------------------------- event

    def _submit_tiered(self, sid: str, event: Event, payload, *,
                       aggregate=None) -> TieredRecord:
        """Process one arriving datum end to end: decide a tier per
        submodule, encode there, transport, re-fuse, emit on glass. With
        the stream policy's ``glass_partials``, an offloaded arrival
        also yields an immediate on-glass provisional partial from
        cached features."""
        prev_observed = set(self.session(sid).inputs)
        st = self._intake(sid, event, payload, aggregate)
        st.dirty.clear()        # per-arrival mode: nothing buffers

        t_a = event.arrival_time
        if st.t_first_arrival is None:
            st.t_first_arrival = t_a
        now = max(t_a, st.ready_at)
        sess = f"session:{sid}"
        if self.tracer:
            self.tracer.set_time(now)
            self.tracer.instant("arrival", "arrival", t_a, track=sess,
                                sid=sid, index=event.index,
                                modality=event.modality, step=st.step)
            if now > t_a:
                # per-session in-order processing: this arrival waits
                # for the previous record's emit
                self.tracer.span("queue.wait", "queue", t_a, now,
                                 track=sess, sid=sid, index=event.index)
        model_name = select_model(self.models, st.inputs)
        payload_b = self._payload_bytes(event.modality, st.inputs[event.modality])
        avail = self._usable_remotes(now)
        queues = self._queues(now)
        dec = self.policy.decide(f"enc:{event.modality}", payload_b, now,
                                 queues=queues, available=avail,
                                 lateness_s=max(0.0, now - t_a),
                                 feat_bytes=self._feat_bytes_est(
                                     event.modality))
        if self.tracer:
            # the precision attr only appears when the joint rung is
            # armed, so precision-less traces stay byte-identical
            extra = ({"precision": dec.precision}
                     if self.policy.precisions is not None else {})
            self.tracer.instant("decide", "placement", now, track=sess,
                                sid=sid, submodule=f"enc:{event.modality}",
                                tier=dec.tier, speculate=dec.speculate,
                                best_remote=dec.best_remote, **extra)

        partial = None
        if dec.speculate and dec.best_remote is not None:
            # deadline margin too thin to trust the estimate: race glass
            # against the best remote, commit the first result, cancel
            # the loser. The glass racer IS the immediate answer, so no
            # separate provisional partial; the race also supersedes
            # tail splitting (both racers run encoder+tail co-located).
            rec = self._race_event(st, event, model_name, payload_b,
                                   now, dec, dec.best_remote)
        else:
            if dec.tier != self.local_name and self.glass_partials:
                partial = self._glass_provisional(st, prev_observed, now)
            if self.tail_placement:
                rec = self._placed_event(st, event, model_name, payload_b,
                                         now, dec, avail, queues,
                                         prev_observed)
            elif dec.tier != self.local_name:
                rec = self._remote_event(st, event, model_name, payload_b,
                                         now, dec, dec.tier)
            else:
                rec = self._glass_event(st, event, model_name, now, dec)
        if partial is not None:
            rec.glass_partial = partial

        st.ready_at = rec.t_emit
        st.t_last_activity = rec.t_emit        # simulated clock
        st.records.append(rec)
        self.records.append(rec)
        if self.max_history is not None:
            del st.records[:-self.max_history]
            del self.records[:-self.max_history]
        self._total_latency += rec.latency_s
        self.metrics.observe("serve.latency_s", rec.latency_s)
        if self.tracer:
            self.tracer.span(
                f"{rec.modality}#{rec.index}", "lifecycle",
                rec.t_arrival, rec.t_emit, track=sess, sid=sid,
                index=rec.index, modality=rec.modality,
                enc_tier=rec.enc_tier, tail_tier=rec.tail_tier,
                kind=rec.kind, fallback=rec.fallback,
                speculative=rec.speculative, detect_s=rec.detect_s)
        if rec.outputs is not None:
            if st.t_first_emit is None:
                st.t_first_emit = rec.t_emit
                if st.t_first_arrival is not None:
                    self.metrics.observe("serve.ttfp_s",
                                         rec.t_emit - st.t_first_arrival)
            if rec.kind == "final" and st.t_final_emit is None:
                st.t_final_emit = rec.t_emit
            if self.tracer:
                key = self._cache_key(sid, rec.model)
                self.tracer.instant(
                    "fuse", "fusion", rec.t_emit, track=sess, sid=sid,
                    key=key, model=rec.model, step=st.step,
                    consumed=self._last_consumed)
                self.tracer.instant(
                    "emit", "predict", rec.t_emit, track=sess, sid=sid,
                    key=key, model=rec.model, step=st.step, kind=rec.kind,
                    modalities=sorted(self.models[rec.model].modalities()))
            if self.stream_policy is not None:
                self._record_prediction(st, Prediction(
                    sid=st.sid, step=st.step, model=rec.model,
                    modalities=tuple(self.models[rec.model].modalities()),
                    kind=rec.kind, outputs=rec.outputs, flush_id=-1,
                    t_emit=rec.t_emit))
        # cross-incident eviction on the SIMULATED clock (every activity
        # timestamp in this mode is a t_emit, so wall-clock poll() must
        # not sweep here — the per-arrival hook is the only safe one)
        self.evict_sessions(rec.t_emit)
        return rec

    def _glass_provisional(self, st: SessionView, prev_observed: set,
                           now: float) -> Optional[Prediction]:
        """Stream x tiered composition: while the edge refreshes the
        arriving modality, the glasses immediately re-fuse what they
        already hold — every feature read from the cache with the
        <=1-step staleness invariant asserted (the arriving modality's
        cached feature is exactly one step behind its input now, the
        paper's tolerated bound). Tagged ``partial`` always: it never
        reflects the newest datum. No cache touch — provisional serving
        must not mask real staleness from later reads."""
        name = select_model(self.models, prev_observed)
        if name is None:
            return None
        sm = self.models[name]
        feats = self.cache.features(self._cache_key(st.sid, name),
                                    sm.modalities(),
                                    input_steps=st.input_step)
        if feats is None:
            return None
        feats = {mm: dequantize_feature(f) for mm, f in feats.items()}
        outputs = sm.tail(self.params[name], feats)
        _start, done = self.glass.occupy(self.glass.time("tail"), now,
                                         label="tail@glass:provisional")
        pred = Prediction(sid=st.sid, step=st.step, model=name,
                          modalities=tuple(sm.modalities()), kind="partial",
                          outputs=outputs, flush_id=-1, t_emit=done)
        self._record_prediction(st, pred)
        if self.tracer:
            key = self._cache_key(st.sid, name)
            sess = f"session:{st.sid}"
            consumed = {mm: [self.cache.peek(key, mm).step,
                             st.input_step.get(mm, 0)]
                        for mm in sm.modalities()}
            self.tracer.instant("fuse", "fusion", done, track=sess,
                                sid=st.sid, key=key, model=name,
                                step=st.step, consumed=consumed,
                                provisional=True)
            self.tracer.instant("emit", "predict", done, track=sess,
                                sid=st.sid, key=key, model=name,
                                step=st.step, kind="partial",
                                modalities=sorted(sm.modalities()),
                                provisional=True)
        if st.t_first_emit is None or done < st.t_first_emit:
            if st.t_first_emit is None and st.t_first_arrival is not None:
                self.metrics.observe("serve.ttfp_s",
                                     done - st.t_first_arrival)
            st.t_first_emit = done
        return pred

    def _kind(self, model_name: Optional[str]) -> str:
        if model_name is None:
            return "partial"
        mods = frozenset(self.models[model_name].modalities())
        return "final" if mods == self.full_set else "partial"

    def _sync_bytes(self, tier: str, st: SessionView,
                    model_name: Optional[str], *, skip: str):
        """Bytes needed to bring ``tier``'s replica up to date on every
        cached feature the selected model consumes (except ``skip``, the
        freshly arriving modality), plus the (replica key, version)
        pairs to stamp once the path succeeds."""
        sync_b, synced = 0, []
        if model_name is not None:
            versions = self._replica_versions[tier]
            key = self._cache_key(st.sid, model_name)
            for mm in self.models[model_name].modalities():
                if mm == skip:
                    continue
                e = self.cache.peek(key, mm)
                if e is not None and \
                        versions.get((key, mm), -1) < e.version:
                    sync_b += payload_nbytes(e.feature)
                    synced.append(((key, mm), e.version))
        return sync_b, synced

    def _stamp_fresh(self, tier: str, st: SessionView, m: str):
        """``tier``'s replica now holds the fresh feature(s) of ``m``."""
        versions = self._replica_versions[tier]
        for name in self.models:
            key = self._cache_key(st.sid, name)
            e = self.cache.peek(key, m)
            if e is not None:
                versions[(key, m)] = e.version

    def _crash_fallback(self, tier: str, st: SessionView, event: Event,
                        model_name: Optional[str], now: float,
                        dec: TierDecision, *, payload_b: Optional[int] = None,
                        feats=None, outputs=None) -> TieredRecord:
        """A remote participant died before its transmission completed:
        mark it dead at the first missed heartbeat, then re-dispatch the
        lost flight. With ``redispatch`` on and a surviving remote
        available, the flight goes to the next-best surviving remote
        (a fresh placement decision at the detection instant, restricted
        to survivors); otherwise — or when the policy rung is off — it
        re-runs on glass. Either way the already-computed numerics are
        reused: placement never changes the math, so the re-run's
        arrays are the in-flight ones. Cascading crashes recurse — a
        re-dispatch target that also dies falls through again until a
        survivor (ultimately glass) emits."""
        t_detect = max(now, self._faults[tier].detect_at)
        self._mark_dead(tier)
        detect_s = max(0.0, t_detect - now)
        if self.redispatch and payload_b is not None:
            survivors = self._usable_remotes(t_detect)
            if survivors:
                dec2 = self.policy.decide(
                    f"enc:{event.modality}", payload_b, t_detect,
                    queues=self._queues(t_detect), available=survivors)
                B = dec2.best_remote
                if B is not None:
                    # the re-aimed flight reuses the dead tier's
                    # already-computed arrays, so it keeps the original
                    # decision's precision whatever the survivors prefer
                    dec2 = replace(dec2, precision=dec.precision)
                    self.metrics.inc("placement.redispatches")
                    if self.tracer:
                        self.tracer.instant(
                            "redispatch", "fault", t_detect,
                            track=f"session:{st.sid}", sid=st.sid,
                            from_tier=tier, to_tier=B)
                    return self._remote_event(
                        st, event, model_name, payload_b, t_detect, dec2,
                        B, feats=feats, outputs=outputs, fallback=True,
                        detect_s=detect_s)
        return self._glass_event(st, event, model_name, t_detect, dec,
                                 fallback=True, detect_s=detect_s,
                                 feats=feats, outputs=outputs)

    def _race_event(self, st: SessionView, event: Event,
                    model_name: Optional[str], payload_b: int,
                    now: float, dec: TierDecision, A: str) -> TieredRecord:
        """Speculative dual placement (cancel-on-commit): dispatch the
        arriving submodule on glass AND remote ``A`` simultaneously,
        commit whichever result reaches the glasses first, and cancel
        the loser at the commit instant — its in-flight transfer never
        delivers, its un-run compute is released, and nothing of it
        ever commits (the cache would refuse the late duplicate
        anyway: same step, structural no-op). The numerics run ONCE —
        both racers share the same arrays, so the committed result is
        bit-equal to the monolithic reference whichever side wins. A
        remote crash mid-race is absorbed with NO detection stall: the
        glass racer is already running, so the EMT pays the glass
        latency instead of the missed-heartbeat timeout (counted in
        ``spec_crash_saves``, not as a fallback)."""
        m = event.modality
        local = self.local_name
        host = self.hosts[A]
        up_ch = self.fabric.channel(local, A)
        down_ch = self.fabric.channel(A, local)

        # ---- real numerics once; the racers share the arrays (and the
        # decision's precision — it is a property of the flight, not of
        # either host, so the committed result is identical whichever
        # side wins)
        feats = self._run_encoders(st, m, dec.precision)
        outputs = None
        if model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)

        # ---- glass racer: always booked (the hedge that cannot crash)
        g_dur = (self._enc_duration(m, len(feats), self.glass,
                                    dec.precision)
                 if feats else 0.0)
        if outputs is not None:
            g_dur += self.glass.time("tail")
        g_start, g_done = self.glass.occupy(g_dur, now)

        # ---- remote racer: the uplink truly dispatches; compute and
        # downlink are PLANNED via eta() so a loss unwinds cleanly
        sync_b, synced = self._sync_bytes(A, st, model_name, skip=m)
        up = up_ch.send(payload_b + sync_b, now)
        r_dur = (self._enc_duration(m, len(feats), host, dec.precision)
                 if feats else 0.0)
        if outputs is not None:
            r_dur += host.time("tail")
        down_b = sum(payload_nbytes(f) for f in feats.values())
        if outputs is not None:
            down_b += payload_nbytes(outputs)
        r_done = max(up.t_deliver, host.free_at) + r_dur
        r_emit = down_ch.eta(down_b, r_done)
        crashed = self._dies_before(A, r_emit)

        # tie -> local: offloading must strictly win (the legacy rule)
        glass_wins = crashed or g_done <= r_emit
        self.metrics.inc("speculation.races")
        if self.tracer:
            self.tracer.instant("race.start", "speculation", now,
                                track=f"session:{st.sid}", sid=st.sid,
                                remote=A, glass_done=g_done,
                                remote_emit=r_emit, crashed=crashed)
        stamp_fresh_remote = False

        if glass_wins:
            stop = g_done                        # the commit instant
            if up.t_deliver > stop:
                # payload still in flight at commit: the wire frees now
                # and the remote never computes
                up_ch.cancel(up.flight, t=stop)
            else:
                rs, rd = host.occupy(r_dur, up.t_deliver)
                cut = (min(stop, self._faults[A].crash_at) if crashed
                       else stop)
                host.release(rs, rd, cut)        # un-run compute freed
                if not self._dies_before(A, up.t_deliver):
                    versions = self._replica_versions[A]
                    for k, version in synced:
                        versions[k] = version
                if rd <= stop and not self._dies_before(A, rd):
                    # loser finished computing; its result transfer is
                    # recalled at commit (a dead-on-the-wire sender is
                    # recalled at its crash instant instead)
                    stamp_fresh_remote = True
                    down = down_ch.send(down_b, rd)
                    down_ch.cancel(down.flight, t=cut)
            winner, t_start, t_emit = local, g_start, g_done
            uplink_s = downlink_s = 0.0
            compute_s, loser_emit = g_dur, r_emit
            self.metrics.inc("placement.on_glass")
            if crashed:
                self.metrics.inc("speculation.crash_saves")
        else:
            _rs, rd = host.occupy(r_dur, up.t_deliver)
            down = down_ch.send(down_b, rd)
            # cancel the glass racer: free the un-run tail of its booking
            self.glass.release(g_start, g_done, down.t_deliver)
            versions = self._replica_versions[A]
            for k, version in synced:
                versions[k] = version
            stamp_fresh_remote = True
            winner, t_start, t_emit = A, up.t_send, down.t_deliver
            uplink_s = up.t_deliver - up.t_send
            downlink_s = down.t_deliver - rd
            compute_s, loser_emit = r_dur, g_done
            self.metrics.inc("placement.offloaded")

        if self.tracer:
            self.tracer.instant("race.win", "speculation", t_emit,
                                track=f"session:{st.sid}", sid=st.sid,
                                winner=winner, loser_emit=loser_emit,
                                crashed=crashed)
        # ---- commit ONCE, for the winner only
        self._commit_features(st, m, feats, tier=winner)
        if outputs is not None:
            self._touch_consumed(st, model_name)
            self.metrics.inc(f"placement.tail.{winner}")
        if stamp_fresh_remote:
            # the loser computed (or received) the same fresh feature;
            # its replica holds the committed version
            self._stamp_fresh(A, st, m)
        self.metrics.inc(f"placement.enc.{winner}")
        self.metrics.inc(f"speculation.wins.{winner}")
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier=winner, kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=t_start, t_emit=t_emit,
            uplink_s=uplink_s, downlink_s=downlink_s, compute_s=compute_s,
            decision=dec, outputs=outputs, enc_tier=winner,
            tail_tier=winner if outputs is not None else None,
            speculative=True, race_winner=winner,
            race_loser_emit=loser_emit, precision=dec.precision)

    def _glass_event(self, st: SessionView, event: Event,
                     model_name: Optional[str], now: float,
                     dec: TierDecision, *, fallback: bool = False,
                     detect_s: float = 0.0, feats=None,
                     outputs=None) -> TieredRecord:
        m = event.modality
        local = self.local_name
        if feats is None:
            feats = self._run_encoders(st, m, dec.precision)
        self._commit_features(st, m, feats, tier=local)
        if outputs is None and model_name is not None:
            gathered = self._gather(st, model_name, m, feats)
            if gathered is not None:
                outputs = self.models[model_name].tail(
                    self.params[model_name], gathered)
        if outputs is not None:
            self._touch_consumed(st, model_name)
        dur = (self._enc_duration(m, len(feats), self.glass, dec.precision)
               if feats else 0.0)
        if outputs is not None:
            dur += self.glass.time("tail")
        start, done = self.glass.occupy(dur, now)
        self.metrics.inc("placement.on_glass")
        self.metrics.inc(f"placement.enc.{local}")
        if outputs is not None:
            self.metrics.inc(f"placement.tail.{local}")
        if fallback:
            self.metrics.inc("placement.fallbacks")
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier=local, kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=start, t_emit=done,
            compute_s=dur, fallback=fallback, detect_s=detect_s,
            decision=dec, outputs=outputs, enc_tier=local,
            tail_tier=local if outputs is not None else None,
            precision=dec.precision)

    def _remote_event(self, st: SessionView, event: Event,
                      model_name: Optional[str], payload_b: int,
                      now: float, dec: TierDecision, A: str, *,
                      feats=None, outputs=None, fallback: bool = False,
                      detect_s: float = 0.0) -> TieredRecord:
        """Encoder AND tail on remote tier ``A`` (the co-located path —
        with ``tail_placement`` off this is the only remote shape).
        ``fallback``/``detect_s`` mark a mid-flight re-dispatch: the
        flight already died once on another tier and was re-aimed here
        at the detection instant."""
        m = event.modality
        host = self.hosts[A]
        up_ch = self.fabric.channel(self.local_name, A)
        down_ch = self.fabric.channel(A, self.local_name)
        # ---- uplink: raw payload + any features this replica lacks
        sync_b, synced = self._sync_bytes(A, st, model_name, skip=m)
        up = up_ch.send(payload_b + sync_b, now)

        # ---- real numerics (uncommitted) + simulated remote compute
        if feats is None:
            feats = self._run_encoders(st, m, dec.precision)
            if model_name is not None:
                gathered = self._gather(st, model_name, m, feats)
                if gathered is not None:
                    outputs = self.models[model_name].tail(
                        self.params[model_name], gathered)
        dur = (self._enc_duration(m, len(feats), host, dec.precision)
               if feats else 0.0)
        if outputs is not None:
            dur += host.time("tail")
        _start, t_done = host.occupy(dur, up.t_deliver)

        # ---- downlink payload: fresh feature(s) + head outputs + the
        # piggybacked cache re-stamp (an empty-feature result still
        # ships a small ack frame)
        down_b = sum(payload_nbytes(f) for f in feats.values())
        if outputs is not None:
            down_b += payload_nbytes(outputs)

        # ---- crash window: the tier must survive through the END of
        # its downlink transmission, not just its compute — a death
        # mid-transfer loses the result exactly like one mid-encode
        if self._dies_before(A, down_ch.eta(down_b, t_done)):
            return self._crash_fallback(A, st, event, model_name, now,
                                        dec, payload_b=payload_b,
                                        feats=feats, outputs=outputs)

        # ---- success: commit to the glass cache, ship the bytes
        self._commit_features(st, m, feats, tier=A)
        if outputs is not None:
            self._touch_consumed(st, model_name)
        down = down_ch.send(down_b, t_done)
        # the replica now holds everything it consumed or produced
        versions = self._replica_versions[A]
        for k, version in synced:
            versions[k] = version
        self._stamp_fresh(A, st, m)
        self.metrics.inc("placement.offloaded")
        self.metrics.inc(f"placement.enc.{A}")
        if outputs is not None:
            self.metrics.inc(f"placement.tail.{A}")
        if fallback:
            self.metrics.inc("placement.fallbacks")
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier=A, kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=up.t_send,
            t_emit=down.t_deliver,
            uplink_s=up.t_deliver - up.t_send,
            downlink_s=down.t_deliver - t_done,
            compute_s=dur, fallback=fallback, detect_s=detect_s,
            decision=dec, outputs=outputs,
            enc_tier=A, tail_tier=A if outputs is not None else None,
            precision=dec.precision)

    # ------------------------------------------- per-submodule placement

    def _placed_event(self, st: SessionView, event: Event,
                      model_name: Optional[str], payload_b: int,
                      now: float, dec: TierDecision, avail, queues,
                      prev_observed=()) -> TieredRecord:
        """Per-submodule placement: the encoder goes to ``dec.tier``;
        when a fusion will run, the tail gets its OWN argmin placement
        (possibly a third host), paying the feature hop between the two
        and the head-output return to the glasses."""
        m = event.modality
        A = dec.tier
        # will a fusion actually run? (fresh feature for m, every other
        # consumed modality already cached)
        fusible = False
        if model_name is not None:
            have_fresh = bool(self._consumers(m))
            key = self._cache_key(st.sid, model_name)
            fusible = all((mm == m and have_fresh)
                          or self.cache.peek(key, mm) is not None
                          for mm in self.models[model_name].modalities())
        if not fusible:
            # nothing to place but the encoder
            if A == self.local_name:
                return self._glass_event(st, event, model_name, now, dec)
            return self._remote_event(st, event, model_name, payload_b,
                                      now, dec, A)
        # real numerics first: the tail decision weighs the ACTUAL
        # feature/output byte sizes (placement never changes the math) —
        # for an int8 flight that is the PACKED feature form, so the
        # tail placement argmin sees the ~4x smaller hop for free
        feats = self._run_encoders(st, m, dec.precision)
        gathered = self._gather(st, model_name, m, feats)
        if gathered is None:
            if A == self.local_name:
                return self._glass_event(st, event, model_name, now, dec,
                                         feats=feats)
            return self._remote_event(st, event, model_name, payload_b,
                                      now, dec, A, feats=feats)
        outputs = self.models[model_name].tail(self.params[model_name],
                                               gathered)
        feat_b = sum(payload_nbytes(f) for f in feats.values())
        out_b = payload_nbytes(outputs)
        dtail = self.policy.decide_tail(feat_b, out_b, A, now,
                                        queues=queues, available=avail)
        T = dtail.tier
        partial = None
        if A == self.local_name and T != A and self.glass_partials:
            # the split shape pays a remote round trip even though the
            # encoder stayed home — the EMT still gets an immediate
            # provisional from cached features while the tail travels
            partial = self._glass_provisional(st, prev_observed, now)
        if T == A:
            if A == self.local_name:
                rec = self._glass_event(st, event, model_name, now, dec,
                                        feats=feats, outputs=outputs)
            else:
                rec = self._remote_event(st, event, model_name, payload_b,
                                         now, dec, A, feats=feats,
                                         outputs=outputs)
        else:
            rec = self._split_event(st, event, model_name, payload_b, now,
                                    dec, A, T, feats, outputs, feat_b,
                                    out_b)
        rec.tail_decision = dtail
        if partial is not None:
            rec.glass_partial = partial
        return rec

    def _split_event(self, st: SessionView, event: Event, model_name: str,
                     payload_b: int, now: float, dec: TierDecision,
                     A: str, T: str, feats, outputs, feat_b: int,
                     out_b: int) -> TieredRecord:
        """Encoder on ``A``, tail on a different tier ``T``. The fresh
        features always flow home to the glasses with the result (the
        paper's cache-carrying discipline), whichever tier computed
        them; commit stays on-success so a mid-flight death loses the
        in-flight work, never corrupts the cache."""
        m = event.modality
        local = self.local_name

        if A == local:
            # encoder at home; only the tail travels
            enc_dur = (self._enc_duration(m, len(feats), self.glass,
                                          dec.precision)
                       if feats else 0.0)
            start, t_enc_done = self.glass.occupy(enc_dur, now)
            # glass-computed features are already safe at home
            self._commit_features(st, m, feats, tier=local)
            sync_b, synced = self._sync_bytes(T, st, model_name, skip=m)
            up = self.fabric.channel(local, T).send(feat_b + sync_b,
                                                    t_enc_done)
            tail_host = self.hosts[T]
            _s, t_tail_done = tail_host.occupy(tail_host.time("tail"),
                                               up.t_deliver)
            down_ch = self.fabric.channel(T, local)
            if self._dies_before(T, down_ch.eta(out_b, t_tail_done)):
                # tail-only fallback: features survived on glass
                t_detect = max(t_enc_done, self._faults[T].detect_at)
                self._mark_dead(T)
                _s2, done = self.glass.occupy(self.glass.time("tail"),
                                              t_detect)
                self._touch_consumed(st, model_name)
                self.metrics.inc("placement.on_glass")
                self.metrics.inc("placement.fallbacks")
                self.metrics.inc(f"placement.enc.{local}")
                self.metrics.inc(f"placement.tail.{local}")
                return TieredRecord(
                    sid=st.sid, index=event.index, modality=m,
                    model=model_name, tier=local,
                    kind=self._kind(model_name),
                    t_arrival=event.arrival_time, t_start=start,
                    t_emit=done,
                    uplink_s=up.t_deliver - up.t_send,
                    compute_s=enc_dur + self.glass.time("tail"),
                    fallback=True,
                    detect_s=max(0.0, t_detect - t_enc_done),
                    decision=dec, outputs=outputs, enc_tier=local,
                    tail_tier=local, precision=dec.precision)
            down = down_ch.send(out_b, t_tail_done)
            self._touch_consumed(st, model_name)
            versions = self._replica_versions[T]
            for k, version in synced:
                versions[k] = version
            self._stamp_fresh(T, st, m)
            self.metrics.inc("placement.on_glass")
            self.metrics.inc(f"placement.enc.{local}")
            self.metrics.inc(f"placement.tail.{T}")
            return TieredRecord(
                sid=st.sid, index=event.index, modality=m,
                model=model_name, tier=local, kind=self._kind(model_name),
                t_arrival=event.arrival_time, t_start=start,
                t_emit=down.t_deliver,
                uplink_s=up.t_deliver - up.t_send,
                downlink_s=down.t_deliver - t_tail_done,
                compute_s=enc_dur + tail_host.time("tail"),
                decision=dec, outputs=outputs, enc_tier=local,
                tail_tier=T, precision=dec.precision)

        host = self.hosts[A]
        up = self.fabric.channel(local, A).send(payload_b, now)
        enc_dur = (self._enc_duration(m, len(feats), host, dec.precision)
                   if feats else 0.0)
        _s, t_enc_done = host.occupy(enc_dur, up.t_deliver)

        if T == local:
            # features come home, fusion runs on the glasses
            down_ch = self.fabric.channel(A, local)
            if self._dies_before(A, down_ch.eta(feat_b, t_enc_done)):
                return self._crash_fallback(A, st, event, model_name, now,
                                            dec, payload_b=payload_b,
                                            feats=feats, outputs=outputs)
            down = down_ch.send(feat_b, t_enc_done)
            self._commit_features(st, m, feats, tier=A)
            self._stamp_fresh(A, st, m)
            _s2, done = self.glass.occupy(self.glass.time("tail"),
                                          down.t_deliver)
            self._touch_consumed(st, model_name)
            self.metrics.inc("placement.offloaded")
            self.metrics.inc(f"placement.enc.{A}")
            self.metrics.inc(f"placement.tail.{local}")
            return TieredRecord(
                sid=st.sid, index=event.index, modality=m,
                model=model_name, tier=A, kind=self._kind(model_name),
                t_arrival=event.arrival_time, t_start=up.t_send,
                t_emit=done,
                uplink_s=up.t_deliver - up.t_send,
                downlink_s=down.t_deliver - t_enc_done,
                compute_s=enc_dur + self.glass.time("tail"),
                decision=dec, outputs=outputs, enc_tier=A,
                tail_tier=local, precision=dec.precision)

        # encoder on A, tail on another remote B: the feature hops
        # A->B on the direct link while the glasses warm B's replica
        # in parallel; B returns features + outputs home
        B = T
        sync_b, synced = self._sync_bytes(B, st, model_name, skip=m)
        sync_d = (self.fabric.channel(local, B).send(sync_b, now)
                  if sync_b else None)
        hop_ch = self.fabric.channel(A, B)
        if self._dies_before(A, hop_ch.eta(feat_b, t_enc_done)):
            return self._crash_fallback(A, st, event, model_name, now,
                                        dec, payload_b=payload_b,
                                        feats=feats, outputs=outputs)
        hop = hop_ch.send(feat_b, t_enc_done)
        ready = max(hop.t_deliver,
                    sync_d.t_deliver if sync_d is not None else 0.0)
        tail_host = self.hosts[B]
        _s2, t_tail_done = tail_host.occupy(tail_host.time("tail"), ready)
        down_ch = self.fabric.channel(B, local)
        down_b = feat_b + out_b         # the result carries the cache home
        if self._dies_before(B, down_ch.eta(down_b, t_tail_done)):
            return self._crash_fallback(B, st, event, model_name, now,
                                        dec, payload_b=payload_b,
                                        feats=feats, outputs=outputs)
        down = down_ch.send(down_b, t_tail_done)
        self._commit_features(st, m, feats, tier=A)
        self._touch_consumed(st, model_name)
        versions = self._replica_versions[B]
        for k, version in synced:
            versions[k] = version
        self._stamp_fresh(A, st, m)
        self._stamp_fresh(B, st, m)
        self.metrics.inc("placement.offloaded")
        self.metrics.inc(f"placement.enc.{A}")
        self.metrics.inc(f"placement.tail.{B}")
        return TieredRecord(
            sid=st.sid, index=event.index, modality=m, model=model_name,
            tier=A, kind=self._kind(model_name),
            t_arrival=event.arrival_time, t_start=up.t_send,
            t_emit=down.t_deliver,
            uplink_s=up.t_deliver - up.t_send,
            downlink_s=down.t_deliver - t_tail_done,
            compute_s=enc_dur + tail_host.time("tail"),
            decision=dec, outputs=outputs, enc_tier=A, tail_tier=B,
            precision=dec.precision)

    # --------------------------------------------------------- episodes

    def run_arrivals(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, sim_window: Optional[float] = None,
                     crash_at: Optional[float] = None,
                     rejoin_at: Optional[float] = None,
                     schedule=None):
        """Drive sessions through their episodes in GLOBAL arrival-time
        order (the field regime: one incident, many responders, one
        interleaved stream — ``core.episodes.merge_arrivals``).
        ``payload_fn(sid, event) -> payload``.

        Tiered placement: per-arrival, optionally killing the edge at
        simulated time ``crash_at``; returns the records. Flush-mode:
        with ``sim_window=None`` the engine's wall-clock deadline policy
        applies; with ``sim_window`` set, the deadline rule runs on
        EPISODE time instead (same semantics, different clock): after
        each submit, flush iff the oldest pending arrival's episode time
        is >= ``sim_window`` seconds behind the current one — so
        ``sim_window=0`` flushes per arrival. A final ``drain`` runs
        either way; returns the flush reports."""
        arrivals = merge_arrivals(episodes)
        if self.tiered:
            if crash_at is not None:
                self.inject_crash(crash_at, rejoin_at=rejoin_at)
            elif rejoin_at is not None:
                raise ValueError("rejoin_at requires crash_at")
            if schedule is not None:
                self.inject_schedule(schedule)
            for _t, sid, ev in arrivals:
                self.submit(sid, ev, payload_fn(sid, ev),
                            aggregate=aggregate)
            return self.records
        if crash_at is not None or rejoin_at is not None \
                or schedule is not None:
            raise ValueError("crash_at/rejoin_at/schedule require tiered "
                             "placement")
        if sim_window is None:
            for _t, sid, ev in arrivals:
                self.submit(sid, ev, payload_fn(sid, ev),
                            aggregate=aggregate)
        else:
            saved, self.deadline_s = self.deadline_s, None
            try:
                oldest = None
                for t, sid, ev in arrivals:
                    self.submit(sid, ev, payload_fn(sid, ev),
                                aggregate=aggregate)
                    oldest = t if oldest is None else oldest
                    if t - oldest >= sim_window:
                        self.flush()
                        oldest = None
            finally:
                self.deadline_s = saved
        self.drain()
        return self.flushes

    def run_episodes(self, episodes: Dict[str, List[Event]], payload_fn,
                     *, aggregate=None, events_per_flush: int = 1):
        """Tick-driven batch serving: at tick t every session submits
        its t-th event; flush every ``events_per_flush`` ticks.
        ``payload_fn(sid, event) -> payload``."""
        if self.tiered:
            raise RuntimeError("run_episodes is a flush-mode driver; "
                               "tiered placement uses run_arrivals")
        horizon = max((len(ev) for ev in episodes.values()), default=0)
        for t in range(horizon):
            for sid, evs in episodes.items():
                if t < len(evs):
                    self.submit(sid, evs[t], payload_fn(sid, evs[t]),
                                aggregate=aggregate)
            if (t + 1) % events_per_flush == 0:
                self.flush()
        if self._pending:
            self.flush()
        return self.flushes

    # ------------------------------------------------------------- stats

    def compile_count(self) -> int:
        return sum(sm.compile_count() for sm in self.models.values())

    def encoder_calls_total(self) -> int:
        return self._enc_calls_total

    def tail_calls_total(self) -> int:
        return self._tail_calls_total

    def event_latencies(self) -> List[float]:
        return [lat for f in self.flushes for lat in f.latencies.values()]

    def total_wall_s(self) -> float:
        return sum(f.wall_s for f in self.flushes)

    def time_to_first_prediction(self, sid: str) -> Optional[float]:
        """Flush-mode: wall seconds from first submit to first emitted
        prediction. Tiered: simulated seconds from first arrival to the
        first emission (a glass provisional counts — it IS the first
        thing the EMT sees)."""
        st = self.sessions[sid]
        if self.tiered:
            if st.t_first_emit is None or st.t_first_arrival is None:
                return None
            return st.t_first_emit - st.t_first_arrival
        if st.t_first_prediction is None or st.t_first_submit is None:
            return None
        return st.t_first_prediction - st.t_first_submit

    def time_to_final_prediction(self, sid: str) -> Optional[float]:
        st = self.sessions[sid]
        if self.tiered:
            if st.t_final_emit is None or st.t_first_arrival is None:
                return None
            return st.t_final_emit - st.t_first_arrival
        if st.t_final_prediction is None or st.t_first_submit is None:
            return None
        return st.t_final_prediction - st.t_first_submit

    # ----- tiered stats (meaningful only with placement enabled)

    def total_latency_s(self) -> float:
        """Cumulative serving latency (sum of per-arrival t_emit -
        t_arrival) — the Fig. 15 comparison metric."""
        return self._total_latency

    def makespan_s(self) -> float:
        return max((r.t_emit for r in self.records), default=0.0)

    def transport_stats(self) -> dict:
        """Per-link byte accounting. ``uplink``/``downlink`` keep the
        historical 2-tier view (the glass<->fastest-remote pair);
        ``links`` breaks out every (src, dst) channel the fabric
        actually used."""
        return {"uplink": self.uplink.stats(),
                "downlink": self.downlink.stats(),
                "links": self.fabric.stats()}

    def placement_counts(self) -> dict:
        """Events placed per host (by ENCODER tier — the bulk compute),
        plus crash fallbacks. Tier-count-agnostic: one key per
        configured host ('glass'/'edge' in the legacy pair)."""
        return {**self.place_counts, "fallbacks": self.fallback_count}

    def tail_placement_counts(self) -> dict:
        """Fusions run per host — diverges from ``placement_counts``
        exactly when per-submodule tail placement split a tail from its
        encoder."""
        return dict(self.tail_counts)

    def speculation_stats(self) -> dict:
        """Speculative-dual-placement and re-dispatch accounting:
        how many arrivals raced, which host won each race, how many
        remote crashes the race absorbed without a detection stall,
        how many lost flights re-aimed at a surviving remote, plus the
        commit-protocol audit trail (cancelled transfers, refused
        duplicate/stale cache commits — both must stay refusals, never
        visible state)."""
        return {"races": self.spec_count,
                "wins": dict(self.spec_wins),
                "crash_saves": self.spec_crash_saves,
                "redispatches": self.redispatch_count,
                "cancelled_msgs": self.fabric.cancelled_msgs(),
                "duplicate_commits": self.cache.duplicate_commits,
                "stale_commits": self.cache.stale_commits}


# ======================================================================
# Spec parsing + factory
# ======================================================================

_SPEC_TOKENS = {
    "batch": "batch", "batched": "batch",
    "stream": "stream", "streaming": "stream",
    "tiered": "tiered", "tier": "tiered", "placement": "tiered",
}

# canonical sections -> (policy class, EngineSpec field); section names
# are pre-canonicalized through _SPEC_TOKENS
_SECTIONS = {
    "batch": (BatchPolicy, "batch"),
    "stream": (StreamPolicy, "stream"),
    "tiered": (PlacementPolicy, "placement"),
}


def parse_spec(spec, **overrides) -> EngineSpec:
    """Normalize an engine spec into a typed :class:`EngineSpec`.

    ``spec`` may be:
      * a string of '+'-joined policy tokens: ``"batch"``, ``"stream"``,
        ``"batch+stream"``, ``"stream+tiered"``, ``"batch+stream+tiered"``
        (aliases: batched/streaming/tier/placement);
      * a dict with sections ``batch`` / ``stream`` / ``tiered`` (each
        True or a kwargs dict) plus engine-wide keys ``share_encoders``
        and ``max_history``;
      * an :class:`EngineSpec` (returned as-is, overrides applied to
        copies of its policies is NOT supported — pass a fresh spec).

    ``overrides`` are routed by name: policy-constructor fields go to
    their policy (e.g. ``deadline_s`` -> StreamPolicy, ``profile``/
    ``trace`` -> PlacementPolicy, ``bucketer`` -> BatchPolicy), and
    ``share_encoders``/``max_history`` to the engine; an override beats
    the same key in a dict-spec section. Tiered specs REQUIRE
    ``profile`` and ``trace`` (there is no meaningful default
    hardware)."""
    if isinstance(spec, EngineSpec):
        if overrides:
            raise ValueError("overrides are not applied to a pre-built "
                             "EngineSpec; pass tokens or a dict instead")
        return spec

    sections: Dict[str, dict] = {}
    engine_kw: Dict[str, Any] = {}
    if isinstance(spec, str):
        for tok in filter(None, (t.strip() for t in spec.split("+"))):
            canon = _SPEC_TOKENS.get(tok.lower())
            if canon is None:
                raise ValueError(
                    f"unknown engine spec token {tok!r}; expected "
                    f"'+'-joined subset of batch/stream/tiered")
            sections[canon] = {}
    elif isinstance(spec, dict):
        for key, val in spec.items():
            if key in ("share_encoders", "max_history"):
                engine_kw[key] = val
                continue
            canon = _SPEC_TOKENS.get(str(key).lower())
            if canon is None:
                raise ValueError(f"unknown engine spec section {key!r}")
            if val is False or val is None:
                continue
            sections[canon] = {} if val is True else dict(val)
    else:
        raise TypeError(f"engine spec must be str, dict, or EngineSpec; "
                        f"got {type(spec).__name__}")

    if not sections:
        raise ValueError("empty engine spec: enable at least one of "
                         "batch/stream/tiered")

    # route the keyword overrides to their policy (or the engine)
    fields_of = {
        "batch": set(BatchPolicy.__dataclass_fields__),
        "stream": set(StreamPolicy.__dataclass_fields__),
        "tiered": set(PlacementPolicy.__dataclass_fields__),
    }
    for k, v in overrides.items():
        if k in ("share_encoders", "max_history"):
            engine_kw[k] = v
            continue
        owner = next((sec for sec in sections if k in fields_of[sec]), None)
        if owner is None and k in fields_of["batch"]:
            # the coalescing machinery exists in every flush-mode engine,
            # so its knobs (bucketer, batch_bucket_min, ...) are always
            # addressable — an explicit "batch" token is only needed to
            # *enable* coalescing semantics in the spec's own vocabulary
            owner = "batch"
            sections.setdefault("batch", {})
        if owner is None:
            enabled = "+".join(sections) or "(none)"
            raise ValueError(f"override {k!r} does not match any enabled "
                             f"policy ({enabled})")
        sections[owner][k] = v        # overrides WIN over dict-spec values

    policies: Dict[str, Any] = {}
    for sec, kw in sections.items():
        cls, target = _SECTIONS[sec]
        unknown = set(kw) - fields_of[sec]
        if unknown:
            raise ValueError(f"unknown {sec} policy option(s): "
                             f"{sorted(unknown)}")
        if cls is PlacementPolicy and not {"profile", "trace"} <= set(kw):
            raise ValueError("tiered placement requires 'profile' "
                             "(ProfileTable) and 'trace' (BandwidthTrace)")
        policies[target] = cls(**kw)
    return EngineSpec(**policies, **engine_kw)


def build_engine(models: Dict[str, SplitModel], params: Dict[str, dict],
                 spec, *, time_fn: Callable[[], float] = time.perf_counter,
                 tracer: Optional[Tracer] = None,
                 **overrides) -> EMSServeEngine:
    """THE factory: assemble an :class:`EMSServeEngine` from a spec.

    ``build_engine(models, params, "batch")`` is the batched
    fast path; ``"stream"`` the progressive-prediction runtime;
    ``"stream+tiered"`` streams partials on-glass while the edge
    computes finals. See :func:`parse_spec` for the spec grammar and
    override routing. ``tracer`` (a :class:`repro.obs.Tracer`) turns on
    full-lifecycle span tracing; it defaults to the no-op."""
    es = parse_spec(spec, **overrides)
    return EMSServeEngine(models, params, batch=es.batch, stream=es.stream,
                          placement=es.placement,
                          share_encoders=es.share_encoders,
                          max_history=es.max_history, time_fn=time_fn,
                          tracer=tracer)
