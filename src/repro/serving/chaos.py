"""Seeded chaos schedules for the fault-injection tier.

A field incident does not crash the edge box once, politely, at a time
a benchmark author picked: tiers drop and rejoin repeatedly as the EMT
moves through the building. :func:`chaos_schedule` turns a seed into a
reproducible sequence of :class:`FaultEvent` crash/rejoin cycles over
the remote tiers, which ``EMSServeEngine.inject_schedule`` replays —
each cycle exercising the full crash -> (heartbeat detection) ->
re-dispatch/fallback -> rejoin -> replica re-warm path.

Schedules are validated structurally: per tier, cycles are strictly
ordered and non-overlapping (a box must rejoin before it can crash
again), and every rejoin strictly follows its crash. The generator
draws up/down durations from clipped exponentials, so a seed sweep
covers short blips (a missed heartbeat or two) through long outages.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class FaultEvent:
    """One crash/rejoin cycle of one remote tier. ``rejoin_at=None``
    means the box stays down for the rest of the episode."""
    crash_at: float
    tier: str
    rejoin_at: Optional[float] = None

    def __post_init__(self):
        if self.rejoin_at is not None and self.rejoin_at <= self.crash_at:
            raise ValueError(
                f"rejoin at {self.rejoin_at} must follow the crash at "
                f"{self.crash_at} ({self.tier})")


def validate_schedule(schedule: Sequence[FaultEvent]) -> List[FaultEvent]:
    """Check per-tier ordering/non-overlap; returns the schedule sorted
    by crash time."""
    by_tier: Dict[str, List[FaultEvent]] = {}
    for e in schedule:
        by_tier.setdefault(e.tier, []).append(e)
    for tier, events in by_tier.items():
        events.sort(key=lambda e: e.crash_at)
        for a, b in zip(events, events[1:]):
            if a.rejoin_at is None:
                raise ValueError(
                    f"{tier}: cycle at {a.crash_at} never rejoins but a "
                    f"later crash at {b.crash_at} is scheduled")
            if b.crash_at < a.rejoin_at:
                raise ValueError(
                    f"{tier}: crash at {b.crash_at} overlaps the outage "
                    f"[{a.crash_at}, {a.rejoin_at})")
    return sorted(schedule, key=lambda e: (e.crash_at, e.tier))


def chaos_schedule(seed: int, *, horizon: float,
                   tiers: Sequence[str],
                   mean_up_s: float = 3.0, mean_down_s: float = 1.5,
                   min_up_s: float = 0.5, min_down_s: float = 0.25,
                   max_cycles_per_tier: int = 8) -> List[FaultEvent]:
    """Reproducible random crash/rejoin cycles over ``tiers`` within
    ``[0, horizon]``.

    Each tier independently alternates up/down periods drawn from
    exponentials with the given means (clipped to the minimums so a
    cycle is never degenerate). A final cycle whose rejoin would land
    beyond the horizon stays down for the rest of the episode — the
    no-surviving-remote glass fallback must get exercised too.
    """
    if horizon <= 0:
        raise ValueError(f"horizon must be positive, got {horizon}")
    rng = np.random.default_rng(seed)
    schedule: List[FaultEvent] = []
    for tier in tiers:
        t = 0.0
        for _ in range(max_cycles_per_tier):
            t += max(min_up_s, float(rng.exponential(mean_up_s)))
            if t >= horizon:
                break
            down = max(min_down_s, float(rng.exponential(mean_down_s)))
            rejoin = t + down
            schedule.append(FaultEvent(
                crash_at=t, tier=tier,
                rejoin_at=rejoin if rejoin < horizon else None))
            if rejoin >= horizon:
                break
            t = rejoin
    return validate_schedule(schedule)
