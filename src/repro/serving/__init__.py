"""Serving runtimes over the split-model zoo.

  * ``engine`` / ``kv_cache`` — LLM decode serving (KV-cache paths);
  * ``batch_engine.BatchedEMSServe`` — multi-session, shape-bucketed,
    dispatch-async batch flushes (complete events);
  * ``stream_engine.StreamingEMSServe`` — async-modality streaming with
    progressive partial->final predictions, deadline-driven flushes,
    and cross-incident session eviction;
  * ``tiered_runtime.TieredEMSServe`` — glass<->edge split placement on
    simulated-clock tiers: live offload decisions, byte-accounted
    feature transport, edge-crash fault tolerance;
  * ``transport`` — in-order byte-accounting tier links;
  * ``event_loop.WallClockDriver`` — monotonic-clock deadline pumping
    for the streaming/tiered engines.
"""
from .batch_engine import BatchedEMSServe, FlushReport  # noqa: F401
from .event_loop import LoopStats, WallClockDriver  # noqa: F401
from .stream_engine import (Prediction, StreamFlushReport,  # noqa: F401
                            StreamingEMSServe, StreamSession)
from .tiered_runtime import (TieredEMSServe, TieredRecord,  # noqa: F401
                             TierHost, TierSession)
from .transport import (Delivery, TransportChannel,  # noqa: F401
                        payload_nbytes)
