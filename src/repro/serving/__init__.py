"""One EMSServe: the unified serving layer over the split-model zoo.

The heart of the package is ``api`` — canonical exchange types
(``Arrival``, ``Prediction``, ``FlushReport``, ``SessionView``,
``TieredRecord``) and the one multi-session runtime
(``EMSServeEngine``) whose behavior is assembled from orthogonal,
composable policies by the ``build_engine(models, params, spec)``
factory:

  * ``BatchPolicy`` — shape-bucketed cross-session coalescing, one
    batched XLA call per (modality, bucket) per flush, one host sync;
    ``ragged=True`` (default OFF) upgrades the flush to the
    concatenated ragged layout: ``core.bucketing.RaggedBatch`` packs
    every pending row of a variable-length modality into one buffer
    (text at ``flash_block``-aligned offsets under the segment-masked
    flash kernel; vitals back-to-back with per-row state resets), and
    all pending fusion tails — across sessions AND modality subsets —
    run as ONE grouped call through zero-filled full-set heads (subset
    heads are row-slices of the full heads, and zero-filled K-slices
    are bitwise inert in a GEMM). A flush then issues O(modalities)+1
    kernels instead of O(modalities x buckets)+O(subsets), bit-parity
    (atol 0) pinned against the unbucketed per-event reference;
  * ``StreamPolicy`` — progressive partial->final predictions, flush
    deadlines, cross-incident session eviction;
  * ``PlacementPolicy`` — N tier hosts on simulated clocks (the legacy
    glass<->edge pair, or ``tiers=("glass", "ph1", "edge64x")``), live
    per-submodule offload decisions (encoder and fusion tail placed
    independently), contention-aware cost estimates, byte-accounted
    per-link transport (``transport.TierFabric``), heartbeat-detected
    crash failover, and tier restart/rejoin with replica re-warm.

Policies compose: ``build_engine(models, params, "stream+tiered", ...)``
streams on-glass provisional partials while the edge computes finals —
a regime none of the pre-unification sibling runtimes could express.

Cancel-on-commit speculation (``PlacementPolicy.speculation``): when a
``core.offload.SpeculationPolicy`` judges the deadline margin thin, the
engine races the arrival on glass AND the best remote simultaneously
and commits whichever finishes first — exactly once. The loser is
cancelled *at the commit instant*: an undelivered uplink is recalled
from the wire (``TransportChannel.cancel`` — a cancelled flight never
delivers, the in-order frontier rolls back, the bytes are audited), an
un-run remote booking is released from its host clock
(``TierHost.release``), and the duplicate-safe ``FeatureCache.put``
refuses any straggler commit at the same or an older step. A remote
crash mid-race is absorbed by the glass racer with no heartbeat stall.
``PlacementPolicy.redispatch`` re-aims flights lost to a tier crash at
the best surviving remote; ``chaos`` generates seeded, validated
crash/rejoin schedules that ``inject_schedule`` replays. All of it
defaults OFF — historical timelines never race.

Quantized precision tier (``PlacementPolicy.precision``, default
``None``): a ``{host: "fp32" | "int8"}`` map arms joint
(tier, precision) enumeration in ``core.offload.MultiTierPolicy`` —
an int8 candidate halves the remote encoder clock
(``int8_compute_scale``) and quarters the returned feature bytes
(``int8_bytes_scale``), so the argmin ships packed features exactly
when the uplink is the bottleneck. int8 flights run the UNMODIFIED
jitted encoders over a sidecar param pytree
(``models.quantized.quantize_emsnet_params`` — GEMM-heavy denses as
``{"w_q", "w_scale"}``, everything else fp32 shared by reference,
derived once per fp32 pytree and cached by id()), return
``{"q", "scale"}`` packed features (~4x smaller ``payload_nbytes``),
and the FeatureCache commits the packed form with staleness semantics
unchanged — consumers dequantize before fusion. Precision rides the
flight: racers run at the decided precision and crash re-dispatch
preserves it. Every model in a precision-armed spec must declare a
``quantize_fn``; an all-fp32 map disarms to the bit-identical legacy
path. The launcher flag is ``--precision ph1=int8,edge64x=int8``.

Observability (``repro.obs``, defaults OFF): every engine carries a
``Metrics`` registry — the stack's formerly ad hoc counters
(``duplicate_commits``, ``cancelled_bytes``, placement tallies, ...)
are names in its one flat namespace, the historical attributes
surviving as read-through properties, plus p50/p95/p99 latency
histograms behind ``EMSServeEngine.metrics_snapshot()``. Passing
``build_engine(..., tracer=repro.obs.Tracer())`` (or ``--trace PATH``
on the launcher) records every arrival's full lifecycle — arrival,
queue wait, encode@tier compute spans, transport flights by flight id,
fuse, cache commit, partial/final emit, and the race/cancel/crash/
redispatch/rejoin annotations — as Chrome trace-event JSON loadable in
Perfetto; the default ``Tracer.disabled`` is a falsy no-op, so untraced
runs regenerate bit-identically. ``python -m repro.obs.audit`` replays
an exported trace and re-verifies the serving invariants (exactly-one
commit, <=1-step staleness, byte conservation incl. cancelled flights,
no emit before its inputs) from the file alone.

Fleet scale lives one package up (``repro.fleet``): ``RegionSim``
replays seeded open-loop Poisson/diurnal incident arrivals against N
replicas of ONE ``build_engine`` spec over mesh-placed params, with
consistent-hash routing and deadline-hysteresis admission control that
sheds overload to on-glass ``degraded``-tagged partials (launcher:
``--fleet RATE --replicas N``; benchmark: ``benchmarks/fleet_load.py``).

Historical constructors remain as thin shims over the same engine:

  * ``batch_engine.BatchedEMSServe`` — the ``"batch"`` construction;
  * ``stream_engine.StreamingEMSServe`` — ``"batch+stream"``;
  * ``tiered_runtime.TieredEMSServe`` — ``"tiered"``;

plus the pieces the engine rides on:

  * ``transport`` — in-order byte-accounting tier links;
  * ``event_loop.WallClockDriver`` — monotonic-clock deadline pumping
    for any engine exposing ``submit``/``poll``/``drain``;
  * ``engine`` / ``kv_cache`` — LLM decode serving (KV-cache paths),
    unrelated to the EMS session engine.

(`core.engine.EMSServe` stays the single-session per-event *reference*
engine — the paper's Table-6 trace and the baseline every parity tier
and benchmark compares against.)
"""
from .api import (Arrival, BatchPolicy, EMSServeEngine,  # noqa: F401
                  EngineSpec, FlushReport, PlacementPolicy, Prediction,
                  SessionView, StreamPolicy, TieredRecord, TierHost,
                  build_engine, parse_spec)
from .batch_engine import BatchedEMSServe, SessionState  # noqa: F401
from .chaos import FaultEvent, chaos_schedule, validate_schedule  # noqa: F401
from .event_loop import LoopStats, WallClockDriver  # noqa: F401
from .stream_engine import (StreamFlushReport,  # noqa: F401
                            StreamingEMSServe, StreamSession)
from .tiered_runtime import TieredEMSServe, TierSession  # noqa: F401
from .transport import (Delivery, MinTrace, TierFabric,  # noqa: F401
                        TransportChannel, payload_nbytes)
