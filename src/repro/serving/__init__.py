"""One EMSServe: the unified serving layer over the split-model zoo.

The heart of the package is ``api`` — canonical exchange types
(``Arrival``, ``Prediction``, ``FlushReport``, ``SessionView``,
``TieredRecord``) and the one multi-session runtime
(``EMSServeEngine``) whose behavior is assembled from orthogonal,
composable policies by the ``build_engine(models, params, spec)``
factory:

  * ``BatchPolicy`` — shape-bucketed cross-session coalescing, one
    batched XLA call per (modality, bucket) per flush, one host sync;
  * ``StreamPolicy`` — progressive partial->final predictions, flush
    deadlines, cross-incident session eviction;
  * ``PlacementPolicy`` — N tier hosts on simulated clocks (the legacy
    glass<->edge pair, or ``tiers=("glass", "ph1", "edge64x")``), live
    per-submodule offload decisions (encoder and fusion tail placed
    independently), contention-aware cost estimates, byte-accounted
    per-link transport (``transport.TierFabric``), heartbeat-detected
    crash failover, and tier restart/rejoin with replica re-warm.

Policies compose: ``build_engine(models, params, "stream+tiered", ...)``
streams on-glass provisional partials while the edge computes finals —
a regime none of the pre-unification sibling runtimes could express.

Historical constructors remain as thin shims over the same engine:

  * ``batch_engine.BatchedEMSServe`` — the ``"batch"`` construction;
  * ``stream_engine.StreamingEMSServe`` — ``"batch+stream"``;
  * ``tiered_runtime.TieredEMSServe`` — ``"tiered"``;

plus the pieces the engine rides on:

  * ``transport`` — in-order byte-accounting tier links;
  * ``event_loop.WallClockDriver`` — monotonic-clock deadline pumping
    for any engine exposing ``submit``/``poll``/``drain``;
  * ``engine`` / ``kv_cache`` — LLM decode serving (KV-cache paths),
    unrelated to the EMS session engine.

(`core.engine.EMSServe` stays the single-session per-event *reference*
engine — the paper's Table-6 trace and the baseline every parity tier
and benchmark compares against.)
"""
from .api import (Arrival, BatchPolicy, EMSServeEngine,  # noqa: F401
                  EngineSpec, FlushReport, PlacementPolicy, Prediction,
                  SessionView, StreamPolicy, TieredRecord, TierHost,
                  build_engine, parse_spec)
from .batch_engine import BatchedEMSServe, SessionState  # noqa: F401
from .event_loop import LoopStats, WallClockDriver  # noqa: F401
from .stream_engine import (StreamFlushReport,  # noqa: F401
                            StreamingEMSServe, StreamSession)
from .tiered_runtime import TieredEMSServe, TierSession  # noqa: F401
from .transport import (Delivery, MinTrace, TierFabric,  # noqa: F401
                        TransportChannel, payload_nbytes)
