"""Serving runtimes over the split-model zoo.

  * ``engine`` / ``kv_cache`` — LLM decode serving (KV-cache paths);
  * ``batch_engine.BatchedEMSServe`` — multi-session, shape-bucketed,
    dispatch-async batch flushes (complete events);
  * ``stream_engine.StreamingEMSServe`` — async-modality streaming with
    progressive partial->final predictions and deadline-driven flushes.
"""
from .batch_engine import BatchedEMSServe, FlushReport  # noqa: F401
from .stream_engine import (Prediction, StreamFlushReport,  # noqa: F401
                            StreamingEMSServe, StreamSession)
