from . import attention, layers, moe, rwkv, ssm, transformer  # noqa: F401
from .transformer import (decode_step, forward_train, init_cache,  # noqa: F401
                          init_params, prefill)
