"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Expert-parallel friendly: tokens are routed top-k, flattened, sorted by
expert id, scattered into a fixed (E, C, d) dispatch buffer (capacity
C = ceil(N*k/E * capacity_factor); overflow tokens are dropped, the
standard GShard/Switch discipline), batch-matmul'd against stacked
expert weights, and combined back with router weights. All shapes are
static, so the whole thing lowers under pjit with the expert dimension
sharded on the `model` mesh axis (the dispatch scatter becomes an
all-to-all).

DeepSeek-style shared experts are a plain dense MLP added to every
token. The auxiliary load-balance loss (Switch form: E * sum_e f_e *
p_e) is returned for the trainer to accumulate.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L


def moe_init(key, cfg):
    d, E, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * scale
                   ).astype(jnp.float32),  # router kept f32 for stable top-k
        "gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dt),
        "up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dt),
        "down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
                 * (1.0 / math.sqrt(ff))).astype(dt),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, ff * cfg.n_shared_experts,
                                 "swiglu", dtype=dt)
    return p


def capacity(n_tokens: int, cfg) -> int:
    c = int(math.ceil(n_tokens * cfg.experts_per_tok / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def moe_forward(p, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    N = B * S
    xf = x.reshape(N, d)

    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                     # (N, E)
    top_w, top_i = jax.lax.top_k(probs, k)                      # (N, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux load-balance loss (Switch): E * sum_e f_e * p_e ----
    counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = counts / (N * k)
    pbar = probs.mean(axis=0)
    aux = E * jnp.sum(f * pbar)

    # ---- sort-based dispatch ----
    Nk = N * k
    eids = top_i.reshape(Nk)
    tids = jnp.arange(Nk, dtype=jnp.int32) // k
    order = jnp.argsort(eids)                                   # stable
    se = eids[order]
    st = tids[order]
    sw = top_w.reshape(Nk)[order]
    starts = jnp.searchsorted(se, jnp.arange(E, dtype=se.dtype))
    pos = jnp.arange(Nk, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    C = capacity(N, cfg)
    keep = pos < C

    buf = jnp.zeros((E, C, d), x.dtype)
    vals = jnp.where(keep[:, None], xf[st], 0)
    buf = buf.at[se, pos].set(vals, mode="drop")                # (E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["down"])          # (E, C, d)

    pos_c = jnp.minimum(pos, C - 1)
    contrib = out_buf[se, pos_c] * (sw * keep)[:, None]
    y = jnp.zeros((N, d), jnp.float32).at[st].add(contrib.astype(jnp.float32))
    y = y.astype(x.dtype).reshape(B, S, d)

    if cfg.n_shared_experts:
        y = y + L.mlp(p["shared"], x, "swiglu")
    return y, aux


# ----------------------------------------------------------------------
# Explicit all-to-all expert parallelism (shard_map)
# ----------------------------------------------------------------------

def moe_forward_a2a(p, x, cfg, *, mesh, token_axes, expert_axes,
                    pair_capacity_factor=2.0):
    """Expert-parallel MoE with explicit ``lax.all_to_all`` dispatch.

    Under GSPMD auto-partitioning, the sort-based dispatch's
    gather/scatter against an expert-sharded (E, C, d) buffer is
    partitioned as materialize-everywhere + all-reduce — ~100x the
    traffic of real expert parallelism (measured in EXPERIMENTS.md
    §Perf). This shard_map implementation is the production path: each
    device routes its local tokens, exchanges exactly
    (n_dev, C_pair, d) with its expert-parallel group, runs its local
    experts, and reverses the exchange. Traffic per device per layer =
    2 x C_pair x n_dev x d — the textbook all-to-all cost.

    token_axes: mesh axes sharding the flattened token dim of x
                (e.g. ('pod','data','model') under the fsdp strategy).
    expert_axes: mesh axes the expert dim is sharded over — must be a
                suffix of token_axes; the all-to-all runs over them,
                outer axes form independent groups.
    Tokens overflowing per-pair or per-expert capacity are dropped
    (standard capacity discipline, same as the dispatch path).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    shared = p.get("shared")

    def body(xf, router, gate, up, down):
        # xf: (N_loc, d); gate/up/down: (E_loc, ...) local expert slices
        N_loc = xf.shape[0]
        E_loc = gate.shape[0]
        n_dev = E // E_loc
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        counts = jnp.zeros((E,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
        f = jax.lax.pmean(counts / (N_loc * k), expert_axes)
        pbar = jax.lax.pmean(probs.mean(axis=0), expert_axes)
        aux = E * jnp.sum(f * pbar)

        Nk = N_loc * k
        eids = top_i.reshape(Nk)
        tids = jnp.arange(Nk, dtype=jnp.int32) // k
        order = jnp.argsort(eids)
        se, st = eids[order], tids[order]
        sw = top_w.reshape(Nk)[order]
        dest = se // E_loc                               # target device
        starts = jnp.searchsorted(se, jnp.arange(0, E, E_loc,
                                                 dtype=se.dtype))
        pos = jnp.arange(Nk, dtype=jnp.int32) - starts[dest].astype(jnp.int32)
        Cp = max(8, -(-math.ceil(Nk / n_dev * pair_capacity_factor) // 8) * 8)
        keep = pos < Cp

        send_x = jnp.zeros((n_dev, Cp, d), x.dtype)
        send_x = send_x.at[dest, pos].set(
            jnp.where(keep[:, None], xf[st], 0), mode="drop")
        # local expert id at destination; -1 = empty slot
        send_e = jnp.full((n_dev, Cp), -1, jnp.int32)
        send_e = send_e.at[dest, pos].set(
            jnp.where(keep, se % E_loc, -1), mode="drop")

        recv_x = jax.lax.all_to_all(send_x, expert_axes, 0, 0, tiled=False)
        recv_e = jax.lax.all_to_all(send_e, expert_axes, 0, 0, tiled=False)
        rx = recv_x.reshape(n_dev * Cp, d)
        re_ = recv_e.reshape(n_dev * Cp)

        # second-level dispatch into the E_loc local experts
        keys = jnp.where(re_ < 0, E_loc, re_)            # empties sort last
        order2 = jnp.argsort(keys)
        se2k = keys[order2]                              # ascending
        C2 = n_dev * Cp
        starts2 = jnp.searchsorted(se2k, jnp.arange(E_loc, dtype=se2k.dtype))
        eid2 = jnp.clip(se2k, 0, E_loc - 1)
        pos2 = jnp.arange(C2, dtype=jnp.int32) - starts2[eid2].astype(jnp.int32)
        valid2 = se2k < E_loc
        buf = jnp.zeros((E_loc, C2, d), x.dtype)
        buf = buf.at[eid2, jnp.where(valid2, pos2, C2)].set(
            jnp.where(valid2[:, None], rx[order2], 0), mode="drop")

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, gate)) \
            * jnp.einsum("ecd,edf->ecf", buf, up)
        out_buf = jnp.einsum("ecf,efd->ecd", h, down)    # (E_loc, C2, d)

        # undo second-level permutation
        back = jnp.zeros((C2, d), x.dtype)
        vals = out_buf[eid2, jnp.minimum(pos2, C2 - 1)] * valid2[:, None]
        back = back.at[order2].set(vals)
        back = back.reshape(n_dev, Cp, d)
        ret = jax.lax.all_to_all(back, expert_axes, 0, 0, tiled=False)

        y = jnp.zeros((N_loc, d), jnp.float32)
        contrib = ret[dest, jnp.minimum(pos, Cp - 1)] * (sw * keep)[:, None]
        y = y.at[st].add(contrib.astype(jnp.float32))
        aux = jax.lax.pmean(aux, token_axes)             # fully replicated
        return y.astype(x.dtype), aux

    from jax.sharding import PartitionSpec as P
    tok_spec = P(token_axes, None)
    exp_spec = P(expert_axes, None, None)
    in_specs = (tok_spec, P(None, None), exp_spec, exp_spec, exp_spec)
    out_specs = (tok_spec, P())
    if hasattr(jax, "shard_map"):
        try:
            sm = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_vma=False)
        except TypeError:
            # ~0.5-0.6 band: public jax.shard_map, pre-rename kwarg
            sm = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)
    else:   # pre-promotion spelling
        from jax.experimental.shard_map import shard_map as _shard_map
        sm = _shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    xf = x.reshape(B * S, d)
    y, aux = sm(xf, p["router"], p["gate"], p["up"], p["down"])
    y = y.reshape(B, S, d)
    if shared is not None:
        y = y + L.mlp(shared, x, "swiglu")
    return y, aux


def moe_ref(p, x, cfg):
    """O(N*E) dense oracle (every expert applied to every token, masked).

    Used only in tests to validate the dispatch path on small shapes.
    """
    B, S, d = x.shape
    N = B * S
    xf = x.reshape(N, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, cfg.experts_per_tok)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    gate_w = jnp.zeros((N, cfg.n_experts), jnp.float32)
    gate_w = jax.vmap(lambda g, i, w: g.at[i].set(w))(gate_w, top_i, top_w)
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", xf, p["gate"])) \
        * jnp.einsum("nd,edf->nef", xf, p["up"])
    o = jnp.einsum("nef,efd->ned", h, p["down"])
    y = jnp.einsum("ned,ne->nd", o.astype(jnp.float32), gate_w)
    y = y.astype(x.dtype).reshape(B, S, d)
    if cfg.n_shared_experts:
        y = y + L.mlp(p["shared"], x, "swiglu")
    return y
