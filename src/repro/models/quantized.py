"""int8 sidecar parameters + packed feature transport for EMSNet.

Two orthogonal artifacts of the quantized glass tier live here:

  * **Sidecar param pytrees** — ``quantize_emsnet_params`` derives,
    ONCE per fp32 pytree, a structurally parallel pytree where every
    GEMM-heavy dense weight (text ``wqkv``/``wo``/``w1``/``w2``,
    vitals ``wx``, scene ``fc``) is replaced by its int8 per-output-
    channel form ``{"w_q", "w_scale"(, "b")}``. Calibration is direct
    max-abs over the trained weights (symmetric, no zero point).
    Everything else — embeddings, layernorms, the tiny recurrent
    ``wh``, and the fusion heads — stays fp32 and is shared BY
    REFERENCE with the source pytree, so the id()-dedup fleet
    placement ships each fp32 tensor once. ``layers.dense`` dispatches
    on the sidecar form, so the unmodified encoder functions run the
    quantized math when handed a sidecar pytree.
  * **Packed features** — ``quantize_feature`` packs a (B, d) f32
    feature into ``{"q": int8 (B, d), "scale": f32 (B, 1)}``, the wire
    form whose ``payload_nbytes`` is ~4x smaller; the consuming tier
    calls ``dequantize_feature`` before fusion. Round-trip error is
    bounded by scale/2 per element (round-to-nearest).
"""
from __future__ import annotations

from repro.kernels.ops import (dequantize_rowwise, quantize_colwise,
                               quantize_rowwise)

__all__ = ["quantize_dense_params", "quantize_emsnet_params",
           "quantize_feature", "dequantize_feature",
           "is_quantized_feature"]

# the dense projections inside one BERT block that carry the FLOPs
_TEXT_DENSE = ("wqkv", "wo", "w1", "w2")


def quantize_dense_params(p):
    """fp32 ``{"w"(, "b")}`` -> int8 sidecar ``{"w_q", "w_scale"(, "b")}``."""
    wq, sw = quantize_colwise(p["w"])
    out = {"w_q": wq, "w_scale": sw}
    if "b" in p:
        out["b"] = p["b"]
    return out


def quantize_emsnet_params(params):
    """Derive the int8 sidecar pytree from a full EMSNet fp32 pytree.

    Pure and deterministic — call it once and share the result; fp32
    leaves that are not quantized are the SAME objects as in ``params``
    (reference-shared, not copied)."""
    q = {}
    for name, sub in params.items():
        if name == "text":
            q[name] = {**sub, "blocks": [
                {**blk, **{k: quantize_dense_params(blk[k])
                           for k in _TEXT_DENSE}}
                for blk in sub["blocks"]]}
        elif name == "vitals":
            q[name] = {**sub, "wx": quantize_dense_params(sub["wx"])}
        elif name == "scene":
            q[name] = {**sub, "fc": quantize_dense_params(sub["fc"])}
        else:
            # heads (and anything unrecognized) stay fp32, shared
            q[name] = sub
    return q


def quantize_feature(f):
    """Pack a (B, d) f32 feature into the int8 wire form."""
    qv, s = quantize_rowwise(f)
    return {"q": qv, "scale": s}


def is_quantized_feature(f) -> bool:
    return isinstance(f, dict) and set(f) == {"q", "scale"}


def dequantize_feature(f):
    """Unpack the wire form back to f32; identity on raw features."""
    if not is_quantized_feature(f):
        return f
    return dequantize_rowwise(f["q"], f["scale"])
