"""Attention: GQA, MLA (DeepSeek), cross-attention, sliding windows.

Prefill/train attention uses a *blocked online-softmax* (flash-style)
implementation in pure jnp — ``flash_attention_jnp`` — so the lowered
HLO never materializes an (Sq, Sk) score matrix. This is also the
reference algorithm mirrored by the Pallas kernel in
``repro.kernels.flash_attention``.

Decode paths operate on fixed-size ring-buffer caches: a slot is valid
iff its stored position is in [t - window, t] (window = buffer size for
full-attention caches), which makes the same code serve both the full
`decode_32k` cache and the sliding-window `long_500k` cache.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from . import layers as L

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Blocked flash attention (pure jnp)
# ----------------------------------------------------------------------

def _chunk(x, n, axis):
    shape = list(x.shape)
    shape[axis:axis + 1] = [n, shape[axis] // n]
    x = x.reshape(shape)
    return jnp.moveaxis(x, axis, 0)


def flash_attention_jnp(q, k, v, *, causal=True, window=0, scale=None,
                        q_positions=None, kv_positions=None,
                        q_chunk=512, kv_chunk=512):
    """Blocked attention with online softmax.

    q: (B, Sq, H, Dq); k: (B, Sk, KV, Dq); v: (B, Sk, KV, Dv).
    GQA handled by grouping H into KV groups. Returns (B, Sq, H, Dv).
    ``window`` > 0 masks keys older than window positions. Positions
    default to aligned arange (self-attention).
    """
    B, Sq, H, Dq = q.shape
    _, Sk, KV, _ = k.shape
    Dv = v.shape[-1]
    G = H // KV
    if scale is None:
        scale = 1.0 / math.sqrt(Dq)
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32) + (Sk - Sq if causal else 0)
    if kv_positions is None:
        kv_positions = jnp.arange(Sk, dtype=jnp.int32)

    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Sk)
    # pad to divisible chunk sizes (padded kv slots get position -1 -> masked)
    pq = (-Sq) % qc
    pk = (-Sk) % kc
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pq), constant_values=2**30)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pk), constant_values=-1)
    nq, nk = (Sq + pq) // qc, (Sk + pk) // kc

    qg = q.reshape(B, nq, qc, KV, G, Dq)
    qs = jnp.moveaxis(qg, 1, 0)                       # (nq, B, qc, KV, G, Dq)
    ks = _chunk(k, nk, 1)                             # (nk, B, kc, KV, Dq)
    vs = _chunk(v, nk, 1)                             # (nk, B, kc, KV, Dv)
    qpos = q_positions.reshape(nq, qc)
    kpos = kv_positions.reshape(nk, kc)

    def q_body(_, q_in):
        qi, qp = q_in                                  # (B,qc,KV,G,Dq), (qc,)

        def kv_body(carry, kv_in):
            m, l, acc = carry
            kj, vj, kp = kv_in
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            mask = kp[None, :] >= 0
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        a0 = jnp.zeros((B, KV, G, qc, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (ks, vs, kpos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]   # (B,KV,G,qc,Dv)
        return None, jnp.moveaxis(out, 3, 1)           # (B,qc,KV,G,Dv)

    _, outs = jax.lax.scan(q_body, None, (qs, qpos))    # (nq,B,qc,KV,G,Dv)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq + pq, H, Dv)
    return out[:, :Sq].astype(q.dtype)


def plain_attention_vs_cache(q, kbuf, vbuf, slot_pos, t, *, window, scale):
    """One-token decode against a ring-buffer cache.

    q: (B, 1, H, D); kbuf/vbuf: (B, W, KV, D); slot_pos: (W,) int32
    positions stored per slot (-1 = never written); t: scalar current pos.
    """
    B, _, H, Dq = q.shape
    KV = kbuf.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Dq)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   kbuf.astype(jnp.float32)) * scale
    valid = (slot_pos >= 0) & (slot_pos <= t)
    if window:
        valid &= t - slot_pos < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w, vbuf.astype(jnp.float32))
    return out.reshape(B, 1, H, vbuf.shape[-1]).astype(q.dtype)


def ring_write(buf, new, t):
    """Write ``new`` (B, 1, ...) at slot t % W of ``buf`` (B, W, ...)."""
    W = buf.shape[1]
    slot = jnp.mod(t, W)
    return jax.lax.dynamic_update_slice_in_dim(
        buf, new.astype(buf.dtype), slot, axis=1)


# ----------------------------------------------------------------------
# GQA self-attention / cross-attention module
# ----------------------------------------------------------------------

def attn_init(key, cfg, *, cross=False, kv_src_dim=None):
    d = cfg.d_model
    src = kv_src_dim if kv_src_dim is not None else d
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 4)
    bias = cfg.qkv_bias and not cross
    return {
        "wq": L.dense_init(ks[0], d, cfg.q_dim, bias=bias, dtype=dt),
        "wk": L.dense_init(ks[1], src, cfg.kv_dim, bias=bias, dtype=dt),
        "wv": L.dense_init(ks[2], src, cfg.kv_dim, bias=bias, dtype=dt),
        "wo": L.dense_init(ks[3], cfg.q_dim, d, dtype=dt),
    }


def attn_forward(p, x, positions, cfg, *, window=0, kernel="jnp"):
    """Training/prefill self-attention. Returns (out, (k, v))."""
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense(p["wq"], x).reshape(B, S, H, D)
    k = L.dense(p["wk"], x).reshape(B, S, KV, D)
    v = L.dense(p["wv"], x).reshape(B, S, KV, D)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    if kernel == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        out = flash_attention_jnp(q, k, v, causal=True, window=window)
    return L.dense(p["wo"], out.reshape(B, S, H * D)), (k, v)


def attn_decode(p, x, cache, t, cfg, *, window=0):
    """One-token decode. x: (B, 1, d). cache: {k, v, pos}. Returns out, cache."""
    B = x.shape[0]
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    tpos = jnp.full((B, 1), t, jnp.int32)
    q = L.dense(p["wq"], x).reshape(B, 1, H, D)
    k = L.dense(p["wk"], x).reshape(B, 1, KV, D)
    v = L.dense(p["wv"], x).reshape(B, 1, KV, D)
    q = L.apply_rope(q, tpos, cfg.rope_theta)
    k = L.apply_rope(k, tpos, cfg.rope_theta)
    kbuf = ring_write(cache["k"], k, t)
    vbuf = ring_write(cache["v"], v, t)
    W = kbuf.shape[1]
    pos = cache["pos"].at[jnp.mod(t, W)].set(t)
    out = plain_attention_vs_cache(q, kbuf, vbuf, pos, t,
                                   window=window, scale=1.0 / math.sqrt(D))
    out = L.dense(p["wo"], out.reshape(B, 1, H * D))
    return out, {"k": kbuf, "v": vbuf, "pos": pos}


def cross_attn_forward(p, x, cond_kv, cfg, *, kernel="jnp"):
    """Cross-attention over conditioning features.

    cond_kv: precomputed (k, v) each (B, Cs, KV, D) — the cacheable
    modality feature (paper's F_I analogue).
    """
    B, S, _ = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = L.dense(p["wq"], x).reshape(B, S, H, D)
    k, v = cond_kv
    out = flash_attention_jnp(q, k, v, causal=False)
    return L.dense(p["wo"], out.reshape(B, S, H * D))


def cross_kv(p, cond, cfg):
    """Project conditioning embeddings to (k, v) once — cached thereafter."""
    B, Cs, _ = cond.shape
    KV, D = cfg.n_kv_heads, cfg.head_dim
    k = L.dense(p["wk"], cond).reshape(B, Cs, KV, D)
    v = L.dense(p["wv"], cond).reshape(B, Cs, KV, D)
    return k, v


def attn_cache_init(cfg, batch, length, dtype):
    KV, D = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, KV, D), dtype),
        "v": jnp.zeros((batch, length, KV, D), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }


# ----------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)
# ----------------------------------------------------------------------

def mla_init(key, cfg):
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 7)
    return {
        "wq_a": L.dense_init(ks[0], d, m.q_lora_rank, dtype=dt),
        "q_norm": L.rmsnorm_init(m.q_lora_rank, dt),
        "wq_b": L.dense_init(ks[1], m.q_lora_rank,
                             H * (m.qk_nope_dim + m.qk_rope_dim), dtype=dt),
        "wkv_a": L.dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_dim, dtype=dt),
        "kv_norm": L.rmsnorm_init(m.kv_lora_rank, dt),
        "wk_b": L.dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_dim, dtype=dt),
        "wv_b": L.dense_init(ks[4], m.kv_lora_rank, H * m.v_dim, dtype=dt),
        "wo": L.dense_init(ks[5], H * m.v_dim, d, dtype=dt),
    }


def _mla_q(p, x, positions, cfg):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q = L.dense(p["wq_b"], L.rmsnorm(p["q_norm"], L.dense(p["wq_a"], x)))
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, x, positions, cfg):
    m = cfg.mla
    kv = L.dense(p["wkv_a"], x)
    ckv = L.rmsnorm(p["kv_norm"], kv[..., :m.kv_lora_rank])
    k_rope = L.apply_rope(kv[..., m.kv_lora_rank:], positions, cfg.rope_theta)
    return ckv, k_rope


def mla_forward(p, x, positions, cfg, *, window=0, kernel="jnp"):
    """Train/prefill: decompress latents and run MHA. Returns out, (ckv, k_rope)."""
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, positions, cfg)
    ckv, k_rope = _mla_ckv(p, x, positions, cfg)
    wk_b = p["wk_b"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    wv_b = p["wv_b"]["w"].reshape(m.kv_lora_rank, H, m.v_dim)
    k_nope = jnp.einsum("bsr,rhn->bshn", ckv, wk_b)
    vdec = jnp.einsum("bsr,rhv->bshv", ckv, wv_b)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (B, S, H, m.qk_rope_dim))], axis=-1)
    scale = 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = flash_attention_jnp(q, k, vdec, causal=True, window=window, scale=scale)
    out = L.dense(p["wo"], out.reshape(B, S, H * m.v_dim))
    return out, (ckv, k_rope)


def mla_decode(p, x, cache, t, cfg, *, window=0):
    """Absorbed-matrix decode against the latent cache (the serving-efficient
    form: scores and context computed directly in the kv_lora latent space)."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    tpos = jnp.full((B, 1), t, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, tpos, cfg)           # (B,1,H,*)
    ckv_new, krope_new = _mla_ckv(p, x, tpos, cfg)     # (B,1,r), (B,1,p)
    cbuf = ring_write(cache["ckv"], ckv_new, t)
    rbuf = ring_write(cache["krope"], krope_new, t)
    W = cbuf.shape[1]
    pos = cache["pos"].at[jnp.mod(t, W)].set(t)

    wk_b = p["wk_b"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    wv_b = p["wv_b"]["w"].reshape(m.kv_lora_rank, H, m.v_dim)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    s = (jnp.einsum("bqhr,bkr->bhqk", q_lat, cbuf.astype(jnp.float32))
         + jnp.einsum("bqhp,bkp->bhqk", q_rope.astype(jnp.float32),
                      rbuf.astype(jnp.float32)))
    s *= 1.0 / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    valid = (pos >= 0) & (pos <= t)
    if window:
        valid &= t - pos < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqk,bkr->bqhr", w, cbuf.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, wv_b.astype(jnp.float32))
    out = L.dense(p["wo"], out.reshape(B, 1, H * m.v_dim).astype(x.dtype))
    return out, {"ckv": cbuf, "krope": rbuf, "pos": pos}


def mla_cache_init(cfg, batch, length, dtype):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, length, m.qk_rope_dim), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }
