"""Primitive layers: init helpers, norms, RoPE, MLPs, embeddings.

Parameters are plain dict pytrees; every function here is pure and
jit/pjit friendly. Weights are stored in ``cfg.dtype`` (bf16 for the
full-size dry-run configs, f32 for CPU smoke tests); all math is done in
f32 accumulation where it matters (norms, softmax, rope).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pdtype(cfg):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False,
               dtype=jnp.float32, scale: float | None = None):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    if "w_q" in p:
        # int8 sidecar form (models.quantized.quantize_dense_params):
        # per-output-channel int8 weights + f32 scales. Activations are
        # rowwise-quantized on the fly and the contraction runs through
        # the fused int8 x int8 -> int32 -> scaled f32 Pallas GEMM.
        from repro.kernels.ops import quantized_matmul
        y = quantized_matmul(x, p["w_q"], p["w_scale"]).astype(x.dtype)
    else:
        y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"emb": (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)}


def embed(p, tokens):
    return jnp.take(p["emb"], tokens, axis=0)


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S) int32."""
    d = x.shape[-1]
    inv = jnp.asarray(rope_freqs(d, theta))            # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    if x.ndim == positions.ndim + 2:                   # (..., S, H, D): broadcast over H
        cos, sin = cos[..., None, :], sin[..., None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., ::2], xf[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# MLPs
# ----------------------------------------------------------------------

def mlp_init(key, d: int, ff: int, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"gate": dense_init(ks[0], d, ff, dtype=dtype),
                "up": dense_init(ks[1], d, ff, dtype=dtype),
                "down": dense_init(ks[2], ff, d, dtype=dtype)}
    if act == "relu2":
        return {"up": dense_init(ks[0], d, ff, dtype=dtype),
                "down": dense_init(ks[1], ff, d, dtype=dtype)}
    raise ValueError(act)


def mlp(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    elif act == "relu2":
        h = jnp.square(jax.nn.relu(dense(p["up"], x)))
    else:
        raise ValueError(act)
    return dense(p["down"], h)
