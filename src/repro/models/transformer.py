"""Composable decoder: assembles layer groups from a ModelConfig.

A config declares ``groups = ((period_specs, count), ...)``; parameters
for each group are stacked along a leading ``count`` axis and the group
is executed with ``lax.scan`` (optionally wrapped in ``jax.checkpoint``)
— this keeps the lowered HLO proportional to the *period* length, not
the layer count, which is what makes 61-layer x full-size dry-run
compiles tractable and is also the idiomatic TPU pattern (one fused
while-loop body reused across layers).

Entry points:
  init_params(cfg, key)
  forward_train(params, cfg, tokens, ...) -> (logits, aux)
  prefill(params, cfg, tokens, ...)       -> (last_logits, cache)
  decode_step(params, cfg, tokens, cache, t, ...) -> (logits, cache)
  init_cache(cfg, batch, cache_len, ...)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import attention as A
from . import layers as L
from . import moe as M
from . import rwkv as R
from . import ssm as S


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------

def init_layer(key, cfg, spec):
    d = cfg.d_model
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 6)
    p = {"norm1": L.rmsnorm_init(d, dt)}
    if spec.mixer == "attn":
        p["mixer"] = A.attn_init(ks[0], cfg)
    elif spec.mixer == "mla":
        p["mixer"] = A.mla_init(ks[0], cfg)
    elif spec.mixer == "mamba":
        p["mixer"] = S.mamba_init(ks[0], cfg)
    elif spec.mixer == "rwkv6":
        p["mixer"] = R.rwkv6_init(ks[0], cfg)
    elif spec.mixer == "cross_attn":
        p["mixer"] = A.attn_init(ks[0], cfg, cross=True)
        p["gate_attn"] = jnp.zeros((), jnp.float32)   # llama-vision gated cross
    else:
        raise ValueError(spec.mixer)
    if spec.cross:
        p["norm_c"] = L.rmsnorm_init(d, dt)
        p["cross"] = A.attn_init(ks[1], cfg, cross=True)
    if spec.mlp != "none":
        p["norm2"] = L.rmsnorm_init(d, dt)
    if spec.mlp == "dense":
        p["mlp"] = L.mlp_init(ks[2], d, cfg.d_ff, cfg.mlp_act, dtype=dt)
    elif spec.mlp == "moe":
        p["mlp"] = M.moe_init(ks[2], cfg)
    elif spec.mlp == "rwkv_cmix":
        p["mlp"] = R.cmix_init(ks[2], cfg)
    return p


def _init_period(key, cfg, specs):
    ks = jax.random.split(key, len(specs))
    return {str(i): init_layer(ks[i], cfg, s) for i, s in enumerate(specs)}


def init_params(cfg, key):
    d = cfg.d_model
    dt = L.pdtype(cfg)
    k_embed, k_head, k_groups, k_cond, k_mtp = jax.random.split(key, 5)
    params = {
        "embed": {"emb": (jax.random.normal(
            k_embed, (cfg.n_codebooks, cfg.vocab_size, d), jnp.float32)
            * 0.02).astype(dt)},
        "final_norm": L.rmsnorm_init(d, dt),
        "groups": {},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            k_head, d, cfg.n_codebooks * cfg.vocab_size, dtype=dt)
    if cfg.cond_dim:
        params["cond_proj"] = L.dense_init(k_cond, cfg.cond_dim, d, dtype=dt)
    gkeys = jax.random.split(k_groups, len(cfg.groups))
    for gi, (specs, count) in enumerate(cfg.groups):
        keys = jax.random.split(gkeys[gi], count)
        params["groups"][str(gi)] = jax.vmap(
            partial(_init_period, cfg=cfg, specs=specs))(keys)
    if cfg.mtp:
        specs_last = cfg.groups[-1][0]
        mtp_spec = specs_last[0]
        params["mtp"] = {
            "proj": L.dense_init(k_mtp, 2 * d, d, dtype=dt),
            "norm_h": L.rmsnorm_init(d, dt),
            "norm_e": L.rmsnorm_init(d, dt),
            "layer": init_layer(jax.random.fold_in(k_mtp, 1), cfg, mtp_spec),
        }
    return params


# ----------------------------------------------------------------------
# Layer application
# ----------------------------------------------------------------------

def _pack_ring(full, positions, Wc):
    """Pack per-position arrays (B, S, ...) into a ring buffer (B, Wc, ...).

    Keeps the last min(S, Wc) positions; slot of position p is p % Wc.
    Returns (buffer, slot_positions (Wc,)).
    """
    B, Sq = full.shape[0], full.shape[1]
    n = min(Sq, Wc)
    tail = full[:, Sq - n:]
    tail_pos = positions[Sq - n:]
    slots = jnp.mod(tail_pos, Wc)
    buf = jnp.zeros((B, Wc) + full.shape[2:], full.dtype)
    buf = buf.at[:, slots].set(tail)
    pos = jnp.full((Wc,), -1, jnp.int32).at[slots].set(tail_pos)
    return buf, pos


def apply_layer(lp, spec, x, ctx, mode, cache, t):
    """Returns (x, new_cache, aux)."""
    cfg = ctx["cfg"]
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    window = spec.window or (ctx["window_attn"] if spec.mixer == "attn" else 0)

    # ---- mixer sublayer ----
    h = L.rmsnorm(lp["norm1"], x, cfg.norm_eps)
    if spec.mixer in ("attn", "mla"):
        if mode == "decode":
            fn = A.attn_decode if spec.mixer == "attn" else A.mla_decode
            mix, new_cache["kv"] = fn(lp["mixer"], h, cache["kv"], t, cfg,
                                      window=window)
        else:
            fn = A.attn_forward if spec.mixer == "attn" else A.mla_forward
            mix, kv = fn(lp["mixer"], h, ctx["positions"], cfg,
                         window=window, kernel=ctx["kernel"])
            if mode == "prefill":
                new_cache["kv"] = _prefill_kv_cache(spec, kv, ctx)
    elif spec.mixer == "mamba":
        if mode == "decode":
            mix, st = S.mamba_decode(lp["mixer"], h, cache["ssm"], cfg)
        else:
            mix, st = S.mamba_forward(lp["mixer"], h, cfg)
        if mode != "train":
            new_cache["ssm"] = st
    elif spec.mixer == "rwkv6":
        prev = cache["rwkv"] if mode == "decode" else None
        mix, (last_x, state) = R.rwkv6_tmix(
            lp["mixer"], h, cfg,
            state=prev["state"] if prev else None,
            x_prev=prev["tshift"] if prev else None)
        if mode != "train":
            new_cache["rwkv"] = {"tshift": last_x, "state": state}
    elif spec.mixer == "cross_attn":
        if mode == "decode":
            ckv = (cache["cross"]["k"], cache["cross"]["v"])
        else:
            ckv = A.cross_kv(lp["mixer"], ctx["cond_x"], cfg)
            if mode == "prefill":
                new_cache["cross"] = {"k": ckv[0], "v": ckv[1]}
        mix = A.cross_attn_forward(lp["mixer"], h, ckv, cfg)
        mix = jnp.tanh(lp["gate_attn"]).astype(mix.dtype) * mix
        if mode == "decode":
            new_cache["cross"] = cache["cross"]
    else:
        raise ValueError(spec.mixer)
    x = x + mix

    # ---- optional conditioning cross-attention sublayer (musicgen) ----
    if spec.cross:
        h = L.rmsnorm(lp["norm_c"], x, cfg.norm_eps)
        if mode == "decode":
            ckv = (cache["cond"]["k"], cache["cond"]["v"])
            new_cache["cond"] = cache["cond"]
        else:
            ckv = A.cross_kv(lp["cross"], ctx["cond_x"], cfg)
            if mode == "prefill":
                new_cache["cond"] = {"k": ckv[0], "v": ckv[1]}
        x = x + A.cross_attn_forward(lp["cross"], h, ckv, cfg)

    # ---- mlp sublayer ----
    if spec.mlp != "none":
        h = L.rmsnorm(lp["norm2"], x, cfg.norm_eps)
        if spec.mlp == "dense":
            out = L.mlp(lp["mlp"], h, cfg.mlp_act)
        elif spec.mlp == "moe":
            if ctx.get("moe_a2a"):
                out, aux = M.moe_forward_a2a(lp["mlp"], h, cfg,
                                             **ctx["moe_a2a"])
            else:
                if ctx.get("moe_pre"):
                    # decode: replicate the (tiny) token activations over
                    # the model axis so dispatch against expert-sharded
                    # weights is comm-free (§Perf decode iteration)
                    h = ctx["moe_pre"](h)
                out, aux = M.moe_forward(lp["mlp"], h, cfg)
        elif spec.mlp == "rwkv_cmix":
            prev = cache.get("cmix") if mode == "decode" else None
            out, last_c = R.rwkv6_cmix(lp["mlp"], h, cfg, x_prev=prev)
            if mode != "train":
                new_cache["cmix"] = last_c
        x = x + out
    return x, new_cache, aux


def _prefill_kv_cache(spec, kv, ctx):
    Wc = ctx["cache_len"]
    positions = ctx["positions"][0] if ctx["positions"].ndim == 2 else ctx["positions"]
    if spec.mixer == "attn":
        k, v = kv
        kb, pos = _pack_ring(k, positions, Wc)
        vb, _ = _pack_ring(v, positions, Wc)
        return {"k": kb, "v": vb, "pos": pos}
    ckv, krope = kv
    cb, pos = _pack_ring(ckv, positions, Wc)
    rb, _ = _pack_ring(krope, positions, Wc)
    return {"ckv": cb, "krope": rb, "pos": pos}


def apply_period(pp, specs, x, ctx, mode, cache, t):
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    for i, spec in enumerate(specs):
        lc = cache[str(i)] if cache is not None else None
        x, nc, a = apply_layer(pp[str(i)], spec, x, ctx, mode, lc, t)
        new_cache[str(i)] = nc
        aux = aux + a
    return x, new_cache, aux


# ----------------------------------------------------------------------
# Forward passes
# ----------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens):
    emb = params["embed"]["emb"]                       # (ncb, V, d)
    if cfg.n_codebooks == 1:
        return jnp.take(emb[0], tokens, axis=0)
    parts = [jnp.take(emb[c], tokens[..., c], axis=0)
             for c in range(cfg.n_codebooks)]
    return sum(parts)


def _logits(params, cfg, x):
    if cfg.tie_embeddings:
        w = params["embed"]["emb"].reshape(cfg.n_codebooks * cfg.vocab_size,
                                           cfg.d_model).T
        out = x @ w
    else:
        out = L.dense(params["lm_head"], x)
    if cfg.n_codebooks > 1:
        out = out.reshape(out.shape[:-1] + (cfg.n_codebooks, cfg.vocab_size))
    return out


def _cond_x(params, cfg, cond):
    if cond is None:
        return None
    return L.dense(params["cond_proj"], cond.astype(L.pdtype(cfg)))


def _make_ctx(cfg, positions, cond_x, *, kernel="jnp", window_attn=0,
              cache_len=0, constrain=None, moe_a2a=None, moe_pre=None):
    return {"cfg": cfg, "positions": positions, "cond_x": cond_x,
            "kernel": kernel, "window_attn": window_attn,
            "cache_len": cache_len, "constrain": constrain or (lambda a: a),
            "moe_a2a": moe_a2a, "moe_pre": moe_pre}


def forward_train(params, cfg, tokens, *, cond=None, next_tokens=None,
                  kernel="jnp", constrain=None, moe_a2a=None):
    """Returns (logits, {'moe_aux', 'mtp_logits'?})."""
    B, Sq = tokens.shape[0], tokens.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x = _embed_tokens(params, cfg, tokens)
    if constrain:
        x = constrain(x)
    ctx = _make_ctx(cfg, positions, _cond_x(params, cfg, cond),
                    kernel=kernel, constrain=constrain, moe_a2a=moe_a2a)
    aux = jnp.zeros((), jnp.float32)

    for gi, (specs, count) in enumerate(cfg.groups):
        def body(carry, pp, specs=specs):
            x, aux = carry
            x, _, a = apply_period(pp, specs, x, ctx, "train", None, None)
            if constrain:
                x = constrain(x)
            return (x, aux + a), None
        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), _ = jax.lax.scan(body, (x, aux), params["groups"][str(gi)])

    h = x
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = _logits(params, cfg, x)
    extras = {"moe_aux": aux}

    if cfg.mtp and next_tokens is not None:
        mp = params["mtp"]
        e = _embed_tokens(params, cfg, next_tokens)
        hcat = jnp.concatenate([L.rmsnorm(mp["norm_h"], h, cfg.norm_eps),
                                L.rmsnorm(mp["norm_e"], e, cfg.norm_eps)], -1)
        h2 = L.dense(mp["proj"], hcat)
        spec = cfg.groups[-1][0][0]
        h2, _, a2 = apply_layer(mp["layer"], spec, h2, ctx, "train", None, None)
        extras["moe_aux"] = extras["moe_aux"] + a2
        h2 = L.rmsnorm(params["final_norm"], h2, cfg.norm_eps)
        extras["mtp_logits"] = _logits(params, cfg, h2)
    return logits, extras


def prefill(params, cfg, tokens, *, cond=None, cache_len=None,
            window_attn=0, kernel="jnp", constrain=None, moe_a2a=None):
    """Process a full prompt; returns (last_token_logits, cache)."""
    B, Sq = tokens.shape[0], tokens.shape[1]
    if cache_len is None:
        cache_len = Sq
    positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    x = _embed_tokens(params, cfg, tokens)
    if constrain:
        x = constrain(x)
    ctx = _make_ctx(cfg, positions, _cond_x(params, cfg, cond), kernel=kernel,
                    window_attn=window_attn, cache_len=cache_len,
                    constrain=constrain, moe_a2a=moe_a2a)
    caches = {}
    for gi, (specs, count) in enumerate(cfg.groups):
        def body(x, pp, specs=specs):
            x, nc, _ = apply_period(pp, specs, x, ctx, "prefill", None, None)
            if constrain:
                x = constrain(x)
            return x, nc
        x, gc = jax.lax.scan(body, x, params["groups"][str(gi)])
        caches[str(gi)] = gc
    x = L.rmsnorm(params["final_norm"], x[:, -1:, :], cfg.norm_eps)
    return _logits(params, cfg, x), caches


def decode_step(params, cfg, tokens, cache, t, *, window_attn=0,
                constrain=None, moe_pre=None):
    """One-token decode. tokens: (B, 1[, ncb]); t: scalar position."""
    x = _embed_tokens(params, cfg, tokens)
    B = x.shape[0]
    positions = jnp.full((B, 1), t, jnp.int32)
    ctx = _make_ctx(cfg, positions, None, window_attn=window_attn,
                    constrain=constrain, moe_pre=moe_pre)
    new_caches = {}
    for gi, (specs, count) in enumerate(cfg.groups):
        def body(x, xs, specs=specs):
            pp, lc = xs
            x, nc, _ = apply_period(pp, specs, x, ctx, "decode", lc, t)
            return x, nc
        x, gc = jax.lax.scan(body, x, (params["groups"][str(gi)], cache[str(gi)]))
        new_caches[str(gi)] = gc
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return _logits(params, cfg, x), new_caches


# ----------------------------------------------------------------------
# Cache init (shape source of truth for decode input specs)
# ----------------------------------------------------------------------

def init_layer_cache(cfg, spec, batch, cache_len, dtype):
    c = {}
    if spec.mixer == "attn":
        c["kv"] = A.attn_cache_init(cfg, batch, cache_len, dtype)
    elif spec.mixer == "mla":
        c["kv"] = A.mla_cache_init(cfg, batch, cache_len, dtype)
    elif spec.mixer == "mamba":
        c["ssm"] = S.mamba_cache_init(cfg, batch, dtype)
    elif spec.mixer == "rwkv6":
        rc = R.rwkv6_cache_init(cfg, batch, dtype)
        c["rwkv"] = {"tshift": rc["tshift"], "state": rc["state"]}
    elif spec.mixer == "cross_attn":
        KV, D = cfg.n_kv_heads, cfg.head_dim
        c["cross"] = {"k": jnp.zeros((batch, cfg.cond_seq_len, KV, D), dtype),
                      "v": jnp.zeros((batch, cfg.cond_seq_len, KV, D), dtype)}
    if spec.cross:
        KV, D = cfg.n_kv_heads, cfg.head_dim
        c["cond"] = {"k": jnp.zeros((batch, cfg.cond_seq_len, KV, D), dtype),
                     "v": jnp.zeros((batch, cfg.cond_seq_len, KV, D), dtype)}
    if spec.mlp == "rwkv_cmix":
        c["cmix"] = jnp.zeros((batch, 1, cfg.d_model), dtype)
    return c


def init_cache(cfg, batch, cache_len, dtype=None):
    dtype = dtype or L.pdtype(cfg)
    caches = {}
    for gi, (specs, count) in enumerate(cfg.groups):
        period = {str(i): init_layer_cache(cfg, s, batch, cache_len, dtype)
                  for i, s in enumerate(specs)}
        caches[str(gi)] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (count,) + a.shape).copy(), period)
    return caches
