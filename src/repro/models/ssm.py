"""Mamba-1 selective-SSM block (Jamba's recurrent mixer).

The selective scan runs as a ``lax.scan`` over time with per-step
discretization (dA, dBx computed inside the body) so the lowered HLO
never materializes the (B, S, d_inner, d_state) tensors — only the
(B, d_inner, d_state) carry lives across steps. Decode is a single
recurrence step against a (conv_state, ssm_state) cache.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L


def dt_rank(cfg) -> int:
    return max(cfg.d_model // 16, 1)


def mamba_init(key, cfg):
    d = cfg.d_model
    m = cfg.mamba
    di, st, dc = m.d_inner(d), m.d_state, m.d_conv
    dtr = dt_rank(cfg)
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": L.dense_init(ks[0], d, 2 * di, dtype=dt),
        "conv_w": (jax.random.normal(ks[1], (dc, di), jnp.float32)
                   * (1.0 / math.sqrt(dc))).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": L.dense_init(ks[2], di, dtr + 2 * st, dtype=dt),
        "dt_w": L.dense_init(ks[3], dtr, di, dtype=dt),
        "dt_bias": jnp.full((di,), math.log(math.e - 1), jnp.float32),  # softplus^-1(1)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(ks[4], di, d, dtype=dt),
    }


def _causal_conv(p, x, cfg):
    """Depthwise causal conv. x: (B, S, di) -> (B, S, di)."""
    dc = cfg.mamba.d_conv
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(dc))
    return out + p["conv_b"]


def _ssm_scan(p, xr, dt_, B_, C_, h0):
    """Selective scan. xr/dt_: (B,S,di); B_/C_: (B,S,st); h0: (B,di,st)."""
    A = -jnp.exp(p["A_log"])                                  # (di, st)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                              # (B,di),(B,di),(B,st),(B,st)
        dA = jnp.exp(dt_t[..., None] * A)                      # (B,di,st)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]        # (B,di,st)
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, c_t)                   # (B,di)
        return h, y

    tm = lambda a: jnp.moveaxis(a, 1, 0)                       # time-major
    h, ys = jax.lax.scan(step, h0,
                         (tm(xr.astype(jnp.float32)), tm(dt_), tm(B_), tm(C_)))
    return h, jnp.moveaxis(ys, 0, 1)                           # (B,S,di)


def _projections(p, xc, cfg):
    st = cfg.mamba.d_state
    dtr = dt_rank(cfg)
    proj = L.dense(p["x_proj"], xc)
    dt_in, B_, C_ = jnp.split(proj, [dtr, dtr + st], axis=-1)
    dt_ = jax.nn.softplus(L.dense(p["dt_w"], dt_in).astype(jnp.float32)
                          + p["dt_bias"])
    return dt_, B_.astype(jnp.float32), C_.astype(jnp.float32)


def mamba_forward(p, x, cfg):
    """Train/prefill. x: (B, S, d). Returns (out, (conv_state, ssm_state))."""
    B, S, _ = x.shape
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    xz = L.dense(p["in_proj"], x)
    xr, z = xz[..., :di], xz[..., di:]
    conv_in = xr
    xc = jax.nn.silu(_causal_conv(p, conv_in, cfg))
    dt_, B_, C_ = _projections(p, xc, cfg)
    h0 = jnp.zeros((B, di, m.d_state), jnp.float32)
    h, ys = _ssm_scan(p, xc, dt_, B_, C_, h0)
    y = ys + p["D"] * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = L.dense(p["out_proj"], y)
    # cache: last (d_conv - 1) pre-conv inputs + final ssm state
    conv_state = conv_in[:, S - (m.d_conv - 1):, :] if S >= m.d_conv - 1 else \
        jnp.pad(conv_in, ((0, 0), (m.d_conv - 1 - S, 0), (0, 0)))
    return out, (conv_state, h)


def mamba_decode(p, x, cache, cfg):
    """One-token decode. x: (B, 1, d); cache: (conv_state, ssm_state)."""
    conv_state, h = cache
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    xz = L.dense(p["in_proj"], x)
    xr, z = xz[..., :di], xz[..., di:]
    window = jnp.concatenate([conv_state, xr], axis=1)         # (B, dc, di)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)[:, None, :]                           # (B,1,di)
    dt_, B_, C_ = _projections(p, xc, cfg)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt_[:, 0, :, None] * A)
    dBx = (dt_[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] * B_[:, 0, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bds,bs->bd", h, C_[:, 0])
    y = y + p["D"] * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = L.dense(p["out_proj"], y)[:, None, :]
    return out, (window[:, 1:, :], h)


def mamba_cache_init(cfg, batch, dtype):
    m = cfg.mamba
    di = m.d_inner(cfg.d_model)
    return (jnp.zeros((batch, m.d_conv - 1, di), dtype),
            jnp.zeros((batch, di, m.d_state), jnp.float32))
