"""RWKV-6 "Finch" block: time-mix with data-dependent decay + channel-mix.

The time-mix recurrence per head (head dim n):
    S_t = diag(w_t) @ S_{t-1} + k_t^T v_t          (S: n x n state)
    y_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
with w_t = exp(-exp(w0 + lora(x_t))) — the data-dependent decay that
distinguishes v6 from v5. Trained with a lax.scan over time; decode is
one recurrence step. The constant-size state is the arch's "feature
cache": long_500k decode touches no sequence-length buffers at all.

A Pallas TPU kernel for the chunked form lives in
``repro.kernels.rwkv6``; this module is its jnp reference.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L


def rwkv6_init(key, cfg):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 10)
    scale = 1.0 / math.sqrt(d)

    def mat(k, a, b, s=scale):
        return (jax.random.normal(k, (a, b), jnp.float32) * s).astype(dt)

    # decay init: spread across heads/channels (v6 style)
    decay = jnp.linspace(-6.0, -1.0, d, dtype=jnp.float32)
    return {
        "mu": jnp.full((5, d), 0.5, dt),          # token-shift mix for r,k,v,w,g
        "wr": mat(ks[0], d, d), "wk": mat(ks[1], d, d),
        "wv": mat(ks[2], d, d), "wg": mat(ks[3], d, d),
        "wo": mat(ks[4], d, d),
        "w0": decay,                               # (d,)
        "w_lora_a": mat(ks[5], d, 64, 0.01),
        "w_lora_b": mat(ks[6], 64, d, 0.01),
        "u": (jax.random.normal(ks[7], (H, hd), jnp.float32) * 0.1),
        "ln_x": L.layernorm_init(hd, jnp.float32),  # per-head group norm
    }


def _tmix_projections(p, x, x_prev, cfg):
    """x: (B, S, d); x_prev: previous-token x (token shift)."""
    H = cfg.d_model // cfg.rwkv_head_dim
    hd = cfg.rwkv_head_dim
    B, S, d = x.shape
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), x_prev.astype(jnp.float32)

    def mix(i):
        return (xf + (xpf - xf) * mu[i]).astype(x.dtype)

    r = (mix(0) @ p["wr"]).reshape(B, S, H, hd)
    k = (mix(1) @ p["wk"]).reshape(B, S, H, hd)
    v = (mix(2) @ p["wv"]).reshape(B, S, H, hd)
    wx = mix(3)
    lora = jnp.tanh(wx @ p["w_lora_a"].astype(wx.dtype)) @ p["w_lora_b"].astype(wx.dtype)
    w = jnp.exp(-jnp.exp(p["w0"] + lora.astype(jnp.float32)))   # (B,S,d) in (0,1)
    w = w.reshape(B, S, H, hd)
    g = jax.nn.silu((mix(4) @ p["wg"])).reshape(B, S, H, hd)
    return r, k, v, w, g


def _wkv_scan(r, k, v, w, u, S0):
    """Recurrence. r/k/v/w: (B, S, H, n) f32; u: (H, n); S0: (B, H, n, n)."""
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                               # (B,H,n)
        kv = k_t[..., :, None] * v_t[..., None, :]             # (B,H,n,n)
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    tm = lambda a: jnp.moveaxis(a, 1, 0)
    S, ys = jax.lax.scan(step, S0, (tm(r), tm(k), tm(v), tm(w)))
    return S, jnp.moveaxis(ys, 0, 1)                           # (B,S,H,n)


def rwkv6_tmix(p, x, cfg, *, state=None, x_prev=None):
    """Time-mix. Returns (out, (last_x, state))."""
    B, S, d = x.shape
    hd = cfg.rwkv_head_dim
    H = d // hd
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), x.dtype)
    shifted = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)
    r, k, v, w, g = _tmix_projections(p, x, shifted, cfg)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    f32 = lambda a: a.astype(jnp.float32)
    state, y = _wkv_scan(f32(r), f32(k), f32(v), f32(w), p["u"], state)
    y = L.layernorm(p["ln_x"], y)                               # per-head norm
    y = (y * g.astype(jnp.float32)).reshape(B, S, d).astype(x.dtype)
    out = y @ p["wo"]
    return out, (x[:, -1:, :], state)


def cmix_init(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    dt = L.pdtype(cfg)
    ks = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "mu": jnp.full((2, d), 0.5, dt),
        "wk": (jax.random.normal(ks[0], (d, ff), jnp.float32) * s).astype(dt),
        "wv": (jax.random.normal(ks[1], (ff, d), jnp.float32)
               * (1.0 / math.sqrt(ff))).astype(dt),
        "wr": (jax.random.normal(ks[2], (d, d), jnp.float32) * s).astype(dt),
    }


def rwkv6_cmix(p, x, cfg, *, x_prev=None):
    """Channel-mix (relu^2). Returns (out, last_x)."""
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, 1, d), x.dtype)
    shifted = jnp.concatenate([x_prev, x[:, :-1, :]], axis=1)
    mu = p["mu"].astype(jnp.float32)
    xf, xpf = x.astype(jnp.float32), shifted.astype(jnp.float32)
    xk = (xf + (xpf - xf) * mu[0]).astype(x.dtype)
    xr = (xf + (xpf - xf) * mu[1]).astype(x.dtype)
    kv = jnp.square(jax.nn.relu(xk @ p["wk"])) @ p["wv"]
    out = jax.nn.sigmoid(xr @ p["wr"]) * kv
    return out, x[:, -1:, :]


def rwkv6_cache_init(cfg, batch, dtype):
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = d // hd
    return {
        "tshift": jnp.zeros((batch, 1, d), dtype),
        "cshift": jnp.zeros((batch, 1, d), dtype),
        "state": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }
