"""EMSNet: the paper's multimodal multitask model, in JAX.

Three backbone encoders (paper Table 1):
  * text:   TinyBERT / MobileBERT / BERTBase — bidirectional transformer
            over symptom-sentence tokens, masked mean-pooled to F_T.
  * vitals: RNN / LSTM / GRU over the (T, 6) time series -> F_V.
  * scene:  FC over the object-detection one-hot -> F_I.
Feature concatenation F_C = [F_T ; F_V ; F_I] (the fusion the paper
selected over dot-product/weighted-sum/attention), then three headers:
protocol (46-way), medicine type (18-way), quantity (regression).
Tasks 4/5 (dosage via med-math, disease history via dictionary) are
deterministic post-processing in ``repro.core.medmath``.

Every encoder is an independent pure function over its own parameter
subtree — exactly the property EMSServe's modality-aware splitter
exploits.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.emsnet import EMSNetConfig
from . import layers as L


# ----------------------------------------------------------------------
# Text encoder (BERT-class, bidirectional)
# ----------------------------------------------------------------------

def _block_init(key, d, heads, ff):
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": L.layernorm_init(d),
        "wqkv": L.dense_init(ks[0], d, 3 * d, bias=True),
        "wo": L.dense_init(ks[1], d, d, bias=True),
        "ln2": L.layernorm_init(d),
        "w1": L.dense_init(ks[2], d, ff, bias=True),
        "w2": L.dense_init(ks[3], ff, d, bias=True),
    }


def text_encoder_init(key, cfg: EMSNetConfig):
    n_layers, d, heads, ff = cfg.text_dims
    ks = jax.random.split(key, n_layers + 3)
    return {
        "tok": L.embedding_init(ks[0], cfg.vocab_size, d),
        "pos": L.embedding_init(ks[1], cfg.max_text_len, d),
        "ln": L.layernorm_init(d),
        "blocks": [_block_init(ks[2 + i], d, heads, ff) for i in range(n_layers)],
    }


def _bert_block(p, x, mask, heads, *, flash=None, segments=None):
    """``flash=(kv_lengths, interpret)`` routes attention through the
    Pallas flash kernel (key-padding-masked, non-causal); None keeps the
    materialized einsum path. Both see the same qkv/wo projections.

    ``segments=(seg_ids, use_flash, block, interpret)`` is the ragged
    layout: ``seg_ids`` (B, S) int32 gives each position's row id (-1 =
    padding); a query attends a key iff their ids match. With
    ``use_flash`` the segment-masked flash kernel runs at the fixed
    ``block`` size (the bit-parity path); otherwise a materialized
    pairwise mask feeds the einsum path."""
    B, S, d = x.shape
    hd = d // heads
    h = L.layernorm(p["ln1"], x)
    qkv = L.dense(p["wqkv"], h).reshape(B, S, 3, heads, hd)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    if segments is not None:
        seg, use_flash, block, interpret = segments
        if use_flash:
            from repro.kernels.flash_attention import flash_attention
            att = flash_attention(q, k, v, causal=False, segment_ids=seg,
                                  block_q=block, block_k=block,
                                  interpret=interpret).reshape(B, S, d)
        else:
            pair = (seg[:, :, None] == seg[:, None, :]) & (seg >= 0)[:, None, :]
            s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
            s = jnp.where(pair[:, None], s, -1e30)
            w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
            att = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, d)
    elif flash is not None:
        from repro.kernels.flash_attention import flash_attention
        kv_lengths, interpret = flash
        att = flash_attention(q, k, v, causal=False, kv_lengths=kv_lengths,
                              interpret=interpret).reshape(B, S, d)
    else:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
        s = jnp.where(mask[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(x.dtype)
        att = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, d)
    x = x + L.dense(p["wo"], att)
    h = L.layernorm(p["ln2"], x)
    x = x + L.dense(p["w2"], jax.nn.gelu(L.dense(p["w1"], h)))
    return x


def text_encoder(p, cfg: EMSNetConfig, tokens):
    """tokens: (B, S) int32, 0 = PAD, or a ragged payload dict from
    ``RaggedBatch.pack("text", ...)`` (keys tokens/row_ids/pos/offsets/
    lengths). Returns F_T (B, d_text) — for the ragged form, one feature
    row per packed row.

    The flash path assumes PAD-only suffixes (valid tokens first), which
    both the tokenizer layout and the bucketer's right-padding guarantee;
    the einsum and segment paths handle arbitrary masks. With
    ``cfg.flash_segments`` the natural path runs the segment-masked
    flash kernel at ``cfg.flash_block`` — the bit-parity reference for
    the ragged layout (same kernel, same block reduction shapes).
    """
    if isinstance(tokens, dict):
        return _text_encoder_ragged(p, cfg, tokens)
    _, d, heads, _ = cfg.text_dims
    flash = segments = None
    if cfg.use_flash_text and cfg.flash_segments:
        # pad S to a flash_block multiple: every GEMM then has M >= block
        # like the packed layout (an M=1 row would lower to a
        # differently-accumulated matvec and break bit parity)
        b = cfg.flash_block
        Sp = -(-tokens.shape[1] // b) * b
        tokens = jnp.pad(tokens, ((0, 0), (0, Sp - tokens.shape[1])))
        mask = tokens > 0
        seg = jnp.where(mask, 0, -1).astype(jnp.int32)
        segments = (seg, True, b, cfg.flash_interpret)
        pos = jnp.minimum(jnp.arange(Sp), cfg.max_text_len - 1)
        x = L.embed(p["tok"], tokens) + p["pos"]["emb"][pos][None]
        for blk in p["blocks"]:
            x = _bert_block(blk, x, mask, heads, segments=segments)
        x = L.layernorm(p["ln"], x)
        m = mask[..., None].astype(x.dtype)
        return (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)
    mask = tokens > 0
    S = tokens.shape[1]
    if cfg.use_flash_text:
        flash = (mask.sum(-1).astype(jnp.int32), cfg.flash_interpret)
    x = L.embed(p["tok"], tokens) + p["pos"]["emb"][None, :S]
    for blk in p["blocks"]:
        x = _bert_block(blk, x, mask, heads, flash=flash, segments=segments)
    x = L.layernorm(p["ln"], x)
    m = mask[..., None].astype(x.dtype)
    return (x * m).sum(1) / jnp.maximum(m.sum(1), 1.0)


def _text_encoder_ragged(p, cfg: EMSNetConfig, packed):
    """Concatenated ragged text: ONE call encodes every pending row.

    ``packed`` is ``RaggedBatch.pack("text", rows)``. Attention is
    segment-masked (row ids from the pack), the positional table is
    gathered at each position's within-row index, and pooling gathers a
    ``max_text_len`` window at each row's offset — masked mean over
    valid tokens exactly as the natural path. Gap/tail positions carry
    id -1: they are masked as keys in every block and excluded from
    pooling, so their (PAD-embedding) activations never reach a row's
    feature."""
    _, d, heads, _ = cfg.text_dims
    toks = packed["tokens"]                         # (1, T)
    seg = packed["row_ids"][None, :]                # (1, T)
    T = toks.shape[1]
    mask = seg >= 0
    segments = (seg, cfg.use_flash_text, cfg.flash_block, cfg.flash_interpret)
    x = L.embed(p["tok"], toks) + p["pos"]["emb"][packed["pos"]][None]
    for blk in p["blocks"]:
        x = _bert_block(blk, x, mask, heads, segments=segments)
    x = L.layernorm(p["ln"], x)
    offsets, lengths = packed["offsets"], packed["lengths"]
    cap = min(cfg.max_text_len, T)
    idx = jnp.clip(offsets[:, None] + jnp.arange(cap)[None, :], 0, T - 1)
    xw = x[0][idx]                                  # (R, cap, d)
    tw = toks[0][idx]
    mw = ((jnp.arange(cap)[None, :] < lengths[:, None])
          & (tw > 0))[..., None].astype(x.dtype)
    return (xw * mw).sum(1) / jnp.maximum(mw.sum(1), 1.0)


# ----------------------------------------------------------------------
# Vitals encoder (RNN / LSTM / GRU)
# ----------------------------------------------------------------------

def vitals_encoder_init(key, cfg: EMSNetConfig):
    d_in, h = cfg.n_vitals, cfg.vitals_hidden
    ks = jax.random.split(key, 3)
    gates = {"rnn": 1, "gru": 3, "lstm": 4}[cfg.vitals_encoder]
    return {
        "wx": L.dense_init(ks[0], d_in, gates * h, bias=True),
        "wh": L.dense_init(ks[1], h, gates * h),
    }


def vitals_encoder(p, cfg: EMSNetConfig, vitals):
    """vitals: (B, T, n_vitals) float, a bucketed payload
    ``{"x": (B, T_b, n_vitals), "len": (B,) int32}`` (zero-padded to a
    length bucket), or a ragged payload from
    ``RaggedBatch.pack("vitals", ...)`` (keys x/reset/offsets/lengths —
    many series concatenated along time). Returns F_V (B, vitals_hidden);
    for the ragged form, one feature row per packed row.

    All three forms run ONE scan body: reset gate (zero the carry at a
    packed row's first step), valid gate (freeze the carry on padded
    steps), emit the hidden state. The natural path feeds constant
    all-false/all-true gates through ``optimization_barrier`` so XLA
    fuses the body identically across paths — that shared fusion is what
    makes the ragged final states bit-identical to per-row runs."""
    length = offsets = lengths = reset_in = None
    if isinstance(vitals, dict) and "offsets" in vitals:
        x = vitals["x"]
        reset_in = vitals["reset"]
        offsets, lengths = vitals["offsets"], vitals["lengths"]
    elif isinstance(vitals, dict):
        x, length = vitals["x"], vitals["len"]
    else:
        x = vitals
    B, T, _ = x.shape
    h = cfg.vitals_hidden
    kind = cfg.vitals_encoder
    x_proj = L.dense(p["wx"], x)                     # (B, T, gates*h)

    def rnn_step(hc, xt):
        hp = hc
        out = jnp.tanh(xt + hp @ p["wh"]["w"])
        return out, None

    def gru_step(hc, xt):
        hp = hc
        zr = xt + hp @ p["wh"]["w"]
        z = jax.nn.sigmoid(zr[:, :h])
        r = jax.nn.sigmoid(zr[:, h:2 * h])
        n = jnp.tanh(xt[:, 2 * h:] + (r * hp) @ p["wh"]["w"][:, 2 * h:])
        out = (1 - z) * n + z * hp
        return out, None

    def lstm_step(carry, xt):
        hp, cp = carry
        g = xt + hp @ p["wh"]["w"]
        i = jax.nn.sigmoid(g[:, :h])
        f = jax.nn.sigmoid(g[:, h:2 * h] + 1.0)
        o = jax.nn.sigmoid(g[:, 2 * h:3 * h])
        c = f * cp + i * jnp.tanh(g[:, 3 * h:])
        return (o * jnp.tanh(c), c), None

    xs = jnp.moveaxis(x_proj, 1, 0)                  # (T, B, gates*h)
    h0 = jnp.zeros((B, h), x.dtype)
    step = {"lstm": lstm_step, "gru": gru_step, "rnn": rnn_step}[kind]
    init = (h0, h0) if kind == "lstm" else h0

    if offsets is not None:
        # packed layout is B == 1; the carry crosses row boundaries but
        # the reset gate zeroes it at each row's first step
        reset = jnp.broadcast_to(reset_in, (T, B, 1))
        valid = jax.lax.optimization_barrier(jnp.ones((T, B, 1), bool))
    elif length is not None:
        reset = jax.lax.optimization_barrier(jnp.zeros((T, B, 1), bool))
        valid = (jax.lax.broadcasted_iota(jnp.int32, (T, B, 1), 0)
                 < length[None, :, None])            # (T, B, 1)
    else:
        reset = jax.lax.optimization_barrier(jnp.zeros((T, B, 1), bool))
        valid = jax.lax.optimization_barrier(jnp.ones((T, B, 1), bool))

    def body(carry, inp):
        xt, rt, vt = inp
        c1 = jax.tree.map(lambda c: jnp.where(rt, jnp.zeros_like(c), c), carry)
        new, _ = step(c1, xt)
        out = jax.tree.map(lambda n_, c_: jnp.where(vt, n_, c_), new, c1)
        return out, (out[0] if kind == "lstm" else out)

    carry, ys = jax.lax.scan(body, init, (xs, reset, valid))
    if offsets is not None:
        idx = jnp.clip(offsets + lengths - 1, 0, T - 1)
        hfin = ys[idx, 0]                            # (R, h)
        return jnp.where((lengths > 0)[:, None], hfin, jnp.zeros_like(hfin))
    return carry[0] if kind == "lstm" else carry


# ----------------------------------------------------------------------
# Scene encoder + headers
# ----------------------------------------------------------------------

def scene_encoder_init(key, cfg: EMSNetConfig):
    return {"fc": L.dense_init(key, cfg.scene_dim, cfg.scene_hidden, bias=True)}


def scene_encoder(p, cfg: EMSNetConfig, scene):
    """scene: (B, scene_dim) one-hot-ish floats. Returns F_I."""
    return jax.nn.relu(L.dense(p["fc"], scene))


def heads_init(key, cfg: EMSNetConfig, modalities):
    dims = cfg.feature_dims
    fc_dim = sum(dims[m] for m in modalities)
    ks = jax.random.split(key, 3)
    return {
        "protocol": L.dense_init(ks[0], fc_dim, cfg.n_protocols, bias=True),
        "medicine": L.dense_init(ks[1], fc_dim, cfg.n_medicines, bias=True),
        "quantity": L.dense_init(ks[2], fc_dim, 1, bias=True),
    }


def fuse_and_heads(p, features: dict, modalities):
    """Concatenate per-modality features (paper's fusion) and run headers."""
    fc = jnp.concatenate([features[m] for m in modalities], axis=-1)
    return {
        "protocol_logits": L.dense(p["protocol"], fc),
        "medicine_logits": L.dense(p["medicine"], fc),
        "quantity": L.dense(p["quantity"], fc)[..., 0],
    }


def slice_heads(heads, cfg: EMSNetConfig, all_modalities, subset):
    """Restrict full-fusion head params to a modality subset.

    Because fusion is concatenation followed by a dense layer, a head
    over the subset's features IS the full head with only the weight
    rows belonging to the subset's slice of F_C (biases unchanged).
    This is what lets one trained parameter set serve every partial-
    modality combination — no per-subset heads to train or store.
    """
    dims = cfg.feature_dims
    offs, off = {}, 0
    for m in all_modalities:
        offs[m] = off
        off += dims[m]
    subset = tuple(m for m in all_modalities if m in set(subset))

    def take(p):
        w = jnp.concatenate([p["w"][offs[m]:offs[m] + dims[m]]
                             for m in subset], axis=0)
        return {"w": w, **({"b": p["b"]} if "b" in p else {})}

    return {k: take(v) for k, v in heads.items()}


def partial_forward(params, cfg: EMSNetConfig, batch: dict, subset,
                    all_modalities=("text", "vitals", "scene")):
    """One-shot forward restricted to an observed-modality subset:
    encode only the subset, fuse through the sliced full heads. With
    ``subset == all_modalities`` this equals ``forward`` exactly (the
    row slices reassemble the full weight matrices)."""
    subset = tuple(m for m in all_modalities if m in set(subset))
    feats = {m: encode(params, cfg, m, batch[m]) for m in subset}
    ph = slice_heads(params["heads"], cfg, all_modalities, subset)
    return fuse_and_heads(ph, feats, subset)


# ----------------------------------------------------------------------
# Whole model
# ----------------------------------------------------------------------

ENCODERS = {
    "text": (text_encoder_init, text_encoder),
    "vitals": (vitals_encoder_init, vitals_encoder),
    "scene": (scene_encoder_init, scene_encoder),
}


def init_params(cfg: EMSNetConfig, key, modalities=("text", "vitals", "scene")):
    ks = jax.random.split(key, len(modalities) + 1)
    p = {m: ENCODERS[m][0](ks[i], cfg) for i, m in enumerate(modalities)}
    p["heads"] = heads_init(ks[-1], cfg, modalities)
    return p


def encode(params, cfg: EMSNetConfig, modality: str, inputs):
    return ENCODERS[modality][1](params[modality], cfg, inputs)


def forward(params, cfg: EMSNetConfig, batch: dict,
            modalities=("text", "vitals", "scene"), *, freeze=()):
    """Full multimodal forward. batch keys = modality names."""
    feats = {}
    for m in modalities:
        f = encode(params, cfg, m, batch[m])
        if m in freeze:
            f = jax.lax.stop_gradient(f)
        feats[m] = f
    return fuse_and_heads(params["heads"], feats, modalities)
