"""Admission / degradation control for the serving fleet.

Open-loop load has no back-pressure: when the offered rate exceeds a
replica's service rate, its backlog — and therefore every *new*
session's time-to-first-prediction — grows without bound ("queueing to
death"). The controller gates **new sessions only** (an admitted
incident is never abandoned mid-flight): when the replica a session
routes to predicts a first-prediction wait beyond the deadline, the
session is *shed* to the on-glass provisional path — the same
degradation the ``stream+tiered`` composition uses while an offload is
in flight — where it receives ``degraded``-tagged partial predictions
from its own glasses instead of a spot in the backlog.

Hysteresis: a replica that enters the shedding state keeps shedding new
sessions until its predicted wait falls below ``exit_frac * deadline``
(strictly below the ``enter_frac * deadline`` trigger), so the fleet
drains and *recovers* after a burst instead of oscillating around the
threshold.

The controller is pure bookkeeping over numbers the region simulator
feeds it (predicted wait, queue depth) — no jax, no engine coupling —
so it is unit-testable in isolation and reusable against any backlog
estimator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

__all__ = ["AdmissionPolicy", "AdmissionController"]


@dataclass(frozen=True)
class AdmissionPolicy:
    """Deadline- and queue-depth-aware gating thresholds.

    * ``deadline_s`` — target time-to-first-prediction for a newly
      admitted session; the wait prediction is compared against it.
    * ``enter_frac`` / ``exit_frac`` — hysteresis band: start shedding
      when ``predicted_wait > enter_frac * deadline_s``, stop when
      ``predicted_wait < exit_frac * deadline_s``.
    * ``max_queue`` — optional hard cap on a replica's queued events;
      beyond it new sessions shed regardless of the wait estimate.
    """
    deadline_s: float
    enter_frac: float = 1.0
    exit_frac: float = 0.5
    max_queue: Optional[int] = None

    def __post_init__(self):
        if self.deadline_s <= 0.0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")
        if not 0.0 < self.exit_frac < self.enter_frac:
            raise ValueError(
                f"need 0 < exit_frac < enter_frac for hysteresis, got "
                f"exit={self.exit_frac}, enter={self.enter_frac}")


class AdmissionController:
    """Per-replica shedding state machine with hysteresis."""

    def __init__(self, policy: AdmissionPolicy, n_replicas: int):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.policy = policy
        self.n_replicas = n_replicas
        self.shedding = [False] * n_replicas
        self.admitted = 0
        self.shed = 0
        # (t, replica, "enter"|"exit") shed-state transitions, for the
        # burst-recovery story and the trace
        self.transitions: List[Tuple[float, int, str]] = []

    def admit(self, replica: int, now: float, predicted_wait_s: float,
              queue_depth: int = 0) -> bool:
        """Decide a NEW session routed to ``replica`` at fleet time
        ``now``: True = admit to the backlog, False = shed to glass."""
        p = self.policy
        hi = p.enter_frac * p.deadline_s
        lo = p.exit_frac * p.deadline_s
        over_cap = (p.max_queue is not None and queue_depth > p.max_queue)
        if self.shedding[replica]:
            if predicted_wait_s < lo and not over_cap:
                self.shedding[replica] = False
                self.transitions.append((now, replica, "exit"))
        else:
            if predicted_wait_s > hi or over_cap:
                self.shedding[replica] = True
                self.transitions.append((now, replica, "enter"))
        ok = not self.shedding[replica]
        if ok:
            self.admitted += 1
        else:
            self.shed += 1
        return ok

    def stats(self) -> dict:
        return {
            "admitted": self.admitted,
            "shed": self.shed,
            "transitions": len(self.transitions),
            "shedding_now": sum(self.shedding),
        }


@dataclass
class AdmitAll:
    """Null controller for the shed-vs-queue A/B: every session is
    admitted, nothing ever degrades — the queue-to-death baseline."""
    admitted: int = 0
    shed: int = 0
    transitions: List[Tuple[float, int, str]] = field(default_factory=list)

    def admit(self, replica: int, now: float, predicted_wait_s: float,
              queue_depth: int = 0) -> bool:
        self.admitted += 1
        return True

    def stats(self) -> dict:
        return {"admitted": self.admitted, "shed": 0, "transitions": 0,
                "shedding_now": 0}


__all__.append("AdmitAll")
