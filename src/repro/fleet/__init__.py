"""Fleet-scale serving: region simulation over sharded engine replicas.

The millions-of-users story on top of the unified serving engine —
everything before this package measured a handful of closed-loop
sessions on one host; here the load is open-loop and the serving plane
is a fleet:

  * ``workload`` — seeded Poisson / diurnal-modulated arrival processes
    spawning whole incident sessions (``IncidentSession``) at a
    configurable offered rate, with stochastic intra-session modality
    lags carried as explicit per-event arrival sequences through
    ``core.episodes.async_episode(times=...)``.
  * ``region`` — ``RegionSim``: N ``EMSServeEngine`` replicas built
    from ONE ``build_engine`` spec, parameters placed across a jax
    device mesh by the (previously dormant) ``distributed.sharding``
    policy, a consistent-hash + least-loaded session router, and a
    shared simulated clock (flush cost = measured wall seconds of the
    real XLA calls, flush start gated on data availability).
  * ``admission`` — deadline/queue-depth admission control with
    hysteresis; overload sheds NEW sessions to the on-glass provisional
    path (``GlassShedPath``) where they receive ``degraded``-tagged
    partials instead of queueing the backlog to death.

Benchmark: ``benchmarks/fleet_load.py`` (latency-vs-offered-load knee,
sessions/s scaling vs replica count, shed-vs-queue A/B) ->
``BENCH_fleet.json``. Launcher: ``python -m repro.launch.serve
--fleet RATE --replicas N``.
"""
from .admission import AdmissionController, AdmissionPolicy, AdmitAll  # noqa: F401
from .region import (ConsistentHashRouter, DegradedRecord,  # noqa: F401
                     GlassShedPath, RegionSim, fleet_mesh,
                     place_fleet_params)
from .workload import (IncidentSession, diurnal_rate,  # noqa: F401
                       diurnal_times, generate_workload, merge_sessions,
                       poisson_times)
