"""Region simulator: N engine replicas from ONE spec, open-loop driven.

The fleet story in one file:

  * **One spec, N replicas** — ``RegionSim`` builds every replica with
    the same ``build_engine`` spec string over the same (mesh-placed)
    parameter pytree. ``fleet_mesh``/``place_fleet_params`` activate the
    dormant ``distributed/sharding.py`` policy: a ``('data','model')``
    device mesh over the available jax devices (CI emulates N host
    devices with ``XLA_FLAGS=--xla_force_host_platform_device_count``)
    and the policy's PartitionSpecs placed with ``jax.device_put`` — the
    EMSNet pytree has no tensor-parallel names, so every leaf lands
    replicated across the mesh: one weight copy per device, which *is*
    the replica story. Pytree identity is preserved, so the
    ``share_encoders`` grouped-tail fast path keeps working.

  * **Shared simulated clock** — arrivals are replayed in global fleet
    time; each replica carries a serving clock that can never run ahead
    of data availability: a flush over everything pending starts at
    ``max(replica_clock, oldest_pending_arrival)`` and costs the
    *measured wall time* of the real batched XLA calls. Backlog is the
    gap ``replica_clock - now`` — exactly the quantity open-loop
    queueing blows up.

  * **Routing** — ``ConsistentHashRouter``: sessions hash onto a vnode
    ring (stable under replica-count changes), with a least-loaded
    spill when the home replica's backlog exceeds the fleet minimum by
    ``spill_s``.

  * **Shedding** — an ``admission.AdmissionController`` gates NEW
    sessions; shed sessions are served by ``GlassShedPath``: the
    on-glass provisional path (the same degradation ``stream+tiered``
    uses mid-offload) on per-session glass clocks timed from the
    ``ProfileTable`` glass tier. Degraded sessions emit ONLY
    ``kind="partial"`` predictions tagged ``degraded=True`` — counted,
    never silently dropped — and touch no replica backlog.

Every admitted session's finals stay bit-parity (atol 0) with the
per-event reference engine (``core.engine.EMSServe`` over the same zoo)
— coalescing is bitwise invariant, so fleet scale never buys drift.
"""
from __future__ import annotations

import bisect
import hashlib
import math
from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..core.splitter import select_model
from ..distributed.sharding import Policy
from ..obs import Metrics
from ..serving.api import build_engine
from .admission import AdmitAll
from .workload import IncidentSession, merge_sessions

__all__ = ["fleet_mesh", "place_fleet_params", "ConsistentHashRouter",
           "DegradedRecord", "GlassShedPath", "RegionSim"]


# ------------------------------------------------------------------ mesh

def fleet_mesh(n_replicas: Optional[int] = None):
    """A ('data', 'model') mesh over the available jax devices (at most
    ``n_replicas`` of them; 'model' is size 1 — no tensor parallelism
    in the EMSNet zoo). Under host-device emulation
    (``XLA_FLAGS=--xla_force_host_platform_device_count=N``) this is
    the N-way fleet mesh; on a single real device it degrades to 1."""
    from jax.sharding import Mesh
    devs = jax.devices()
    n = len(devs) if n_replicas is None else max(1, min(n_replicas,
                                                        len(devs)))
    return Mesh(np.asarray(devs[:n]).reshape(n, 1), ("data", "model"))


def place_fleet_params(params: Dict[str, dict], mesh, *, cfg=None,
                       strategy: str = "2d"):
    """Place the engine parameter pytrees onto ``mesh`` through the
    ``distributed.sharding.Policy`` PartitionSpecs.

    ``params`` maps model name -> pytree; names sharing ONE pytree (the
    subset zoo) are placed once and keep identity, so the engine's
    ``share_encoders`` grouped-tail identity check still holds.
    Returns ``(placed_params, report)`` where the report counts
    replicated vs sharded leaves and total bytes."""
    pol = Policy(cfg, mesh, strategy=strategy)
    placed_by_id: Dict[int, dict] = {}
    leaves_total = sharded = 0
    nbytes = 0
    for p in params.values():
        if id(p) in placed_by_id:
            continue
        pspecs = pol.param_pspecs(p)
        for spec in jax.tree.leaves(
                pspecs, is_leaf=lambda x: isinstance(x, P)):
            leaves_total += 1
            if any(a is not None for a in tuple(spec)):
                sharded += 1
        nbytes += sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(p))
        placed_by_id[id(p)] = jax.device_put(p, pol.shardings(pspecs))
    report = {
        "devices": len(mesh.devices.reshape(-1)),
        "axis_sizes": {k: int(v)
                       for k, v in zip(mesh.axis_names,
                                       mesh.devices.shape)},
        "strategy": strategy,
        "param_leaves": leaves_total,
        "sharded_leaves": sharded,
        "replicated_leaves": leaves_total - sharded,
        "param_bytes": int(nbytes),
    }
    return {k: placed_by_id[id(v)] for k, v in params.items()}, report


# ---------------------------------------------------------------- router

def _hash64(s: str) -> int:
    return int.from_bytes(hashlib.blake2b(s.encode(),
                                          digest_size=8).digest(), "big")


class ConsistentHashRouter:
    """Consistent-hash session->replica ring with a least-loaded spill.

    Each replica owns ``vnodes`` points on a 64-bit ring; a session id
    hashes to the next point clockwise (stable when replicas are added
    or removed — only ~1/N of sessions move). When per-replica loads
    are supplied and the home replica's load exceeds the fleet minimum
    by more than ``spill_s`` seconds, the session routes to the
    least-loaded replica instead (ties to the lowest index)."""

    def __init__(self, n_replicas: int, *, vnodes: int = 64, seed: int = 0,
                 spill_s: float = 0.05):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.n_replicas = n_replicas
        self.spill_s = spill_s
        self._seed = seed
        ring = [(_hash64(f"{seed}:{r}:{v}"), r)
                for r in range(n_replicas) for v in range(vnodes)]
        ring.sort()
        self._points = [p for p, _ in ring]
        self._owners = [r for _, r in ring]
        self.spills = 0

    def home(self, sid: str) -> int:
        h = _hash64(f"{self._seed}:{sid}")
        i = bisect.bisect_right(self._points, h) % len(self._points)
        return self._owners[i]

    def route(self, sid: str,
              loads: Optional[Sequence[float]] = None) -> int:
        r = self.home(sid)
        if loads is None:
            return r
        if len(loads) != self.n_replicas:
            raise ValueError(f"loads has {len(loads)} entries for "
                             f"{self.n_replicas} replicas")
        least = min(range(self.n_replicas), key=lambda i: (loads[i], i))
        if loads[r] - loads[least] > self.spill_s:
            self.spills += 1
            return least
        return r


# ------------------------------------------------------------ glass path

@dataclass(frozen=True)
class DegradedRecord:
    """One on-glass provisional emission for a shed session. Always a
    tagged partial — a degraded session never receives a final."""
    sid: str
    index: int
    modality: str
    model: Optional[str]
    t_arrival: float
    t_emit: float
    outputs: Optional[dict]
    kind: str = "partial"
    degraded: bool = True


class GlassShedPath:
    """On-glass provisional serving for shed sessions.

    Reuses the ``stream+tiered`` degradation shape: each shed session's
    own glasses encode the arriving modality and re-fuse the cached
    subset tail, timed on a per-session glass clock from the
    ``ProfileTable`` glass tier (no fleet queueing — glasses don't
    share a backlog). Real numerics run (the partials match
    ``partial_forward``), but every emission is ``kind="partial"`` and
    ``degraded=True``."""

    def __init__(self, models, params, profile, *, bucketer=None,
                 metrics: Optional[Metrics] = None, tracer=None):
        self.models = models
        self.params = params
        self.profile = profile
        self.bucketer = bucketer
        self.metrics = metrics if metrics is not None else Metrics()
        self.tracer = tracer
        self.clock: Dict[str, float] = {}
        self.inputs: Dict[str, dict] = defaultdict(dict)
        self.feats: Dict[str, dict] = defaultdict(dict)
        self.records: List[DegradedRecord] = []
        self.ttfp: Dict[str, float] = {}
        self._first_arrival: Dict[str, float] = {}

    def _encoder_for(self, modality: str):
        for name, sm in self.models.items():
            if modality in sm.modalities():
                return name, sm
        raise KeyError(f"no model consumes modality {modality!r}")

    def serve(self, sid: str, event, payload, t_arrival: float
              ) -> DegradedRecord:
        m = event.modality
        self._first_arrival.setdefault(sid, t_arrival)
        self.inputs[sid][m] = payload
        enc_name, enc_sm = self._encoder_for(m)
        x = self.bucketer.fit(m, payload) if self.bucketer else payload
        feat = enc_sm.encoders[m](self.params[enc_name], x)
        self.feats[sid][m] = feat
        total = self.profile.time(f"enc:{m}", "glass")

        name = select_model(self.models, self.inputs[sid])
        outputs = None
        if name is not None:
            sm = self.models[name]
            feats = {mm: self.feats[sid][mm] for mm in sm.modalities()}
            outputs = sm.tail(self.params[name], feats)
            total += self.profile.time("tail", "glass")

        start = max(t_arrival, self.clock.get(sid, 0.0))
        t_emit = start + total
        self.clock[sid] = t_emit
        rec = DegradedRecord(sid=sid, index=event.index, modality=m,
                             model=name, t_arrival=t_arrival,
                             t_emit=t_emit, outputs=outputs)
        self.records.append(rec)
        self.metrics.inc("fleet.degraded_events")
        if outputs is not None:
            self.metrics.inc("fleet.degraded_partials")
            if sid not in self.ttfp:
                self.ttfp[sid] = t_emit - self._first_arrival[sid]
                self.metrics.observe("fleet.ttfp_degraded_s",
                                     self.ttfp[sid])
        if self.tracer:
            self.tracer.instant("fleet.degraded", "fleet", t_emit,
                                track="fleet", sid=sid, index=event.index,
                                modality=m, model=name, kind="partial")
        return rec


# ------------------------------------------------------------ region sim

class RegionSim:
    """N ``EMSServeEngine`` replicas from ONE spec under open-loop load.

    Arrivals (from ``workload.generate_workload``) are replayed in
    global fleet-time order. New sessions route through the
    consistent-hash + least-loaded router and the admission controller;
    admitted events join their replica's pending buffer and are served
    by deadline-free coalescing flushes on the replica's simulated
    serving clock (flush start = ``max(clock, oldest pending arrival)``,
    flush cost = measured wall seconds of the real XLA calls). Shed
    sessions go to the ``GlassShedPath``. Nothing is ever dropped:
    ``sessions_offered == admitted + shed`` is an invariant."""

    def __init__(self, models, params, *, n_replicas: int = 2,
                 spec: str = "batch+stream", admission=None,
                 profile=None, router: Optional[ConsistentHashRouter] = None,
                 tracer=None, svc_prior_s: float = 0.002,
                 engine_kw: Optional[dict] = None):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        self.n_replicas = n_replicas
        self.admission = admission if admission is not None else AdmitAll()
        self.router = (router if router is not None
                       else ConsistentHashRouter(n_replicas))
        if self.router.n_replicas != n_replicas:
            raise ValueError("router sized for "
                             f"{self.router.n_replicas} replicas, "
                             f"sim has {n_replicas}")
        self.metrics = Metrics()
        self.tracer = tracer
        kw = dict(share_encoders=True, deadline_s=None)
        kw.update(engine_kw or {})
        self.replicas = [build_engine(models, params, spec,
                                      tracer=tracer, **kw)
                         for _ in range(n_replicas)]
        self.glass = (GlassShedPath(models, params, profile,
                                    bucketer=kw.get("bucketer"),
                                    metrics=self.metrics, tracer=tracer)
                      if profile is not None else None)
        self.clock = [0.0] * n_replicas      # per-replica serving clock
        self.buf: List[List[Tuple[float, str, object]]] = \
            [[] for _ in range(n_replicas)]
        self.route_of: Dict[str, int] = {}   # admitted sid -> replica
        self.degraded: set = set()           # shed sids
        self.ttfp: Dict[str, float] = {}     # admitted sessions
        self.ttfinal: Dict[str, float] = {}
        self.first_arrival: Dict[str, float] = {}
        self.flush_log: List[Tuple[int, float, float, int]] = []
        self._svc_est = float(svc_prior_s)   # EWMA per-event service
        self._payload_fn = None
        self.metrics.set_gauge("fleet.replicas", n_replicas)

    # ---- load estimation -------------------------------------------

    def backlog_s(self, r: int, now: float) -> float:
        """Predicted wait a new arrival to replica ``r`` faces at fleet
        time ``now``: how far the serving clock runs ahead of the data,
        plus the estimated service of everything already buffered."""
        return (max(0.0, self.clock[r] - now)
                + len(self.buf[r]) * self._svc_est)

    # ---- intake -----------------------------------------------------

    def _on_new_session(self, sid: str, now: float) -> None:
        self.metrics.inc("fleet.sessions_offered")
        loads = [self.backlog_s(r, now) for r in range(self.n_replicas)]
        r = self.router.route(sid, loads)
        predicted = loads[r] + self._svc_est
        self.metrics.observe("fleet.predicted_wait_s", predicted)
        if self.admission.admit(r, now, predicted,
                                queue_depth=len(self.buf[r])):
            self.route_of[sid] = r
            self.metrics.inc("fleet.sessions_admitted")
            if self.tracer:
                self.tracer.instant("fleet.admit", "fleet", now,
                                    track="fleet", sid=sid, replica=r,
                                    predicted_wait_s=predicted)
        else:
            if self.glass is None:
                raise RuntimeError(
                    "admission controller shed a session but no "
                    "GlassShedPath is configured (pass profile=...)")
            self.degraded.add(sid)
            self.metrics.inc("fleet.sessions_shed")
            if self.tracer:
                self.tracer.instant("fleet.shed", "fleet", now,
                                    track="fleet", sid=sid, replica=r,
                                    predicted_wait_s=predicted)

    # ---- replica pump ----------------------------------------------

    def _pump(self, r: int, until: float) -> None:
        """Run every flush on replica ``r`` that would start no later
        than fleet time ``until`` (retrospective event-driven sim: a
        flush takes everything that arrived by its start instant)."""
        buf = self.buf[r]
        eng = self.replicas[r]
        while buf:
            start = max(self.clock[r], buf[0][0])
            if start > until:
                break
            i = 0
            while i < len(buf) and buf[i][0] <= start:
                i += 1
            batch, del_n = buf[:i], i
            del buf[:del_n]
            for _, sid, ev in batch:
                eng.submit(sid, ev, self._payload_fn(sid, ev))
            rep = eng.flush()
            done = start + rep.wall_s
            self.clock[r] = done
            self.flush_log.append((r, start, done, rep.n_events))
            if rep.n_events:
                per_ev = rep.wall_s / rep.n_events
                self._svc_est = 0.8 * self._svc_est + 0.2 * per_ev
            self.metrics.inc("fleet.flushes")
            self.metrics.observe("fleet.flush_wall_s", rep.wall_s)
            for p in rep.predictions:
                t0 = self.first_arrival[p.sid]
                if p.sid not in self.ttfp:
                    self.ttfp[p.sid] = done - t0
                    self.metrics.observe("fleet.ttfp_s", self.ttfp[p.sid])
                if p.kind == "final" and p.sid not in self.ttfinal:
                    self.ttfinal[p.sid] = done - t0
                    self.metrics.observe("fleet.ttfinal_s",
                                         self.ttfinal[p.sid])

    # ---- drive ------------------------------------------------------

    def run(self, sessions: Sequence[IncidentSession], payload_fn):
        """Replay the workload; ``payload_fn(sid, event) -> payload``.
        Returns the report dict (also available as ``.report()``)."""
        self._payload_fn = payload_fn
        arrivals = merge_sessions(sessions)
        self._last_arrival = arrivals[-1][0] if arrivals else 0.0
        for t, sid, ev in arrivals:
            if sid not in self.route_of and sid not in self.degraded:
                self.first_arrival[sid] = t
                self._on_new_session(sid, t)
            if sid in self.degraded:
                self.glass.serve(sid, ev, self._payload_fn(sid, ev), t)
                continue
            r = self.route_of[sid]
            # buffer BEFORE pumping: an idle replica flushes the event
            # at its own arrival instant (continuous batching — waiting
            # for the next arrival would put a ~1/rate floor under every
            # light-load TTFP); a busy one leaves it to coalesce with
            # whatever else lands before the clock frees up
            self.buf[r].append((t, sid, ev))
            self.metrics.inc("fleet.events_admitted")
            self._pump(r, t)
        for r in range(self.n_replicas):
            self._pump(r, math.inf)
        return self.report()

    # ---- results ----------------------------------------------------

    def final_outputs(self, sid: str) -> Optional[dict]:
        """Last FINAL prediction outputs of an admitted session (None
        when the session never finalized or was shed)."""
        r = self.route_of.get(sid)
        if r is None:
            return None
        st = self.replicas[r].sessions.get(sid)
        if st is None:
            return None
        for p in reversed(st.predictions):
            if p.kind == "final":
                return p.outputs
        return None

    def makespan(self) -> float:
        glass_last = max((r.t_emit for r in self.glass.records),
                         default=0.0) if self.glass is not None else 0.0
        return max([getattr(self, "_last_arrival", 0.0), glass_last]
                   + list(self.clock))

    def fleet_metrics(self) -> Metrics:
        """Exact fleet-wide registry: the sim's own counters merged with
        every replica engine's (counters summed, quantile sketches
        merged bucket-exactly)."""
        regs = [self.metrics] + [e.metrics for e in self.replicas]
        return Metrics.merged(regs)

    def report(self) -> dict:
        offered = len(self.route_of) + len(self.degraded)
        n_deg_partials = (sum(1 for r in self.glass.records
                              if r.outputs is not None)
                          if self.glass is not None else 0)
        return {
            "n_replicas": self.n_replicas,
            "sessions_offered": offered,
            "sessions_admitted": len(self.route_of),
            "sessions_shed": len(self.degraded),
            "sessions_finalized": len(self.ttfinal),
            "events_admitted": int(
                self.metrics.get("fleet.events_admitted")),
            "events_degraded": (len(self.glass.records)
                                if self.glass is not None else 0),
            "degraded_partials": n_deg_partials,
            "router_spills": self.router.spills,
            "admission": self.admission.stats(),
            "makespan_s": self.makespan(),
            "svc_est_s": self._svc_est,
            "per_replica": [
                {"sessions": sum(1 for v in self.route_of.values()
                                 if v == r),
                 "flushes": sum(1 for f in self.flush_log if f[0] == r),
                 "final_clock_s": self.clock[r]}
                for r in range(self.n_replicas)],
        }
