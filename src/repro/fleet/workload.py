"""Open-loop fleet workload: seeded Poisson / diurnal incident arrivals.

Everything measured before this subsystem was closed-loop: a fixed set
of sessions replayed end to end, the driver waiting for the engine.
Field EMS load is the opposite — incidents arrive on their own clock
whether or not the fleet keeps up (open loop), at rates that are bursty
and diurnal. This module generates that load:

  * ``poisson_times`` — homogeneous Poisson process: i.i.d. exponential
    inter-arrival gaps at ``rate`` sessions/s over ``[0, horizon)``.
  * ``diurnal_times`` — inhomogeneous Poisson via thinning against the
    sinusoidal envelope ``diurnal_rate`` (peak rate ``base*(1+amp)``);
    candidate points are drawn at the peak rate and accepted with
    probability ``lambda(t)/peak``, the textbook exact method.
  * ``generate_workload`` — spawns a whole ``IncidentSession`` per
    arrival: scenario cycled over ``core.episodes.LAG_SCENARIOS``, and
    *stochastic* intra-session modality lags (exponentially-jittered
    vitals/scene gaps around the scenario's periods) carried as an
    explicit per-event arrival-time sequence through
    ``async_episode(times=...)`` — no fixed grids.

Determinism: every draw flows from ``np.random.default_rng`` seeded by
``(seed, stream-tag[, session index])``, so the same ``(rate, horizon,
seed)`` always yields the identical workload, event for event.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from ..core.episodes import Event, LAG_SCENARIOS, async_episode

__all__ = ["IncidentSession", "poisson_times", "diurnal_rate",
           "diurnal_times", "generate_workload", "merge_sessions"]


@dataclass(frozen=True)
class IncidentSession:
    """One incident: a session id, its absolute start time, and the
    relative-time event sequence (``Event.arrival_time`` is seconds
    since *session* start — shift by ``t_start`` for fleet time)."""
    sid: str
    t_start: float
    scenario: str
    events: Tuple[Event, ...]

    def absolute_events(self) -> List[Event]:
        return [Event(e.index, e.modality, self.t_start + e.arrival_time)
                for e in self.events]


# ---------------------------------------------------------------- arrivals

def poisson_times(rate: float, horizon: float, seed: int = 0) -> List[float]:
    """Arrival instants of a homogeneous Poisson process on [0, horizon)."""
    if rate <= 0.0:
        raise ValueError(f"rate must be > 0, got {rate}")
    if horizon <= 0.0:
        return []
    rng = np.random.default_rng([seed, 0x9015])
    out: List[float] = []
    t = float(rng.exponential(1.0 / rate))
    while t < horizon:
        out.append(t)
        t += float(rng.exponential(1.0 / rate))
    return out


def diurnal_rate(t: float, base_rate: float, *, amp: float = 0.6,
                 period: float = 86400.0, phase: float = 0.0) -> float:
    """Sinusoidal rate envelope ``base*(1 + amp*sin(2pi (t-phase)/period))``
    — bounded in ``[base*(1-amp), base*(1+amp)]`` for ``0 <= amp < 1``."""
    if not 0.0 <= amp < 1.0:
        raise ValueError(f"amp must be in [0, 1), got {amp}")
    return base_rate * (1.0 + amp * math.sin(2.0 * math.pi
                                             * (t - phase) / period))


def diurnal_times(base_rate: float, horizon: float, seed: int = 0, *,
                  amp: float = 0.6, period: float = 86400.0,
                  phase: float = 0.0) -> List[float]:
    """Inhomogeneous Poisson arrivals under the diurnal envelope, by
    exact thinning against the peak rate."""
    if base_rate <= 0.0:
        raise ValueError(f"base_rate must be > 0, got {base_rate}")
    if not 0.0 <= amp < 1.0:
        raise ValueError(f"amp must be in [0, 1), got {amp}")
    if horizon <= 0.0:
        return []
    peak = base_rate * (1.0 + amp)
    rng = np.random.default_rng([seed, 0xD1CA])
    out: List[float] = []
    t = float(rng.exponential(1.0 / peak))
    while t < horizon:
        accept = diurnal_rate(t, base_rate, amp=amp, period=period,
                              phase=phase) / peak
        if rng.uniform() < accept:
            out.append(t)
        t += float(rng.exponential(1.0 / peak))
    return out


# ---------------------------------------------------------------- sessions

def _session_times(scenario: str, rng, *, n_vitals: int, n_scene: int,
                   vitals_period: float,
                   scene_period: float) -> Dict[str, List[float]]:
    """Stochastic intra-session lags: per-modality onset drawn from the
    LAG_SCENARIOS distribution, then exponentially-jittered gaps with
    the scenario's mean period — a true per-event arrival sequence."""
    spec = LAG_SCENARIOS[scenario]

    def onset(m):
        mu, sigma = spec[m]
        return float(max(0.0, rng.normal(mu, sigma)))

    def stream(m, n, mean_gap):
        t = onset(m)
        ts = [t]
        for _ in range(max(1, n) - 1):
            t += float(rng.exponential(mean_gap))
            ts.append(t)
        return ts

    return {
        "text": [onset("text")],
        "vitals": stream("vitals", n_vitals, vitals_period),
        "scene": stream("scene", n_scene, scene_period),
    }


def generate_workload(rate: float, horizon: float, *, seed: int = 0,
                      process: str = "poisson", amp: float = 0.6,
                      period: float = 60.0, phase: float = 0.0,
                      scenarios: Sequence[str] = ("text_first",
                                                  "vitals_first",
                                                  "scene_late"),
                      n_vitals: int = 3, n_scene: int = 2,
                      vitals_period: float = 1.0,
                      scene_period: float = 2.0,
                      time_scale: float = 1.0,
                      sid_prefix: str = "f") -> List[IncidentSession]:
    """Spawn whole incident sessions at an offered ``rate`` (sessions/s)
    over ``[0, horizon)`` seconds of fleet time.

    ``process`` is ``"poisson"`` (homogeneous) or ``"diurnal"``
    (sinusoidal modulation with ``amp``/``period``/``phase``). Each
    session cycles through ``scenarios`` and carries stochastic
    intra-session modality lags via ``async_episode(times=...)``.

    ``time_scale`` multiplies every INTRA-session time (modality onsets
    and stream gaps; session start instants are untouched): real
    incidents unfold over ~10 s, so a capacity benchmark that must
    reach serving-limited steady state within a short horizon compresses
    the session timescale instead of inflating the horizon."""
    if time_scale <= 0.0:
        raise ValueError(f"time_scale must be > 0, got {time_scale}")
    if process == "poisson":
        starts = poisson_times(rate, horizon, seed)
    elif process == "diurnal":
        starts = diurnal_times(rate, horizon, seed, amp=amp,
                               period=period, phase=phase)
    else:
        raise ValueError(f"process must be 'poisson' or 'diurnal', "
                         f"got {process!r}")
    sessions: List[IncidentSession] = []
    for i, t0 in enumerate(starts):
        scen = scenarios[i % len(scenarios)]
        rng = np.random.default_rng([seed, 0x5E55, i])
        times = _session_times(scen, rng, n_vitals=n_vitals,
                               n_scene=n_scene,
                               vitals_period=vitals_period,
                               scene_period=scene_period)
        if time_scale != 1.0:
            times = {m: [t * time_scale for t in ts]
                     for m, ts in times.items()}
        events = async_episode(scen, times=times)
        sessions.append(IncidentSession(sid=f"{sid_prefix}{i}",
                                        t_start=float(t0), scenario=scen,
                                        events=tuple(events)))
    return sessions


def merge_sessions(sessions: Sequence[IncidentSession]):
    """Interleave sessions into one global fleet arrival stream:
    ``[(absolute_time, sid, Event)]`` sorted by time (ties by sid) —
    the same discipline as ``core.episodes.merge_arrivals``."""
    out = [(s.t_start + e.arrival_time, s.sid, e)
           for s in sessions for e in s.events]
    out.sort(key=lambda x: (x[0], x[1]))
    return out
