"""Loop-aware analysis of compiled (post-SPMD, per-device) HLO text.

``compiled.cost_analysis()`` does NOT multiply through ``while`` bodies,
so any scan-over-layers model (i.e. every model here) is undercounted by
the layer count. This walker parses the HLO text into its computation
graph, extracts per-op contributions, and resolves ENTRY totals
recursively with while-loop trip counts (XLA annotates
``known_trip_count`` on scan-derived loops; a constant-scan of the
condition computation is the fallback).

Per-op contributions:
  * flops: ``dot`` ops — 2 x numel(result) x contracted size (operand
    shapes resolved from the computation-local symbol table);
  * collective bytes by kind: result-buffer bytes of all-reduce /
    all-gather / reduce-scatter / all-to-all / collective-permute
    (per-device, post-partitioning);
  * hbm bytes: result bytes of materializing ops (parameters, tuples,
    GTEs, bitcasts and constants excluded) — a fused-kernel-granularity
    traffic estimate.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z0-9\-]+)\((.*)$")
_PARAM_SIG_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^()]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*([0-9]+)')
_CALL_RE = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "bitcast",
                  "constant", "after-all", "custom-call"}


def _shape_info(text: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + [(dtype, dims)] for a (possibly tuple) shape string."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in d:
            n *= x
        total += n * _DTYPE_BYTES[dt]
        shapes.append((dt, d))
    return total, shapes


@dataclass
class Op:
    name: str
    opcode: str
    result_text: str
    rest: str                       # operands + attrs (rest of the line)

    @property
    def result_bytes(self):
        return _shape_info(self.result_text)[0]


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)   # name -> shape text


@dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: dict.fromkeys(COLLECTIVES, 0.0))
    coll_count: Dict[str, float] = field(default_factory=lambda: dict.fromkeys(COLLECTIVES, 0.0))

    def add(self, other: "Totals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in COLLECTIVES:
            self.coll[k] += other.coll[k] * mult
            self.coll_count[k] += other.coll_count[k] * mult


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                for pname, pshape in _PARAM_SIG_RE.findall(m.group(2)):
                    cur.symbols[pname] = pshape
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if m:
            name, result, opcode, rest = m.groups()
            cur.symbols[name] = result
            cur.ops.append(Op(name, opcode, result, rest))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _dot_flops(comp: Computation, op: Op) -> float:
    """2 * numel(result) * contracted-dims size (from lhs operand shape)."""
    rbytes, rshapes = _shape_info(op.result_text)
    if not rshapes:
        return 0.0
    numel = 1
    for d in rshapes[0][1]:
        numel *= d
    # compiled HLO prints operands with inline types ("dot(f32[4,16]{1,0}
    # %gte.4, ...)"); prefer that shape, else resolve the bare name
    m = re.match(r"\s*(?:([a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+)?%?([\w.\-]+)",
                 op.rest)
    contracted = 1
    if m:
        lhs_shape = m.group(1) or comp.symbols.get(m.group(2), "")
        _, lshapes = _shape_info(lhs_shape)
        cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
        if lshapes and cd:
            dims = [int(x) for x in cd.group(1).split(",") if x]
            for d in dims:
                if d < len(lshapes[0][1]):
                    contracted *= lshapes[0][1][d]
    return 2.0 * numel * contracted


def _trip_count(op: Op, comps: Dict[str, Computation]) -> float:
    m = _TRIP_RE.search(op.rest)
    if m:
        return float(m.group(1))
    m = _COND_RE.search(op.rest)
    if m and m.group(1) in comps:
        consts = []
        for o in comps[m.group(1)].ops:
            if o.opcode == "constant":
                cm = re.match(r"\s*(\d+)\s*\)", o.rest)
                if cm:
                    consts.append(int(cm.group(1)))
            consts.extend(int(c) for c in _CONST_RE.findall(o.rest))
        if consts:
            return float(max(consts))
    return 1.0


def _resolve(comp: Computation, comps, memo) -> Totals:
    if comp.name in memo:
        return memo[comp.name]
    memo[comp.name] = Totals()          # cycle guard
    t = Totals()
    for op in comp.ops:
        if op.opcode == "dot":
            t.flops += _dot_flops(comp, op)
        if op.opcode in COLLECTIVES or any(
                op.opcode == c + "-start" for c in COLLECTIVES):
            kind = op.opcode.replace("-start", "")
            t.coll[kind] += op.result_bytes
            t.coll_count[kind] += 1
        if op.opcode not in SKIP_BYTES_OPS:
            t.bytes += op.result_bytes
        # recurse into called computations
        mult = 1.0
        if op.opcode == "while":
            mult = _trip_count(op, comps)
        for callee in _CALL_RE.findall(op.rest):
            if callee in comps:
                t.add(_resolve(comps[callee], comps, memo), mult)
    memo[comp.name] = t
    return t


def analyze_hlo(hlo: str) -> Totals:
    comps, entry = parse_computations(hlo)
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].ops)) if comps else None
    if entry is None:
        return Totals()
    return _resolve(comps[entry], comps, {})
