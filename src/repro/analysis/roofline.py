"""Roofline analysis from compiled dry-run artifacts.

Three terms (seconds), per device, TPU v5e constants:
  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16 / chip)
  memory     = HLO_bytes / HBM_bw                (819 GB/s / chip)
  collective = collective_bytes / link_bw        (~50 GB/s / ICI link)

``cost_analysis()`` FLOPs/bytes on a post-SPMD module are already
per-device. Collective bytes are not in cost_analysis: we parse the
compiled HLO text and sum *result* buffer sizes of collective ops (these
shapes are per-device post-partitioning). All-reduce traffic is counted
twice (ring reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-op-kind result-buffer bytes of collectives in (per-device) HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_txt)
        counts[kind] += 1
    return out, counts


def weighted_collective_bytes(by_kind: dict) -> float:
    """Ring-algorithm traffic weights: AR moves ~2x its buffer."""
    w = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
         "all-to-all": 1.0, "collective-permute": 1.0}
    return sum(by_kind[k] * w[k] for k in by_kind)


@dataclass
class Roofline:
    flops: float                 # per device (loop-aware HLO dot count)
    hbm_bytes: float             # per device (analytic model)
    coll_bytes: float            # per device (weighted, loop-aware HLO)
    model_flops: float = 0.0     # 6*N*D (useful compute, global)
    chips: int = 256
    hbm_bytes_hlo: float = 0.0   # fusion-naive HLO upper bound (recorded)

    @property
    def t_compute(self):
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self):
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self):
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "hbm_bytes_hlo_upper_bound": self.hbm_bytes_hlo,
        }


def model_flops(cfg, shape) -> float:
    """6*N*D (training) or 2*N*D (inference) with N = active params."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * tokens


def analytic_hbm_bytes(cfg, shape, *, model_shards=16, data_shards=16,
                       pods=1, experts_2d=False) -> float:
    """First-principles per-device HBM traffic per step (the roofline
    memory term). The HLO-derived byte count is recorded alongside as an
    upper bound: CPU HLO fusion granularity counts scan-internal
    intermediates that live in VMEM on TPU.

    Model: each device streams its tensor-parallel weight slice
    (gathered over the FSDP axis, so the slice is W/model_shards) once
    per forward and once per backward pass per microbatch, plus gradient
    writes, plus activation traffic (remat: one write + two reads of
    layer I/O), plus decode-cache read/write."""
    dtype_b = 2.0                                  # bf16
    total = cfg.param_count()
    if experts_2d and cfg.n_experts:
        # routed experts sharded over data x model, rest over model
        routed = 0
        for specs, count in cfg.groups:
            for s in specs:
                if s.mlp == "moe":
                    routed += count * cfg.n_experts * 3 * cfg.d_model * cfg.moe_d_ff
        W = ((total - routed) / model_shards
             + routed / (model_shards * data_shards)) * dtype_b
    else:
        W = total * dtype_b / model_shards
    d = cfg.d_model
    L = cfg.n_layers
    dp = data_shards * pods

    if shape.kind == "train":
        M = max(cfg.train_microbatches, 1)
        tokens_local = shape.global_batch * shape.seq_len / dp
        act = tokens_local * d * dtype_b * L * 3.0     # write + 2 reads
        grads_opt = 3.0 * W * 2.0                      # f32 grads + opt I/O
        return M * 2.0 * W + grads_opt + act
    if shape.kind == "prefill":
        tokens_local = shape.global_batch * shape.seq_len / dp
        act = tokens_local * d * dtype_b * L * 2.0
        cache = _cache_bytes(cfg, shape, dp)
        return W + act + cache
    # decode: weights once + cache read/write
    return W + 2.0 * _cache_bytes(cfg, shape, dp)


def _cache_bytes(cfg, shape, dp) -> float:
    """Per-device decode-cache bytes for this arch family."""
    from repro.serving.kv_cache import cache_plan
    cache_len, _ = cache_plan(cfg, shape)
    B = shape.global_batch
    dtype_b = 2.0
    total = 0.0
    for specs, count in cfg.groups:
        for s in specs:
            if s.mixer == "attn":
                total += count * B * cache_len * cfg.kv_dim * 2 * dtype_b
            elif s.mixer == "mla":
                m = cfg.mla
                total += count * B * cache_len * (m.kv_lora_rank
                                                  + m.qk_rope_dim) * dtype_b
            elif s.mixer == "mamba":
                di = cfg.mamba.d_inner(cfg.d_model)
                total += count * B * di * (cfg.mamba.d_state * 4
                                           + cfg.mamba.d_conv * dtype_b)
            elif s.mixer == "rwkv6":
                hd = cfg.rwkv_head_dim
                total += count * B * (cfg.d_model // hd) * hd * hd * 4
    return total / dp


def analyze(cost: dict, hlo_text: str, cfg, shape, chips: int,
            experts_2d: bool = False) -> Roofline:
    """Roofline terms from the compiled per-device HLO.

    Uses the loop-aware walker in ``hlo_analyzer`` (XLA's cost_analysis
    does not multiply through scan-derived while loops, undercounting
    every scanned-layer model by its layer count — the raw
    cost_analysis numbers are still recorded in the dry-run artifact
    for comparison)."""
    from . import hlo_analyzer as H
    t = H.analyze_hlo(hlo_text)
    pods = 2 if chips == 512 else 1
    return Roofline(
        flops=t.flops,
        hbm_bytes=analytic_hbm_bytes(cfg, shape, model_shards=16,
                                     data_shards=16, pods=pods,
                                     experts_2d=experts_2d),
        coll_bytes=weighted_collective_bytes(t.coll),
        model_flops=model_flops(cfg, shape),
        chips=chips,
        hbm_bytes_hlo=t.bytes,
    )
