"""Optimizers as pure pytree transforms: AdamW and Adafactor.

AdamW is the default for CPU-scale training (EMSNet, smoke configs).
Adafactor (factored second moment, no first moment) is the default for
the large assigned architectures — its state is ~1/d_model of AdamW's,
which is what keeps the 671B dry-run's bytes-per-device honest.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


# ---------------------------------------------------------------- AdamW

def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "step": step}, gnorm


# ------------------------------------------------------------ Adafactor

def _factored(shape):
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def init(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jnp.ndarray)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, grads, state, params):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(p, g, v):
        g2 = g * g + 1e-30
        if _factored(p.shape):
            vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
            nv = {"vr": vr, "vc": vc}
        else:
            nv_ = beta * v["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(nv_ + 1e-30)
            nv = {"v": nv_}
        # update clipping (RMS <= 1)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_params, {"v": new_v, "step": step}, gnorm


# ------------------------------------------------------------- factory

def make_optimizer(name: str, **kw):
    cfg = OptConfig(name=name, **kw)
    if name == "adamw":
        return cfg, adamw_init, partial(adamw_update, cfg)
    if name == "adafactor":
        return cfg, adafactor_init, partial(adafactor_update, cfg)
    if name == "sgd":
        def sgd_init(params):
            return {"step": jnp.zeros((), jnp.int32)}

        def sgd_update(grads, state, params):
            step = state["step"] + 1
            lr = schedule(cfg, step)
            grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
            new_params = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
                params, grads)
            return new_params, {"step": step}, gnorm
        return cfg, sgd_init, sgd_update
    raise ValueError(name)
