"""Checkpointing: pytree <-> .npz + structure JSON.

Flat, dependency-free, works for params and optimizer state alike.
Leaves are saved under their joined tree path; restore validates the
structure against a template pytree.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path, tree, *, metadata=None):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    meta = {"keys": sorted(flat), "metadata": metadata or {}}
    path.with_suffix(".json").write_text(json.dumps(meta, indent=1))


def restore(path, template):
    """Restore into the structure of ``template`` (shapes must match)."""
    path = Path(path)
    if path.suffix != ".npz":
        path = path.with_suffix(".npz")
    data = np.load(path)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_t:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def metadata(path):
    p = Path(path).with_suffix(".json")
    return json.loads(p.read_text())["metadata"]
