"""EMSNet multitask training, including Progressive Modality Integration.

Task selection mirrors the paper's P / M / Q / P-M / P-Q / M-Q / P-M-Q
grid (protocol CE, medicine CE, quantity MSE). PMI (paper §3.2): the
3-modal model is *not* trained from scratch on the tiny D2 — the
text+vitals encoders come from the 2-modal model trained on the large
D1 and are frozen (stop-gradient) while the freshly-initialized scene
encoder and (warm-started) headers integrate the new modality.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.emsnet import EMSNetConfig
from repro.models import emsnet as E
from . import losses as LS
from . import optimizer as OPT

TASKS = ("protocol", "medicine", "quantity")


def multitask_loss(out: dict, labels: dict, tasks=TASKS):
    loss = jnp.zeros((), jnp.float32)
    parts = {}
    if "protocol" in tasks:
        parts["protocol"] = LS.cross_entropy(out["protocol_logits"],
                                             labels["protocol"])
        loss += parts["protocol"]
    if "medicine" in tasks:
        parts["medicine"] = LS.cross_entropy(out["medicine_logits"],
                                             labels["medicine"])
        loss += parts["medicine"]
    if "quantity" in tasks:
        parts["quantity"] = LS.mse(out["quantity"], labels["quantity"])
        loss += parts["quantity"]
    return loss, parts


def make_train_step(cfg: EMSNetConfig, modalities, tasks=TASKS, *,
                    freeze=(), lr=1e-3):
    opt_cfg, opt_init, opt_update = OPT.make_optimizer(
        "adamw", lr=lr, warmup_steps=20, decay_steps=100_000)

    def loss_fn(params, batch):
        out = E.forward(params, cfg, batch, modalities, freeze=freeze)
        loss, _ = multitask_loss(out, batch["labels"], tasks)
        return loss

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if freeze:
            grads = {k: (jax.tree.map(jnp.zeros_like, v) if k in freeze else v)
                     for k, v in grads.items()}
        new_params, opt_state, gnorm = opt_update(grads, opt_state, params)
        if freeze:  # keep frozen subtrees bit-identical (no weight decay)
            new_params = {k: (params[k] if k in freeze else v)
                          for k, v in new_params.items()}
        return new_params, opt_state, loss

    return step, opt_init


@partial(jax.jit, static_argnames=("cfg", "modalities", "tasks"))
def eval_batch(params, cfg, batch, modalities, tasks=TASKS):
    out = E.forward(params, cfg, batch, modalities)
    metrics = {}
    if "protocol" in tasks:
        metrics.update({f"protocol_{k}": v for k, v in
                        LS.topk_accuracy(out["protocol_logits"],
                                         batch["labels"]["protocol"]).items()})
    if "medicine" in tasks:
        metrics.update({f"medicine_{k}": v for k, v in
                        LS.topk_accuracy(out["medicine_logits"],
                                         batch["labels"]["medicine"]).items()})
    if "quantity" in tasks:
        q, t = out["quantity"], batch["labels"]["quantity"]
        metrics["quantity_mse"] = LS.mse(q, t)
        metrics["quantity_pearsonr"] = LS.pearsonr(q, t)
        metrics["quantity_spearmanr"] = LS.spearmanr(q, t)
    return metrics


def evaluate(params, cfg, ds, modalities, tasks=TASKS, *, batch_size=256):
    accs = []
    for i in range(0, len(ds) - batch_size + 1, batch_size):
        batch = ds.batch(np.arange(i, i + batch_size), modalities)
        accs.append(eval_batch(params, cfg, batch, modalities, tasks))
    if not accs:
        batch = ds.batch(np.arange(len(ds)), modalities)
        accs = [eval_batch(params, cfg, batch, modalities, tasks)]
    return {k: float(np.mean([a[k] for a in accs])) for k in accs[0]}


def train(cfg: EMSNetConfig, loader, *, modalities, tasks=TASKS, steps=200,
          seed=0, params=None, freeze=(), lr=1e-3, log_every=0):
    step_fn, opt_init = make_train_step(cfg, modalities, tasks,
                                        freeze=freeze, lr=lr)
    if params is None:
        params = E.init_params(cfg, jax.random.PRNGKey(seed), modalities)
    opt_state = opt_init(params)
    losses = []
    for i in range(steps):
        batch = next(loader)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        losses.append(float(loss))
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1}: loss={np.mean(losses[-log_every:]):.4f}",
                  flush=True)
    return params, losses


# ----------------------------------------------------------------------
# Progressive Modality Integration
# ----------------------------------------------------------------------

def pmi_init(cfg: EMSNetConfig, params_2modal, *, seed=0,
             base=("text", "vitals"), new="scene"):
    """Build 3-modal params from a trained 2-modal model: reuse base
    encoders, fresh scene encoder, warm-started headers (the first
    |F_C^2modal| columns of each header copy the 2-modal weights)."""
    modalities = tuple(base) + (new,)
    fresh = E.init_params(cfg, jax.random.PRNGKey(seed), modalities)
    params = dict(fresh)
    for m in base:
        params[m] = params_2modal[m]
    dims = cfg.feature_dims
    fc2 = sum(dims[m] for m in base)
    heads = {}
    for h in ("protocol", "medicine", "quantity"):
        w = fresh["heads"][h]["w"]
        w = w.at[:fc2].set(params_2modal["heads"][h]["w"])
        heads[h] = {"w": w, "b": params_2modal["heads"][h]["b"]}
    params["heads"] = heads
    return params, modalities


def pmi_finetune(cfg: EMSNetConfig, params_2modal, loader3, *, steps=200,
                 seed=0, lr=1e-3, freeze_base=True, log_every=0):
    """Stage-2 of PMI: integrate the scene modality on the small D2."""
    params, modalities = pmi_init(cfg, params_2modal, seed=seed)
    freeze = ("text", "vitals") if freeze_base else ()
    return train(cfg, loader3, modalities=modalities, steps=steps, seed=seed,
                 params=params, freeze=freeze, lr=lr, log_every=log_every)
