"""Losses and metrics: CE (+top-k accuracy), MSE + Pearson/Spearman.

Everything reduces in f32 and works on vocab-sharded logits under pjit
(reductions over the sharded vocab axis become all-reduces).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cross_entropy(logits, labels, *, mask=None):
    """logits: (..., V); labels: (...) int. Returns mean CE."""
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(lf.max(axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    label_logit = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def topk_accuracy(logits, labels, ks=(1, 3, 5)):
    lf = logits.astype(jnp.float32).reshape(-1, logits.shape[-1])
    lab = labels.reshape(-1)
    out = {}
    maxk = max(ks)
    _, top = jax.lax.top_k(lf, maxk)
    hit = top == lab[:, None]
    for k in ks:
        out[f"top{k}"] = jnp.mean(jnp.any(hit[:, :k], axis=1).astype(jnp.float32))
    return out


def mse(pred, target):
    return jnp.mean(jnp.square(pred.astype(jnp.float32)
                               - target.astype(jnp.float32)))


def pearsonr(x, y):
    x = x.astype(jnp.float32).reshape(-1)
    y = y.astype(jnp.float32).reshape(-1)
    xm = x - x.mean()
    ym = y - y.mean()
    denom = jnp.sqrt(jnp.sum(xm * xm) * jnp.sum(ym * ym))
    return jnp.sum(xm * ym) / jnp.maximum(denom, 1e-9)


def _ranks(x):
    """Average-free ranks via double argsort (ties broken by order)."""
    order = jnp.argsort(x)
    r = jnp.zeros_like(x).at[order].set(jnp.arange(x.shape[0], dtype=x.dtype))
    return r


def spearmanr(x, y):
    x = x.astype(jnp.float32).reshape(-1)
    y = y.astype(jnp.float32).reshape(-1)
    return pearsonr(_ranks(x), _ranks(y))
