"""Generic LLM training step: multitask CE (+MTP, +MoE aux), microbatched
gradient accumulation, pluggable optimizer. This is what the multi-pod
dry-run lowers for ``train_4k``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from . import losses as LS
from . import optimizer as OPT


def make_loss_fn(cfg, *, constrain=None, kernel="jnp", moe_a2a=None):
    def loss_fn(params, batch):
        logits, extras = T.forward_train(
            params, cfg, batch["tokens"], cond=batch.get("cond"),
            next_tokens=batch["labels"], kernel=kernel, constrain=constrain,
            moe_a2a=moe_a2a)
        loss = LS.cross_entropy(logits, batch["labels"])
        loss = loss + cfg.router_aux_weight * extras["moe_aux"]
        if "mtp_logits" in extras:
            mtp = extras["mtp_logits"]
            loss = loss + 0.3 * LS.cross_entropy(mtp[:, :-1], batch["labels"][:, 1:])
        return loss
    return loss_fn


def make_train_step(cfg, *, optimizer=None, constrain=None, kernel="jnp",
                    constrain_grads=None, moe_a2a=None):
    """Returns (train_step, opt_init). train_step(params, opt_state, batch).

    ``constrain_grads``: optional pytree-sharding hint applied to the
    accumulated gradients — under ZeRO-3 this turns GSPMD's gradient
    all-reduce into a reduce-scatter straight into the parameter shards
    (§Perf iteration 3)."""
    opt_name = optimizer or cfg.optimizer
    _, opt_init, opt_update = OPT.make_optimizer(opt_name)
    loss_fn = make_loss_fn(cfg, constrain=constrain, kernel=kernel,
                           moe_a2a=moe_a2a)
    vg = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        M = cfg.train_microbatches
        if M > 1:
            mb = jax.tree.map(
                lambda a: a.reshape((M, a.shape[0] // M) + a.shape[1:]), batch)

            def body(acc, b):
                loss, g = vg(params, b)
                acc = jax.tree.map(
                    lambda s, gi: s + gi.astype(jnp.float32) / M, acc, g)
                return acc, loss

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(body, g0, mb)
            loss = losses.mean()
        else:
            loss, grads = vg(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if constrain_grads is not None:
            grads = constrain_grads(grads)
        new_params, new_opt, gnorm = opt_update(grads, opt_state, params)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt_init
