"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The dry-run forces 512 host platform devices;
the single-pod mesh uses the first 256 of them.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    from jax.sharding import Mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devices)} — the dry-run entry point "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh():
    """1x1 mesh over the single real device (tests, CPU training)."""
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
