"""EMSServe serving launcher: run Table-6 episodes through the engine
with adaptive offloading, feature caching, and (optionally) an edge
crash, printing the per-event trace. ``--batched N`` instead serves N
concurrent sessions through the coalescing BatchedEMSServe fast path
and prints per-flush stats. ``--stream N`` serves N concurrent sessions
with *asynchronously arriving modalities* through StreamingEMSServe,
printing every progressive (partial -> final) prediction and the
per-session time-to-first/final-prediction summary. ``--tiered N``
hosts the split pieces on glass/edge simulated-clock tiers through
TieredEMSServe — live per-event offload decisions, byte-accounted
feature transport, and (with ``--outage-at``) an edge crash with
heartbeat-detected on-glass failover. ``--wall-clock`` pumps the
``--stream``/``--tiered`` modes from a monotonic clock
(``serving.event_loop.WallClockDriver``) instead of replaying episode
time manually; ``--speed`` fast-forwards the replay.

  PYTHONPATH=src python -m repro.launch.serve --episode 1 --mobility
  PYTHONPATH=src python -m repro.launch.serve --episode 2 --no-cache
  PYTHONPATH=src python -m repro.launch.serve --batched 8
  PYTHONPATH=src python -m repro.launch.serve --stream 4 --scenario mix
  PYTHONPATH=src python -m repro.launch.serve --stream 4 --wall-clock \
      --deadline-ms 50 --speed 10
  PYTHONPATH=src python -m repro.launch.serve --tiered 4 --mobility
  PYTHONPATH=src python -m repro.launch.serve --tiered 2 --outage-at 4
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def build_models(cfg):
    from repro.core import emsnet_module, split
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    key = jax.random.PRNGKey(0)
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    return splits, params


def sample_payloads(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                         (1, cfg.max_text_len)), jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len,
                                               cfg.n_vitals)), jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }


def build_zoo(cfg, seed=0):
    """Subset-model zoo over ONE shared parameter pytree (streaming /
    tiered modes)."""
    from repro.core import emsnet_zoo, split
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    return splits, {k: shared for k in zoo}


def scenario_episodes(n_sessions, scenario, *, n_vitals=4, n_scene=2):
    from repro.core import async_episode
    names = (["text_first", "vitals_first", "scene_late"]
             if scenario == "mix" else [scenario])
    return {f"s{i}": async_episode(names[i % len(names)], seed=i,
                                   n_vitals=n_vitals, n_scene=n_scene)
            for i in range(n_sessions)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episode", type=int, default=1, choices=[1, 2, 3])
    ap.add_argument("--text-encoder", default="tinybert")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--mobility", action="store_true",
                    help="walk 0->30->0 m during the episode (scenario 3)")
    ap.add_argument("--crash-edge-at", type=int, default=-1)
    ap.add_argument("--batched", type=int, default=0, metavar="N",
                    help="serve N concurrent sessions via BatchedEMSServe")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="serve N concurrent async-modality sessions via "
                         "StreamingEMSServe (progressive predictions)")
    ap.add_argument("--scenario", default="mix",
                    choices=["mix", "text_first", "vitals_first",
                             "scene_late"],
                    help="--stream: inter-modality lag scenario")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="--stream: coalesce arrivals within this window "
                         "of episode time before flushing (0 = flush "
                         "per arrival)")
    ap.add_argument("--tiered", type=int, default=0, metavar="N",
                    help="serve N concurrent async-modality sessions via "
                         "TieredEMSServe (glass/edge split placement on "
                         "simulated-clock tiers)")
    ap.add_argument("--outage-at", type=float, default=-1.0, metavar="S",
                    help="--tiered: kill the edge at episode second S "
                         "(heartbeat-detected on-glass failover)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="--stream/--tiered: replay arrivals and pump "
                         "deadline flushes from a monotonic clock")
    ap.add_argument("--speed", type=float, default=1.0,
                    help="--wall-clock: episode seconds per wall second")
    args = ap.parse_args()

    from repro.configs.emsnet import config as emsnet_config
    from repro.core import (AdaptiveOffloadPolicy, BandwidthTrace, Bucketer,
                            EMSServe, HeartbeatMonitor, ProfileTable,
                            nlos_bandwidth, profile, table6)

    cfg = emsnet_config(text_encoder=args.text_encoder, vocab_size=2048)

    if args.tiered:
        from repro.serving.tiered_runtime import TieredEMSServe
        splits, params = build_zoo(cfg)
        payloads = sample_payloads(cfg)
        full = splits["text+vitals+scene"]
        base = profile(full, params["text+vitals+scene"], payloads, iters=3)
        if args.mobility:
            dist = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
            trace = BandwidthTrace.walk(dist, nlos_bandwidth, period=1.0)
        else:
            trace = BandwidthTrace.static(nlos_bandwidth(5.0))
        eps = scenario_episodes(args.tiered, args.scenario)
        eng = TieredEMSServe(splits, params,
                             profile=ProfileTable(base=base), trace=trace,
                             share_encoders=True, max_history=None)
        if args.outage_at >= 0:
            eng.inject_edge_crash(args.outage_at)
        payload_fn = lambda sid, ev: payloads[ev.modality]  # noqa: E731
        if args.wall_clock:
            from repro.serving.event_loop import WallClockDriver
            WallClockDriver(eng, speed=args.speed).run(eps, payload_fn)
        else:
            eng.run_arrivals(eps, payload_fn)
        for r in eng.records:
            fb = " !! failover" if r.fallback else ""
            print(f"[{r.sid:4s} {r.index:2d}] {r.modality:6s} "
                  f"tier={r.tier:5s} {r.kind:7s} "
                  f"up={r.uplink_s*1e3:6.1f}ms "
                  f"compute={r.compute_s*1e3:7.1f}ms "
                  f"down={r.downlink_s*1e3:6.1f}ms "
                  f"latency={r.latency_s*1e3:8.1f}ms{fb}")
        pc = eng.placement_counts()
        ts = eng.transport_stats()
        print(f"\n{args.tiered} sessions, {eng.events_total} arrivals: "
              f"{pc['edge']} offloaded / {pc['glass']} on-glass / "
              f"{pc['fallbacks']} crash failovers")
        print(f"cumulative serving latency {eng.total_latency_s()*1e3:.1f} ms"
              f" | uplink {ts['uplink']['bytes']/1e6:.2f} MB in "
              f"{ts['uplink']['msgs']} msgs | downlink "
              f"{ts['downlink']['bytes']/1e3:.1f} KB in "
              f"{ts['downlink']['msgs']} msgs")
        return

    if args.stream:
        from repro.serving.stream_engine import StreamingEMSServe
        splits, params = build_zoo(cfg)
        payloads = sample_payloads(cfg)
        eps = scenario_episodes(args.stream, args.scenario)
        eng = StreamingEMSServe(
            splits, params, share_encoders=True,
            deadline_s=(args.deadline_ms / 1e3 if args.wall_clock else None),
            bucketer=Bucketer(max_buckets={"vitals": cfg.vitals_len,
                                           "text": cfg.max_text_len}),
            batch_bucket_min=min(8, args.stream),
            max_history=None)      # the trace below prints every flush
        payload_fn = lambda sid, ev: payloads[ev.modality]  # noqa: E731
        if args.wall_clock:
            from repro.serving.event_loop import WallClockDriver
            WallClockDriver(eng, speed=args.speed).run(eps, payload_fn)
        else:
            eng.run_arrivals(eps, payload_fn,
                             sim_window=args.deadline_ms / 1e3)
        for f in eng.flushes:
            for p in f.predictions:
                proto = int(jnp.argmax(p.outputs["protocol_logits"]))
                print(f"flush[{f.flush_id:3d}] {p.sid:4s} "
                      f"{p.kind:7s} over {'+'.join(p.modalities):24s} "
                      f"-> protocol={proto}")
        print(f"\n{args.stream} sessions, {eng.events_total} arrivals, "
              f"{eng.flushes_total} flushes, "
              f"{eng.encoder_calls_total()} encoder calls, "
              f"XLA compiles {eng.compile_count()}")
        for sid in sorted(eps):
            ttfp = eng.time_to_first_prediction(sid)
            ttf = eng.time_to_final_prediction(sid)
            print(f"  {sid}: time-to-first {ttfp*1e3:7.1f} ms | "
                  f"time-to-final "
                  f"{'n/a' if ttf is None else f'{ttf*1e3:7.1f} ms'}")
        return

    splits, params = build_models(cfg)
    payloads = sample_payloads(cfg)

    if args.batched:
        from repro.serving.batch_engine import BatchedEMSServe
        beng = BatchedEMSServe(
            splits, params,
            bucketer=Bucketer(max_buckets={"vitals": cfg.vitals_len,
                                           "text": cfg.max_text_len}),
            batch_bucket_min=min(8, args.batched))
        eps = {f"s{i}": table6()[1 + i % 3] for i in range(args.batched)}
        beng.run_episodes(eps, lambda sid, ev: payloads[ev.modality])
        for i, f in enumerate(beng.flushes):
            print(f"flush[{i:2d}] events={f.n_events:3d} "
                  f"enc_calls={f.n_encoder_calls} tail_calls={f.n_tail_calls} "
                  f"wall={f.wall_s*1e3:7.2f}ms")
        lats = sorted(beng.event_latencies())
        print(f"\n{args.batched} sessions, {beng.events_total} events in "
              f"{beng.total_wall_s()*1e3:.1f} ms compute "
              f"(p50 latency {lats[len(lats)//2]*1e3:.1f} ms, "
              f"XLA compiles {beng.compile_count()}, "
              f"cache entries {len(beng.cache)})")
        return

    base = profile(splits["m3"], params["m3"], payloads)
    base["full"] = base["full"]
    table = ProfileTable(base=base)
    if args.mobility:
        dist = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
        trace = BandwidthTrace.walk(dist, nlos_bandwidth, period=1.0)
    else:
        trace = BandwidthTrace.static(nlos_bandwidth(5.0))
    policy = AdaptiveOffloadPolicy(table, HeartbeatMonitor(trace))

    engine = EMSServe(splits, params, policy=policy,
                      cached=not args.no_cache)
    events = table6()[args.episode]
    for i, ev in enumerate(events):
        if i == args.crash_edge_at:
            print("!! edge server crash — failing over to on-glass inference")
            engine.crash_edge()
        rec = engine.on_event(ev, payloads[ev.modality])
        top = ""
        if rec.recommendation is not None:
            p = int(jnp.argmax(rec.recommendation["protocol_logits"]))
            m = int(jnp.argmax(rec.recommendation["medicine_logits"]))
            q = float(rec.recommendation["quantity"][0])
            top = f" -> protocol={p} medicine={m} qty={q:+.2f}"
        print(f"[{ev.index:2d}] {ev.modality:6s} tier={rec.tier:5s} "
              f"dt={rec.delta_t*1e3:7.2f}ms compute={rec.compute_s*1e3:7.2f}ms "
              f"cum={rec.cumulative_s*1e3:8.2f}ms{top}")
    print(f"\ncumulative serving time: {engine.cumulative_time()*1e3:.1f} ms "
          f"(cache hits: {engine.cache.hits})")


if __name__ == "__main__":
    main()
