"""EMSServe serving launcher.

Default mode runs Table-6 episodes through the per-event reference
engine (``core.engine.EMSServe``) with adaptive offloading, feature
caching, and (optionally) an edge crash, printing the per-event trace.

``--engine SPEC`` serves ``--sessions N`` concurrent sessions through
the unified session engine (``serving.api.build_engine``), where SPEC
is a '+'-joined subset of ``batch`` / ``stream`` / ``tiered`` —
composable, not mutually exclusive:

  PYTHONPATH=src python -m repro.launch.serve --episode 1 --mobility
  PYTHONPATH=src python -m repro.launch.serve --episode 2 --no-cache
  PYTHONPATH=src python -m repro.launch.serve --engine batch --sessions 8
  PYTHONPATH=src python -m repro.launch.serve --engine batch+stream \
      --sessions 4 --scenario mix
  PYTHONPATH=src python -m repro.launch.serve --engine stream \
      --sessions 4 --wall-clock --deadline-ms 50 --speed 10
  PYTHONPATH=src python -m repro.launch.serve --engine tiered \
      --sessions 4 --mobility
  PYTHONPATH=src python -m repro.launch.serve --engine stream+tiered \
      --sessions 2 --outage-at 4

``batch`` coalesces cross-session work into shape-bucketed batched XLA
calls; ``stream`` adds progressive partial->final predictions,
deadlines, and eviction; ``tiered`` hosts the split pieces on
glass/edge simulated-clock tiers (live offload decisions, byte-
accounted transport, ``--outage-at`` edge-crash failover).
``stream+tiered`` additionally serves on-glass provisional partials
while the edge computes each offloaded refresh. ``--wall-clock`` pumps
deadline flushes from a monotonic clock
(``serving.event_loop.WallClockDriver``); ``--speed`` fast-forwards.

``--fleet RATE --replicas N`` runs the region simulator instead
(``repro.fleet``): N engine replicas from one spec over mesh-placed
parameters, open-loop Poisson incident arrivals at RATE sessions/s,
consistent-hash routing, deadline admission control with on-glass
shedding. ``--metrics-out`` writes Prometheus text; ``--trace x.jsonl``
streams a bounded-memory audit trace:

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
      PYTHONPATH=src python -m repro.launch.serve --fleet 4 \
      --replicas 2 --sessions 12 --trace fleet.jsonl --metrics-out m.prom

The pre-unification flags ``--batched/--stream/--tiered N`` still work
as deprecation shims that map onto the equivalent ``--engine`` spec.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def build_models(cfg):
    from repro.core import emsnet_module, split
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    key = jax.random.PRNGKey(0)
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    return splits, params


def sample_payloads(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                         (1, cfg.max_text_len)), jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len,
                                               cfg.n_vitals)), jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }


def build_zoo(cfg, seed=0):
    """Subset-model zoo over ONE shared parameter pytree (streaming /
    tiered specs)."""
    from repro.core import emsnet_zoo, split
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(seed))
    return splits, {k: shared for k in zoo}


def scenario_episodes(n_sessions, scenario, *, n_vitals=4, n_scene=2):
    from repro.core import async_episode
    names = (["text_first", "vitals_first", "scene_late"]
             if scenario == "mix" else [scenario])
    return {f"s{i}": async_episode(names[i % len(names)], seed=i,
                                   n_vitals=n_vitals, n_scene=n_scene)
            for i in range(n_sessions)}


def _mobility_trace(mobility: bool):
    from repro.core import BandwidthTrace, nlos_bandwidth
    if mobility:
        dist = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
        return BandwidthTrace.walk(dist, nlos_bandwidth, period=1.0)
    return BandwidthTrace.static(nlos_bandwidth(5.0))


def _print_tiered(eng, n_sessions):
    for r in eng.records:
        fb = " !! failover" if r.fallback else ""
        gp = (f" (glass partial @{r.glass_partial.t_emit:6.2f}s)"
              if r.glass_partial is not None else "")
        split = (f" tail={r.tail_tier}" if r.tail_tier is not None
                 and r.tail_tier != r.enc_tier else "")
        qz = f" [{r.precision}]" if r.precision != "fp32" else ""
        print(f"[{r.sid:4s} {r.index:2d}] {r.modality:6s} "
              f"tier={r.tier:7s}{qz} {r.kind:7s} "
              f"up={r.uplink_s*1e3:6.1f}ms "
              f"compute={r.compute_s*1e3:7.1f}ms "
              f"down={r.downlink_s*1e3:6.1f}ms "
              f"latency={r.latency_s*1e3:8.1f}ms{fb}{split}{gp}")
    pc = eng.placement_counts()
    fallbacks = pc.pop("fallbacks")
    placed = " / ".join(f"{n} {tier}" for tier, n in pc.items())
    print(f"\n{n_sessions} sessions, {eng.events_total} arrivals: "
          f"{placed} / {fallbacks} crash failovers / "
          f"{eng.rejoin_count} rejoins")
    for link, s in eng.transport_stats()["links"].items():
        print(f"  link {link:18s} {s['bytes']/1e6:8.2f} MB in "
              f"{s['msgs']:3d} msgs")
    print(f"cumulative serving latency {eng.total_latency_s()*1e3:.1f} ms")


def _print_stream(eng, eps):
    for f in eng.flushes:
        for p in f.predictions:
            proto = int(jnp.argmax(p.outputs["protocol_logits"]))
            print(f"flush[{f.flush_id:3d}] {p.sid:4s} "
                  f"{p.kind:7s} over {'+'.join(p.modalities):24s} "
                  f"-> protocol={proto}")
    print(f"\n{len(eps)} sessions, {eng.events_total} arrivals, "
          f"{eng.flushes_total} flushes, "
          f"{eng.encoder_calls_total()} encoder calls, "
          f"XLA compiles {eng.compile_count()}")
    for sid in sorted(eps):
        ttfp = eng.time_to_first_prediction(sid)
        ttf = eng.time_to_final_prediction(sid)
        print(f"  {sid}: time-to-first {ttfp*1e3:7.1f} ms | "
              f"time-to-final "
              f"{'n/a' if ttf is None else f'{ttf*1e3:7.1f} ms'}")


def _print_batch(eng, n_sessions):
    for f in eng.flushes:
        print(f"flush[{f.flush_id:2d}] events={f.n_events:3d} "
              f"enc_calls={f.n_encoder_calls} tail_calls={f.n_tail_calls} "
              f"wall={f.wall_s*1e3:7.2f}ms")
    lats = sorted(eng.event_latencies())
    print(f"\n{n_sessions} sessions, {eng.events_total} events in "
          f"{eng.total_wall_s()*1e3:.1f} ms compute "
          f"(p50 latency {lats[len(lats)//2]*1e3:.1f} ms, "
          f"XLA compiles {eng.compile_count()}, "
          f"cache entries {len(eng.cache)})")


def serve_unified(args):
    """One path for every --engine spec: build the zoo/models, assemble
    the engine from composable policies, drive it, print the trace."""
    from repro.configs.emsnet import config as emsnet_config
    from repro.core import Bucketer, ProfileTable, profile, table6
    from repro.serving.api import build_engine

    cfg = emsnet_config(text_encoder=args.text_encoder, vocab_size=2048)
    spec = parse_spec_tokens(args.engine)
    n = args.sessions
    tiered = "tiered" in spec
    stream = "stream" in spec

    # flag/spec mismatches fail loudly, not silently
    if args.outage_at >= 0 and not tiered:
        raise SystemExit("--outage-at requires a tiered spec "
                         "(e.g. --engine stream+tiered)")
    if args.rejoin_at >= 0 and args.outage_at < 0:
        raise SystemExit("--rejoin-at requires --outage-at")
    if args.tiers and not tiered:
        raise SystemExit("--tiers requires a tiered spec")
    if args.deadline_ms and not stream:
        raise SystemExit("--deadline-ms requires a stream spec")
    if args.wall_clock and not (stream or tiered):
        raise SystemExit("--wall-clock requires a stream or tiered spec")
    if (args.speculate or args.redispatch) and not tiered:
        raise SystemExit("--speculate/--redispatch require a tiered spec")
    if args.precision and not tiered:
        raise SystemExit("--precision requires a tiered spec")
    if args.chaos_seed >= 0 and not tiered:
        raise SystemExit("--chaos-seed requires a tiered spec")
    if args.chaos_seed >= 0 and args.outage_at >= 0:
        raise SystemExit("--chaos-seed conflicts with --outage-at; "
                         "pick one fault schedule")
    if args.ragged and tiered:
        raise SystemExit("--ragged requires a flush-mode spec (batch "
                         "and/or stream); tiered places each arrival "
                         "individually and never coalesces a flush")

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer()

    # the fault schedule is validated against the EPISODES (cheap to
    # build) before any model/profiling work happens
    eps = (scenario_episodes(n, args.scenario) if tiered or stream
           else None)
    chaos = None
    if tiered:
        from repro.core import horizon
        span = horizon(eps)
        if args.outage_at >= 0:
            if args.outage_at > span:
                raise SystemExit(
                    f"--outage-at {args.outage_at:g} is beyond the "
                    f"episode horizon ({span:.2f}s): the crash would "
                    f"never be observed")
            if args.rejoin_at >= 0 and args.rejoin_at <= args.outage_at:
                raise SystemExit(
                    f"--rejoin-at {args.rejoin_at:g} must be strictly "
                    f"after --outage-at {args.outage_at:g}")
        if args.chaos_seed >= 0:
            if not args.tiers:
                raise SystemExit("--chaos-seed needs --tiers (the "
                                 "schedule spans the remote tiers)")
            from repro.serving.chaos import chaos_schedule
            remote = tuple(t.strip() for t in args.tiers.split(",")
                           if t.strip())[1:]
            chaos = chaos_schedule(args.chaos_seed, horizon=span,
                                   tiers=remote)

    kw = {}
    if tiered and args.speculate:
        from repro.core.offload import SpeculationPolicy
        kw["speculation"] = SpeculationPolicy(
            deadline_s=args.spec_deadline_ms / 1e3,
            margin_s=args.spec_margin_ms / 1e3)
    if tiered and args.redispatch:
        kw["redispatch"] = True
    if tiered and args.precision:
        prec = {}
        for part in filter(None, (p.strip()
                                  for p in args.precision.split(","))):
            host, sep, p = part.partition("=")
            if not sep or not host.strip() or not p.strip():
                raise SystemExit(
                    f"--precision: malformed entry {part!r} "
                    "(expected HOST=fp32|int8, comma-separated)")
            prec[host.strip()] = p.strip()
        kw["precision"] = prec
    if tiered or stream:
        splits, params = build_zoo(cfg)          # one shared pytree
        kw["share_encoders"] = True
    else:
        splits, params = build_models(cfg)       # independent m1/m2/m3
    payloads = sample_payloads(cfg)
    payload_fn = lambda sid, ev: payloads[ev.modality]  # noqa: E731

    if tiered:
        full = splits["text+vitals+scene"]
        base = profile(full, params["text+vitals+scene"], payloads, iters=3)
        kw["profile"] = ProfileTable(base=base)
        kw["trace"] = _mobility_trace(args.mobility)
        if args.tiers:
            from repro.core import TIER_FACTORS
            tiers = tuple(t.strip() for t in args.tiers.split(",")
                          if t.strip())
            unknown = [t for t in tiers if t not in TIER_FACTORS]
            if unknown or len(tiers) < 2:
                raise SystemExit(
                    f"--tiers: unknown tier(s) {unknown} or too few; "
                    f"pick >= 2 of {sorted(TIER_FACTORS)} (local first)")
            kw["tiers"] = tiers
            # the EMT's phone rides in a pocket: a near-field tether,
            # unlike the distance-degraded glass<->edge WiFi
            from repro.core import BandwidthTrace, nlos_bandwidth
            kw["tier_traces"] = {t: BandwidthTrace.static(nlos_bandwidth(0.0))
                                 for t in tiers[1:] if t.startswith("ph")}
    if stream:
        kw["deadline_s"] = (args.deadline_ms / 1e3 if args.wall_clock
                            else None)
    if "batch" in spec or stream:
        kw["bucketer"] = Bucketer(max_buckets={"vitals": cfg.vitals_len,
                                               "text": cfg.max_text_len})
        kw["batch_bucket_min"] = min(8, n)
        if args.ragged:
            kw["ragged"] = True

    eng = build_engine(splits, params, "+".join(spec), max_history=None,
                       tracer=tracer, **kw)

    if tiered:
        if args.outage_at >= 0:
            eng.inject_crash(args.outage_at,
                             rejoin_at=(args.rejoin_at
                                        if args.rejoin_at >= 0 else None))
            print(f"fault schedule: crash {eng._primary} "
                  f"@{args.outage_at:.2f}s, detect @{eng.detect_at:.2f}s"
                  + (f", rejoin @{args.rejoin_at:.2f}s"
                     if args.rejoin_at >= 0 else " (no restart)"))
        if chaos is not None:
            eng.inject_schedule(chaos)
            print(f"fault schedule: chaos seed {args.chaos_seed}, "
                  f"{len(chaos)} crash/rejoin cycles")
            for e in chaos:
                rj = (f"rejoin @{e.rejoin_at:6.2f}s"
                      if e.rejoin_at is not None else "no restart")
                print(f"  crash {e.tier:8s} @{e.crash_at:6.2f}s, {rj}")
        if args.wall_clock:
            from repro.serving.event_loop import WallClockDriver
            WallClockDriver(eng, speed=args.speed).run(eps, payload_fn)
        else:
            eng.run_arrivals(eps, payload_fn)
        _print_tiered(eng, n)
        if args.speculate or args.redispatch:
            ss = eng.speculation_stats()
            wins = " / ".join(f"{v} {t}" for t, v in ss["wins"].items()
                              if v)
            print(f"speculation: {ss['races']} races "
                  f"({wins or 'no wins'}), "
                  f"{ss['crash_saves']} crash saves, "
                  f"{ss['redispatches']} re-dispatches, "
                  f"{ss['cancelled_msgs']} cancelled transfers, "
                  f"{ss['duplicate_commits']} duplicate commits")
    elif stream:
        if args.wall_clock:
            from repro.serving.event_loop import WallClockDriver
            WallClockDriver(eng, speed=args.speed).run(eps, payload_fn)
        else:
            eng.run_arrivals(eps, payload_fn,
                             sim_window=args.deadline_ms / 1e3)
        _print_stream(eng, eps)
    else:
        eps = {f"s{i}": table6()[1 + i % 3] for i in range(n)}
        eng.run_episodes(eps, payload_fn)
        _print_batch(eng, n)
    if args.ragged:
        pf = [f.padded_flop_frac for f in eng.flushes]
        print(f"ragged flush: {eng.ragged.n_shapes()} packed shapes, "
              f"mean padded-FLOP fraction "
              f"{float(np.mean(pf)) if pf else 0.0:.3f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(eng.metrics.to_prometheus())
        print(f"metrics: prometheus text -> {args.metrics_out}")
    if tracer is not None:
        other = {"metrics": eng.metrics_snapshot()}
        if tiered:
            other["transport"] = eng.fabric.stats()
        n_ev = tracer.export(args.trace, other_data=other)
        print(f"trace: {n_ev} events -> {args.trace} "
              f"(load in Perfetto: ui.perfetto.dev; audit: "
              f"python -m repro.obs.audit {args.trace})")


def serve_fleet(args):
    """``--fleet RATE``: open-loop region simulation — ``--replicas N``
    engine replicas built from ONE spec over mesh-placed parameters,
    Poisson session arrivals at RATE sessions/s, consistent-hash +
    least-loaded routing, deadline admission control, and the on-glass
    degraded shed path for what the region turns away."""
    from repro.configs.emsnet import config as emsnet_config
    from repro.core import ProfileTable, profile
    from repro.fleet import (AdmissionController, AdmissionPolicy,
                             RegionSim, fleet_mesh, generate_workload,
                             place_fleet_params)

    cfg = emsnet_config(text_encoder=args.text_encoder, vocab_size=2048)
    splits, params = build_zoo(cfg)
    placed, placement = place_fleet_params(params, fleet_mesh())
    payloads = sample_payloads(cfg)
    payload_fn = lambda sid, ev: payloads[ev.modality]  # noqa: E731

    tracer = None
    if args.trace:
        from repro.obs import StreamingTracer, Tracer
        tracer = (StreamingTracer(args.trace, buffer=512)
                  if args.trace.endswith(".jsonl") else Tracer())

    full = splits["text+vitals+scene"]
    base = profile(full, placed["text+vitals+scene"], payloads, iters=2)
    deadline = (args.deadline_ms / 1e3) if args.deadline_ms else 0.5
    ctrl = AdmissionController(AdmissionPolicy(deadline_s=deadline),
                              args.replicas)
    sim = RegionSim(splits, placed, n_replicas=args.replicas,
                    admission=ctrl, profile=ProfileTable(base=base),
                    tracer=tracer)
    axes = "x".join(str(v) for v in placement["axis_sizes"].values())
    print(f"fleet: {args.replicas} replicas, params on {axes} mesh over "
          f"{placement['devices']} device(s) "
          f"({placement['replicated_leaves']} replicated / "
          f"{placement['sharded_leaves']} sharded leaves, "
          f"{placement['param_bytes'] / 1e6:.1f} MB), "
          f"admission deadline {deadline * 1e3:.0f} ms")

    horizon = args.sessions / args.fleet
    sessions = generate_workload(args.fleet, horizon, seed=0)
    rep = sim.run(sessions, payload_fn)

    ttfp = sorted(sim.ttfp.values())
    p = lambda q: ttfp[min(len(ttfp) - 1,  # noqa: E731
                           int(q * len(ttfp)))] if ttfp else float("nan")
    print(f"\n{rep['sessions_offered']} sessions offered @ "
          f"{args.fleet:g}/s: {rep['sessions_admitted']} admitted "
          f"({rep['sessions_finalized']} finalized), "
          f"{rep['sessions_shed']} shed to glass "
          f"({rep['degraded_partials']} degraded partials)")
    print(f"admitted TTFP p50 {p(0.50) * 1e3:7.1f} ms | "
          f"p95 {p(0.95) * 1e3:7.1f} ms | "
          f"makespan {rep['makespan_s']:.2f}s | "
          f"{rep['sessions_finalized'] / rep['makespan_s']:.2f} "
          f"finalized sessions/s")
    for r, pr in enumerate(rep["per_replica"]):
        print(f"  replica {r}: {pr['sessions']:3d} sessions "
              f"{pr['flushes']:4d} flushes "
              f"idle-at {pr['final_clock_s']:.2f}s")

    mx = sim.fleet_metrics()
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(mx.to_prometheus())
        print(f"metrics: prometheus text -> {args.metrics_out}")
    if tracer is not None:
        n_ev = tracer.export(args.trace,
                             other_data={"metrics": mx.snapshot()})
        print(f"trace: {n_ev} events -> {args.trace} "
              f"(audit: python -m repro.obs.audit {args.trace})")


def parse_spec_tokens(engine_arg: str):
    """Canonical token tuple for an --engine spec string (validation is
    re-done by api.parse_spec; this is just for mode branching)."""
    from repro.serving.api import _SPEC_TOKENS
    toks = []
    for t in filter(None, (t.strip() for t in engine_arg.split("+"))):
        canon = _SPEC_TOKENS.get(t.lower())
        if canon is None:
            raise SystemExit(f"--engine: unknown token {t!r} "
                             f"(use +-joined batch/stream/tiered)")
        if canon not in toks:
            toks.append(canon)
    if not toks:
        raise SystemExit("--engine: empty spec")
    return tuple(toks)


def _apply_legacy_shims(args):
    """Map the pre-unification mode flags onto --engine specs, with a
    one-line pointer to the replacement."""
    for flag, count, spec in (("--batched", args.batched, "batch"),
                              ("--stream", args.stream, "stream"),
                              ("--tiered", args.tiered, "tiered")):
        if count:
            if args.engine:
                raise SystemExit(f"{flag} conflicts with --engine; "
                                 f"use --engine alone")
            args.engine = spec
            args.sessions = count
            print(f"note: {flag} N is deprecated — use "
                  f"`--engine {spec} --sessions {count}` "
                  f"(specs compose, e.g. --engine stream+tiered)")
    return args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episode", type=int, default=1, choices=[1, 2, 3])
    ap.add_argument("--text-encoder", default="tinybert")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--mobility", action="store_true",
                    help="walk 0->30->0 m during the episode (scenario 3)")
    ap.add_argument("--crash-edge-at", type=int, default=-1)
    ap.add_argument("--engine", default="", metavar="SPEC",
                    help="unified session engine: '+'-joined subset of "
                         "batch/stream/tiered (e.g. batch+stream, "
                         "stream+tiered)")
    ap.add_argument("--sessions", type=int, default=4, metavar="N",
                    help="--engine: number of concurrent sessions")
    ap.add_argument("--scenario", default="mix",
                    choices=["mix", "text_first", "vitals_first",
                             "scene_late"],
                    help="stream/tiered specs: inter-modality lag scenario")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="stream spec: coalesce arrivals within this "
                         "window before flushing (0 = flush per arrival)")
    ap.add_argument("--ragged", action="store_true",
                    help="batch/stream specs: pack the pending rows of "
                         "each variable-length modality into ONE "
                         "concatenated ragged kernel call per flush and "
                         "fuse all pending tails into ONE grouped call")
    ap.add_argument("--outage-at", type=float, default=-1.0, metavar="S",
                    help="tiered spec: kill the (fastest) remote tier at "
                         "episode second S (heartbeat-detected on-glass "
                         "failover)")
    ap.add_argument("--rejoin-at", type=float, default=-1.0, metavar="S",
                    help="tiered spec: restart the crashed tier at episode "
                         "second S (replica re-warm from the glass cache, "
                         "placement-eligible again)")
    ap.add_argument("--tiers", default="", metavar="LIST",
                    help="tiered spec: comma-separated ordered tier list "
                         "from core.offload.TIER_FACTORS, local first "
                         "(e.g. glass,ph1,edge64x); enables contention-"
                         "aware decisions and per-submodule tail placement")
    ap.add_argument("--speculate", action="store_true",
                    help="tiered spec: arm speculative dual placement — "
                         "deadline-pressured arrivals race glass against "
                         "the best remote (cancel-on-commit)")
    ap.add_argument("--spec-deadline-ms", type=float, default=350.0,
                    help="--speculate: per-arrival serving deadline")
    ap.add_argument("--spec-margin-ms", type=float, default=50.0,
                    help="--speculate: race when the estimated slack "
                         "before the deadline dips below this")
    ap.add_argument("--redispatch", action="store_true",
                    help="tiered spec: re-aim a flight lost to a tier "
                         "crash at the next-best surviving remote "
                         "instead of always re-running on glass")
    ap.add_argument("--precision", default="", metavar="MAP",
                    help="tiered spec: comma-separated HOST=fp32|int8 "
                         "map (e.g. ph1=int8,edge64x=int8) arming the "
                         "joint precision+placement co-decision: int8-"
                         "capable hosts may run the sidecar-quantized "
                         "encoders and ship ~4x-smaller packed features "
                         "when the link is the bottleneck")
    ap.add_argument("--chaos-seed", type=int, default=-1, metavar="SEED",
                    help="tiered spec with --tiers: seeded random "
                         "crash/rejoin schedule over the remote tiers "
                         "(repeated crash->re-dispatch->rejoin cycles)")
    ap.add_argument("--fleet", type=float, default=0.0, metavar="RATE",
                    help="region simulation: offer whole incident "
                         "sessions at RATE sessions/s (open-loop "
                         "Poisson) to --replicas engine replicas with "
                         "admission control; --sessions N is the total "
                         "offered count")
    ap.add_argument("--replicas", type=int, default=2, metavar="N",
                    help="--fleet: engine replicas (params are placed "
                         "across the jax device mesh; emulate devices "
                         "with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--metrics-out", default="", metavar="PATH",
                    help="write the run's metrics registry as "
                         "Prometheus text exposition to PATH (fleet "
                         "mode: exact fleet-wide merge across replicas)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="--engine/--fleet: record every event's "
                         "serving lifecycle with repro.obs.Tracer and "
                         "export a Chrome trace-event JSON (Perfetto-"
                         "loadable, auditable via python -m "
                         "repro.obs.audit); a .jsonl PATH in fleet mode "
                         "streams through the bounded-memory "
                         "StreamingTracer instead")
    ap.add_argument("--wall-clock", action="store_true",
                    help="stream/tiered specs: replay arrivals and pump "
                         "deadline flushes from a monotonic clock")
    ap.add_argument("--speed", type=float, default=1.0,
                    help="--wall-clock: episode seconds per wall second")
    # ---- deprecated mode flags (shims onto --engine)
    ap.add_argument("--batched", type=int, default=0, metavar="N",
                    help="deprecated: --engine batch --sessions N")
    ap.add_argument("--stream", type=int, default=0, metavar="N",
                    help="deprecated: --engine stream --sessions N")
    ap.add_argument("--tiered", type=int, default=0, metavar="N",
                    help="deprecated: --engine tiered --sessions N")
    args = _apply_legacy_shims(ap.parse_args())

    if args.fleet < 0.0:
        raise SystemExit("--fleet RATE must be > 0 (sessions/s)")
    if args.fleet and args.engine:
        raise SystemExit("--fleet conflicts with --engine: the region "
                         "simulator builds its own replica engines "
                         "from one spec")
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.trace and not (args.engine or args.fleet):
        raise SystemExit("--trace requires --engine or --fleet (the "
                         "reference per-event engine predates the "
                         "traced serving stack)")
    if args.metrics_out and not (args.engine or args.fleet):
        raise SystemExit("--metrics-out requires --engine or --fleet")
    if args.fleet:
        serve_fleet(args)
        return
    if args.engine:
        serve_unified(args)
        return

    # ---- default: the per-event reference engine on a Table-6 episode
    from repro.configs.emsnet import config as emsnet_config
    from repro.core import (AdaptiveOffloadPolicy, EMSServe,
                            HeartbeatMonitor, ProfileTable, profile, table6)

    cfg = emsnet_config(text_encoder=args.text_encoder, vocab_size=2048)
    splits, params = build_models(cfg)
    payloads = sample_payloads(cfg)

    base = profile(splits["m3"], params["m3"], payloads)
    table = ProfileTable(base=base)
    policy = AdaptiveOffloadPolicy(table,
                                   HeartbeatMonitor(
                                       _mobility_trace(args.mobility)))

    engine = EMSServe(splits, params, policy=policy,
                      cached=not args.no_cache)
    events = table6()[args.episode]
    for i, ev in enumerate(events):
        if i == args.crash_edge_at:
            print("!! edge server crash — failing over to on-glass inference")
            engine.crash_edge()
        rec = engine.on_event(ev, payloads[ev.modality])
        top = ""
        if rec.recommendation is not None:
            p = int(jnp.argmax(rec.recommendation["protocol_logits"]))
            m = int(jnp.argmax(rec.recommendation["medicine_logits"]))
            q = float(rec.recommendation["quantity"][0])
            top = f" -> protocol={p} medicine={m} qty={q:+.2f}"
        print(f"[{ev.index:2d}] {ev.modality:6s} tier={rec.tier:5s} "
              f"dt={rec.delta_t*1e3:7.2f}ms compute={rec.compute_s*1e3:7.2f}ms "
              f"cum={rec.cumulative_s*1e3:8.2f}ms{top}")
    print(f"\ncumulative serving time: {engine.cumulative_time()*1e3:.1f} ms "
          f"(cache hits: {engine.cache.hits})")


if __name__ == "__main__":
    main()
