"""EMSServe serving launcher: run Table-6 episodes through the engine
with adaptive offloading, feature caching, and (optionally) an edge
crash, printing the per-event trace. ``--batched N`` instead serves N
concurrent sessions through the coalescing BatchedEMSServe fast path
and prints per-flush stats.

  PYTHONPATH=src python -m repro.launch.serve --episode 1 --mobility
  PYTHONPATH=src python -m repro.launch.serve --episode 2 --no-cache
  PYTHONPATH=src python -m repro.launch.serve --batched 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np


def build_models(cfg):
    from repro.core import emsnet_module, split
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    key = jax.random.PRNGKey(0)
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    return splits, params


def sample_payloads(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                         (1, cfg.max_text_len)), jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len,
                                               cfg.n_vitals)), jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--episode", type=int, default=1, choices=[1, 2, 3])
    ap.add_argument("--text-encoder", default="tinybert")
    ap.add_argument("--no-cache", action="store_true")
    ap.add_argument("--mobility", action="store_true",
                    help="walk 0->30->0 m during the episode (scenario 3)")
    ap.add_argument("--crash-edge-at", type=int, default=-1)
    ap.add_argument("--batched", type=int, default=0, metavar="N",
                    help="serve N concurrent sessions via BatchedEMSServe")
    args = ap.parse_args()

    from repro.configs.emsnet import config as emsnet_config
    from repro.core import (AdaptiveOffloadPolicy, BandwidthTrace, Bucketer,
                            EMSServe, HeartbeatMonitor, ProfileTable,
                            nlos_bandwidth, profile, table6)

    cfg = emsnet_config(text_encoder=args.text_encoder, vocab_size=2048)
    splits, params = build_models(cfg)
    payloads = sample_payloads(cfg)

    if args.batched:
        from repro.serving.batch_engine import BatchedEMSServe
        beng = BatchedEMSServe(
            splits, params,
            bucketer=Bucketer(max_buckets={"vitals": cfg.vitals_len,
                                           "text": cfg.max_text_len}),
            batch_bucket_min=min(8, args.batched))
        eps = {f"s{i}": table6()[1 + i % 3] for i in range(args.batched)}
        beng.run_episodes(eps, lambda sid, ev: payloads[ev.modality])
        for i, f in enumerate(beng.flushes):
            print(f"flush[{i:2d}] events={f.n_events:3d} "
                  f"enc_calls={f.n_encoder_calls} tail_calls={f.n_tail_calls} "
                  f"wall={f.wall_s*1e3:7.2f}ms")
        lats = sorted(beng.event_latencies())
        print(f"\n{args.batched} sessions, {beng.events_total} events in "
              f"{beng.total_wall_s()*1e3:.1f} ms compute "
              f"(p50 latency {lats[len(lats)//2]*1e3:.1f} ms, "
              f"XLA compiles {beng.compile_count()}, "
              f"cache entries {len(beng.cache)})")
        return

    base = profile(splits["m3"], params["m3"], payloads)
    base["full"] = base["full"]
    table = ProfileTable(base=base)
    if args.mobility:
        dist = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 11))
        trace = BandwidthTrace.walk(dist, nlos_bandwidth, period=1.0)
    else:
        trace = BandwidthTrace.static(nlos_bandwidth(5.0))
    policy = AdaptiveOffloadPolicy(table, HeartbeatMonitor(trace))

    engine = EMSServe(splits, params, policy=policy,
                      cached=not args.no_cache)
    events = table6()[args.episode]
    for i, ev in enumerate(events):
        if i == args.crash_edge_at:
            print("!! edge server crash — failing over to on-glass inference")
            engine.crash_edge()
        rec = engine.on_event(ev, payloads[ev.modality])
        top = ""
        if rec.recommendation is not None:
            p = int(jnp.argmax(rec.recommendation["protocol_logits"]))
            m = int(jnp.argmax(rec.recommendation["medicine_logits"]))
            q = float(rec.recommendation["quantity"][0])
            top = f" -> protocol={p} medicine={m} qty={q:+.2f}"
        print(f"[{ev.index:2d}] {ev.modality:6s} tier={rec.tier:5s} "
              f"dt={rec.delta_t*1e3:7.2f}ms compute={rec.compute_s*1e3:7.2f}ms "
              f"cum={rec.cumulative_s*1e3:8.2f}ms{top}")
    print(f"\ncumulative serving time: {engine.cumulative_time()*1e3:.1f} ms "
          f"(cache hits: {engine.cache.hits})")


if __name__ == "__main__":
    main()
