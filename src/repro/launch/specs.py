"""ShapeDtypeStruct input specs for every (arch x input-shape) combination.

``input_specs(cfg, shape, policy)`` returns (fn, args) where ``fn`` is
the step function to lower and ``args`` is a pytree of ShapeDtypeStructs
carrying NamedShardings — weak-type-correct, shardable, zero allocation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs.base import InputShape, ModelConfig
from repro.distributed.sharding import Policy
from repro.models import transformer as T
from repro.serving.kv_cache import cache_plan
from repro.training import optimizer as OPT
from repro.training.trainer import make_train_step


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(policy: Policy, pspecs, tree):
    return jax.tree.map(
        lambda leaf, spec: _sds(leaf.shape, leaf.dtype,
                                NamedSharding(policy.mesh, spec)),
        tree, pspecs)


def abstract_params(cfg: ModelConfig, policy: Policy):
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    return _with_shardings(policy, policy.param_pspecs(aparams), aparams)


def token_batch(cfg: ModelConfig, shape: InputShape, policy: Policy,
                *, labels: bool):
    B, S = shape.global_batch, shape.seq_len
    tshape = (B, S) if cfg.n_codebooks == 1 else (B, S, cfg.n_codebooks)
    batch = {"tokens": jax.ShapeDtypeStruct(tshape, jnp.int32)}
    if labels:
        batch["labels"] = jax.ShapeDtypeStruct(tshape, jnp.int32)
    if cfg.cond_dim:
        batch["cond"] = jax.ShapeDtypeStruct(
            (B, cfg.cond_seq_len, cfg.cond_dim), jnp.bfloat16)
    return _with_shardings(policy, policy.batch_pspecs(batch), batch)


def input_specs(cfg: ModelConfig, shape: InputShape, policy: Policy):
    """Returns (step_fn, args_tuple) ready for jax.jit(step_fn).lower(*args)."""
    params = abstract_params(cfg, policy)

    if shape.kind == "train":
        def constrain_grads(grads):
            pspecs = policy.param_pspecs(grads)
            return jax.tree.map(
                lambda g, s: jax.lax.with_sharding_constraint(
                    g, NamedSharding(policy.mesh, s)),
                grads, pspecs)
        moe_a2a = None
        if (policy.tuned and policy.strategy == "fsdp" and cfg.n_experts):
            expert_axes = (("data", "model") if policy.experts_2d
                           else ("model",))
            moe_a2a = {"mesh": policy.mesh, "token_axes": policy.dp,
                       "expert_axes": expert_axes}
        step, opt_init = make_train_step(cfg, constrain=policy.constrain,
                                         constrain_grads=constrain_grads,
                                         moe_a2a=moe_a2a)
        aopt = jax.eval_shape(opt_init, params)
        opt = _with_shardings(policy, policy.opt_pspecs(params, aopt), aopt)
        batch = token_batch(cfg, shape, policy, labels=True)
        return step, (params, opt, batch)

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch["tokens"],
                             cond=batch.get("cond"),
                             constrain=policy.constrain)
        batch = token_batch(cfg, shape, policy, labels=False)
        return prefill_step, (params, batch)

    if shape.kind == "decode":
        cache_len, window = cache_plan(cfg, shape)
        long = shape.name == "long_500k"

        moe_pre = None
        if policy.tuned and cfg.n_experts:
            from jax.sharding import PartitionSpec as P

            def moe_pre(h):
                return jax.lax.with_sharding_constraint(
                    h, NamedSharding(policy.mesh, P(*(None,) * h.ndim)))

        def serve_step(params, cache, tokens, t):
            return T.decode_step(params, cfg, tokens, cache, t,
                                 window_attn=window, moe_pre=moe_pre)

        acache = jax.eval_shape(
            partial(T.init_cache, cfg, shape.global_batch, cache_len))
        cache = _with_shardings(policy, policy.cache_pspecs(acache, long=long),
                                acache)
        B = shape.global_batch
        tshape = (B, 1) if cfg.n_codebooks == 1 else (B, 1, cfg.n_codebooks)
        tokens = _with_shardings(
            policy, policy.batch_spec(jax.ShapeDtypeStruct(tshape, jnp.int32)),
            jax.ShapeDtypeStruct(tshape, jnp.int32))
        t = jax.ShapeDtypeStruct((), jnp.int32)
        return serve_step, (params, cache, tokens, t)

    raise ValueError(shape.kind)
