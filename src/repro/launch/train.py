"""Training launcher.

Two modes, matching the paper's workload (EMSNet) and the assigned-
architecture zoo:

  * ``--model emsnet``: end-to-end EMSNet training on the synthetic
    NEMSIS-schema datasets — D1 (2-modal) pretraining, then PMI 3-modal
    integration on D2, evaluation on held-out test splits, checkpoint.
    ``--text-encoder bertbase`` gives the ~110M-parameter configuration.

  * ``--model <arch-id> [--reduced]``: LM training loop for any
    registry architecture on synthetic token streams (reduced configs
    run on CPU; full configs are exercised via the dry-run).
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def train_emsnet(args):
    import jax
    from repro.configs.emsnet import config as emsnet_config
    from repro.data import synthetic_nemsis as D
    from repro.training import checkpoint as CKPT
    from repro.training import emsnet_trainer as ET

    cfg = emsnet_config(text_encoder=args.text_encoder,
                        vitals_encoder=args.vitals_encoder,
                        vocab_size=2048)
    print(f"EMSNet {cfg.text_encoder}-{cfg.vitals_encoder}-fc")
    d1 = D.generate(cfg, args.d1_size, seed=0)
    tr1, va1, te1 = D.splits(d1)
    print(f"D1 (2-modal): {len(d1)} samples -> {len(tr1)}/{len(va1)}/{len(te1)}")

    t0 = time.time()
    loader1 = D.loader(tr1, args.batch, modalities=("text", "vitals"))
    params2, _ = ET.train(cfg, loader1, modalities=("text", "vitals"),
                          steps=args.steps, lr=args.lr,
                          log_every=max(args.steps // 5, 1))
    m2 = ET.evaluate(params2, cfg, te1, ("text", "vitals"))
    print(f"2-modal test ({time.time()-t0:.0f}s):",
          {k: round(v, 3) for k, v in m2.items()})

    d2 = D.generate(cfg, args.d2_size, seed=7, modal3=True)
    tr2, va2, te2 = D.splits(d2)
    loader2 = D.loader(tr2, min(args.batch, 32))
    params3, _ = ET.pmi_finetune(cfg, params2, loader2,
                                 steps=max(args.steps // 2, 50), lr=args.lr)
    m3 = ET.evaluate(params3, cfg, te2, ("text", "vitals", "scene"))
    print("3-modal PMI test:", {k: round(v, 3) for k, v in m3.items()})

    if args.out:
        CKPT.save(args.out, {"m2": params2, "m3": params3},
                  metadata={"cfg": str(cfg), "metrics2": m2, "metrics3": m3})
        print(f"checkpoint -> {args.out}")


def train_llm(args):
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.training.trainer import make_train_step

    cfg = get_config(args.model)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    step_fn, opt_init = make_train_step(cfg)
    step_fn = jax.jit(step_fn)
    from repro.models import transformer as T
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    opt_state = opt_init(params)
    rng = np.random.default_rng(0)
    B, S = args.batch, args.seq
    for i in range(args.steps):
        shape = (B, S) if cfg.n_codebooks == 1 else (B, S, cfg.n_codebooks)
        toks = rng.integers(0, cfg.vocab_size, size=(shape[0], shape[1] + 1)
                            + shape[2:]).astype(np.int32)
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if cfg.cond_dim:
            batch["cond"] = jnp.asarray(
                rng.normal(size=(B, cfg.cond_seq_len, cfg.cond_dim)), jnp.float32)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if (i + 1) % max(args.steps // 5, 1) == 0:
            print(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="emsnet")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--text-encoder", default="tinybert")
    ap.add_argument("--vitals-encoder", default="gru")
    ap.add_argument("--d1-size", type=int, default=8000)
    ap.add_argument("--d2-size", type=int, default=600)
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    if args.model == "emsnet":
        train_emsnet(args)
    else:
        train_llm(args)


if __name__ == "__main__":
    main()
