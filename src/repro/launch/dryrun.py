import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks
# the device count on first init), which is why the module docstring
# below is a plain string and `from __future__` is not used here.

DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

For each combination this lowers the appropriate step function
(train_step / prefill_step / serve_step) with ShapeDtypeStruct inputs
carrying production NamedShardings, compiles it, and records
memory_analysis(), cost_analysis(), and the collective schedule parsed
from the compiled HLO into a JSON artifact under
``benchmarks/artifacts/dryrun/``.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch mistral-nemo-12b \
      --shape decode_32k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis import roofline as RL
from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed.sharding import Policy
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs

ARTIFACTS = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def run_one(arch: str, shape_name: str, mesh_kind: str, *, save=True,
            keep_hlo=False, tuned=False, strategy="2d"):
    import dataclasses
    cfg = get_config(arch)
    if strategy == "fsdp":
        # pure-FSDP shards batch over all intra-pod chips: one sample
        # per device at train_4k, so no microbatch accumulation
        cfg = dataclasses.replace(cfg, train_microbatches=1)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.devices.size
    policy = Policy(cfg, mesh, tuned=tuned, strategy=strategy)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": chips, "tuned": tuned, "strategy": strategy, "ok": False}
    t0 = time.time()
    try:
        fn, args = input_specs(cfg, shape, policy)
        with mesh:
            lowered = jax.jit(fn).lower(*args)
            rec["t_lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["t_compile_s"] = time.time() - t1
        mem = compiled.memory_analysis()
        if mem is not None:
            for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                      "output_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(mem, k, None)
                if v is not None:
                    rec[k] = int(v)
        cost = compiled.cost_analysis() or {}
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float))}
        hlo = compiled.as_text()
        from repro.analysis.hlo_analyzer import analyze_hlo
        totals = analyze_hlo(hlo)
        rec["collectives_bytes"] = totals.coll
        rec["collectives_count"] = totals.coll_count
        roof = RL.analyze(rec["cost"], hlo, cfg, shape, chips,
                          experts_2d=tuned and policy.experts_2d)
        rec["roofline"] = roof.as_dict()
        rec["param_count"] = cfg.param_count()
        rec["active_param_count"] = cfg.active_param_count()
        rec["ok"] = True
        if keep_hlo:
            rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # noqa: BLE001 — record failures as data
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["t_total_s"] = time.time() - t0
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        suffix = "_tuned" if tuned else ""
        if strategy != "2d":
            suffix += f"_{strategy}"
        out = ARTIFACTS / f"{arch}_{shape_name}_{mesh_kind}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tuned", action="store_true",
                    help="apply the §Perf sharding changes (see "
                         "distributed.sharding.Policy)")
    ap.add_argument("--strategy", default="2d", choices=["2d", "fsdp"])
    args = ap.parse_args()

    archs = ARCHS if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                rec = run_one(arch, shape, mk, tuned=args.tuned,
                              strategy=args.strategy)
                status = "OK" if rec["ok"] else "FAIL"
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
                extra = ""
                if rec["ok"]:
                    r = rec["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" tc={r['t_compute_s']:.3g}s"
                             f" tm={r['t_memory_s']:.3g}s"
                             f" tcoll={r['t_collective_s']:.3g}s")
                else:
                    extra = " " + rec["error"][:200]
                print(f"[{status}] {arch} x {shape} x {mk}"
                      f" ({rec['t_total_s']:.1f}s){extra}", flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
