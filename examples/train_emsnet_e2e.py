"""End-to-end training driver (deliverable b): train EMSNet on the
synthetic NEMSIS-schema dataset for a few hundred steps — D1 2-modal
pretraining then PMI 3-modal integration — evaluate all three tasks,
checkpoint, and serve the result through EMSServe.

With ``--text-encoder bertbase`` the backbone is the paper's ~110M
configuration (slow on CPU); the default tinybert is the paper's
on-device pick.

  PYTHONPATH=src python examples/train_emsnet_e2e.py --steps 300
"""
import argparse
import sys

from repro.launch import train as launcher

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--model") for a in argv):
        argv = ["--model", "emsnet", "--out", "checkpoints/emsnet"] + argv
    sys.argv = [sys.argv[0]] + argv
    launcher.main()
