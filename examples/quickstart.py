"""Quickstart: EMSNet + EMSServe in ~60 lines.

Builds the paper's three models (M1 text, M2 text+vitals, M3
text+vitals+scene), splits them with the modality-aware splitter, and
streams paper Table-6 episode 1 through the EMSServe engine twice —
direct (PyTorch-style re-inference) vs cached — printing the speedup.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.emsnet import tiny
from repro.core import EMSServe, emsnet_module, profile, split, table6

cfg = tiny()
key = jax.random.PRNGKey(0)

# --- build + split the multimodal models (paper Fig. 9: M1/M2/M3) ----
modules = {
    "m1": emsnet_module(cfg, ("text",)),
    "m2": emsnet_module(cfg, ("text", "vitals")),
    "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
}
models = {k: split(m) for k, m in modules.items()}
params = {k: m.init_fn(jax.random.fold_in(key, i))
          for i, (k, m) in enumerate(modules.items())}

# --- sample multimodal payloads (stub frontends) ----------------------
rng = np.random.default_rng(0)
payloads = {
    "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                     (1, cfg.max_text_len)), jnp.int32),
    "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len, cfg.n_vitals)),
                          jnp.float32),
    "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)), jnp.float32),
}

# --- one-time offline profiling (paper §4.2.2) ------------------------
prof = profile(models["m3"], params["m3"], payloads)
print("profile:", {k: f"{v*1e3:.2f} ms" for k, v in prof.items()})

# --- episode 1, direct vs cached --------------------------------------
times = {}
for cached in (False, True):
    for attempt in range(2):                      # 2nd run: warm jits
        eng = EMSServe(models, params, cached=cached, real_time=True)
        eng.run_episode(table6()[1], lambda ev: payloads[ev.modality])
    times[cached] = eng.cumulative_time()
    last = eng.records[-1].recommendation
    print(f"{'cached' if cached else 'direct':6s}: "
          f"{times[cached]*1e3:8.1f} ms cumulative, "
          f"final protocol={int(jnp.argmax(last['protocol_logits']))}")

print(f"\nEMSServe speedup over direct multimodal inference: "
      f"{times[False]/times[True]:.2f}x  (paper: 1.9x-11.7x)")
