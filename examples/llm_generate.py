"""Prefix-cached LLM serving across the architecture zoo.

The paper's feature cache, applied to autoregressive state: requests
that extend an already-served prompt reuse the stored decode cache (KV
ring buffer / MLA latent / SSM state) instead of re-encoding the prefix.
Runs reduced variants of three different cache families on CPU.

  PYTHONPATH=src python examples/llm_generate.py [--arch rwkv6-1.6b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import transformer as T
from repro.serving.engine import LLMServer, Request

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default=None,
                help="single arch id; default runs three cache families")
args = ap.parse_args()

archs = [args.arch] if args.arch else \
    ["mistral-nemo-12b", "rwkv6-1.6b", "deepseek-v3-671b"]

for arch in archs:
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    srv = LLMServer(cfg, params, cache_len=96)

    system_prompt = np.arange(1, 33, dtype=np.int32)     # shared prefix
    users = [np.array([40 + i, 50 + i], np.int32) for i in range(4)]

    t0 = time.perf_counter()
    srv.serve_one(Request("warm", system_prompt, max_new_tokens=1))
    results = [srv.serve_one(Request(f"u{i}",
                                     np.concatenate([system_prompt, u]),
                                     max_new_tokens=8))
               for i, u in enumerate(users)]
    dt = time.perf_counter() - t0

    hits = sum(r.prefix_hit for r in results)
    encoded = sum(r.prefill_tokens for r in results)
    naive = sum(len(system_prompt) + len(u) for u in users)
    print(f"{arch:22s} ({cfg.arch_type:6s}): {hits}/4 prefix hits, "
          f"encoded {encoded} vs {naive} prompt tokens "
          f"({naive/max(encoded,1):.1f}x fewer), {dt:.2f}s, "
          f"first completion: {results[0].tokens[:6].tolist()}")
