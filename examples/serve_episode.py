"""EMSServe scenario demo: adaptive offloading under mobility + edge
crash fault tolerance (paper §4.2.3 + Figure 15).

An EMT wearing the glass walks away from the manpack edge server
(0 -> 30 m through NLOS rooms) while episode-2 data arrives
asynchronously; at event 12 the manpack battery dies. Watch the
placement decisions flip and the failover keep recommendations flowing.

  PYTHONPATH=src python examples/serve_episode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.emsnet import tiny
from repro.core import (AdaptiveOffloadPolicy, BandwidthTrace, EMSServe,
                        HeartbeatMonitor, ProfileTable, emsnet_module,
                        nlos_bandwidth, profile, split, table6)

cfg = tiny()
key = jax.random.PRNGKey(0)
modules = {
    "m1": emsnet_module(cfg, ("text",)),
    "m2": emsnet_module(cfg, ("text", "vitals")),
    "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
}
models = {k: split(m) for k, m in modules.items()}
params = {k: m.init_fn(jax.random.fold_in(key, i))
          for i, (k, m) in enumerate(modules.items())}

rng = np.random.default_rng(1)
payloads = {
    "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                     (1, cfg.max_text_len)), jnp.int32),
    "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len, cfg.n_vitals)),
                          jnp.float32),
    "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)), jnp.float32),
}

# profile once offline, then drive decisions from it (paper §4.2.2)
base = profile(models["m3"], params["m3"], payloads)
trace = BandwidthTrace.walk(np.linspace(0, 30, 21), nlos_bandwidth)
policy = AdaptiveOffloadPolicy(ProfileTable(base=base),
                               HeartbeatMonitor(trace))

engine = EMSServe(models, params, policy=policy, cached=True)
for i, ev in enumerate(table6()[2]):
    if i == 12:
        print("-- manpack battery died: edge crash, failing over on-glass --")
        engine.crash_edge()
    rec = engine.on_event(ev, payloads[ev.modality])
    out = ""
    if rec.recommendation is not None:
        out = (f" protocol={int(jnp.argmax(rec.recommendation['protocol_logits']))}"
               f" medicine={int(jnp.argmax(rec.recommendation['medicine_logits']))}")
    print(f"[{i:2d}] {ev.modality:6s} -> {rec.tier:5s}"
          f"  transfer={rec.delta_t*1e3:7.1f}ms"
          f"  compute={rec.compute_s*1e3:7.1f}ms"
          f"  model={rec.model or '-':3s}{out}")

print(f"\ncumulative: {engine.cumulative_time()*1e3:.1f} ms, "
      f"cache hits: {engine.cache.hits}, entries: {len(engine.cache)}")
