"""Bucketing invariants: monotone bucket selection, masks that zero out
exactly the padded tail, and bucketed-equals-unbucketed encoder math on
the unpadded prefix (including length-0 and length-==-bucket rows)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.emsnet import tiny
from repro.core import Bucketer, bucket_length
from repro.core.bucketing import pad_axis, stack_bucketed
from repro.models import emsnet as E


# ----------------------------------------------------------- monotone

def test_bucket_length_monotone_unclamped():
    bs = [bucket_length(n) for n in range(1, 257)]
    assert all(a <= b for a, b in zip(bs, bs[1:]))


@pytest.mark.parametrize("max_bucket", [4, 16, 48, 64])
def test_bucket_length_monotone_clamped(max_bucket):
    bs = [bucket_length(n, max_bucket=max_bucket) for n in range(1, 257)]
    assert all(a <= b for a, b in zip(bs, bs[1:]))
    assert bs[-1] == max_bucket                    # clamp reached
    assert all(b <= max_bucket for b in bs)        # never past the cap


def test_bucket_length_idempotent():
    """A bucketed length re-buckets to itself: serving a padded payload
    again never grows it."""
    for n in range(1, 129):
        b = bucket_length(n, max_bucket=64)
        assert bucket_length(b, max_bucket=64) == b


# ------------------------------------------------- masks vs padded tail

def test_vitals_mask_covers_exactly_the_padded_tail():
    b = Bucketer(min_bucket=4)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 5, 3)),
                    jnp.float32)
    p = b.fit("vitals", x)
    T_b, n = int(p["x"].shape[1]), int(p["len"][0])
    assert n == 5 and T_b == 8
    np.testing.assert_array_equal(np.asarray(p["x"][:, :n]), np.asarray(x))
    assert np.all(np.asarray(p["x"][:, n:]) == 0.0)   # tail is all-zero


def test_text_pad_is_exactly_the_pad_suffix():
    b = Bucketer(min_bucket=4)
    toks = jnp.asarray([[7, 9, 11]], jnp.int32)
    p = b.fit("text", toks)
    assert p.shape == (1, 4)
    np.testing.assert_array_equal(np.asarray(p[0, :3]), [7, 9, 11])
    assert int(p[0, 3]) == 0                          # PAD id, masked out


def test_stack_bucketed_surplus_rows_are_masked_out():
    """Batch-axis padding rows carry len=0 (vitals) / PAD=0 (text), so
    the encoders' masks zero exactly those rows."""
    rows = [{"x": jnp.ones((1, 4, 2)), "len": jnp.array([4], jnp.int32)}
            for _ in range(3)]
    s = stack_bucketed(rows, 8)
    assert np.all(np.asarray(s["len"][3:]) == 0)
    assert np.all(np.asarray(s["x"][3:]) == 0.0)
    t = stack_bucketed([jnp.full((1, 4), 5, jnp.int32)] * 3, 8)
    assert np.all(np.asarray(t[3:]) == 0)


# --------------------------------- bucketed == unbucketed on the prefix

@pytest.mark.parametrize("kind", ["rnn", "gru", "lstm"])
def test_bucketed_vitals_rows_equal_unpadded_prefix(kind):
    """Each row of a bucketed call equals the unbucketed call on that
    row's unpadded prefix — including a length-0 row (the zero initial
    state) and a length-==-bucket row (no padding at all)."""
    cfg = tiny(vitals_encoder=kind)
    p = E.vitals_encoder_init(jax.random.PRNGKey(0), cfg)
    T_b, lens = 8, [0, 8, 3]                          # empty, full, partial
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(len(lens), T_b, cfg.n_vitals)),
                    jnp.float32)
    # zero the padded tails so the payload matches what the bucketer emits
    mask = (np.arange(T_b)[None, :, None]
            < np.asarray(lens)[:, None, None])
    x = x * jnp.asarray(mask, jnp.float32)
    got = E.vitals_encoder(p, cfg, {"x": x,
                                    "len": jnp.asarray(lens, jnp.int32)})
    for i, n in enumerate(lens):
        want = E.vitals_encoder(p, cfg, x[i:i + 1, :n])
        np.testing.assert_allclose(got[i:i + 1], want, atol=1e-6)


@pytest.mark.parametrize("kind", ["rnn", "gru", "lstm"])
def test_length_zero_row_is_initial_state(kind):
    cfg = tiny(vitals_encoder=kind)
    p = E.vitals_encoder_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 4, cfg.n_vitals)),
                    jnp.float32)
    out = E.vitals_encoder(p, cfg, {"x": x, "len": jnp.zeros((1,), jnp.int32)})
    np.testing.assert_allclose(out, jnp.zeros_like(out), atol=0)


def test_bucketed_text_encoder_equals_unpadded():
    """Padding text to its bucket must not move F_T (key mask + masked
    mean-pool): encoder(bucketed) == encoder(raw)."""
    cfg = tiny()
    p = E.text_encoder_init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        1, cfg.vocab_size, (2, 5)), jnp.int32)
    want = E.text_encoder(p, cfg, toks)
    got = E.text_encoder(p, cfg, Bucketer().fit("text", toks))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_pad_axis_crop_directions():
    x = jnp.arange(8).reshape(1, 8)
    np.testing.assert_array_equal(np.asarray(pad_axis(x, 3, axis=1,
                                                      keep="tail"))[0],
                                  [5, 6, 7])
    np.testing.assert_array_equal(np.asarray(pad_axis(x, 3, axis=1,
                                                      keep="head"))[0],
                                  [0, 1, 2])
