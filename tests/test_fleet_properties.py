"""Property tests (hypothesis) for the fleet workload generator.

Four exact claims:

  * **seeded determinism** — for ANY ``(rate, horizon, seed, process,
    time_scale)``, two calls to ``generate_workload`` produce the
    identical session stream, event for event;
  * **arrival-process sanity** — Poisson and diurnal arrival instants
    are strictly inside ``[0, horizon)``, sorted, and the diurnal
    envelope never escapes ``[base*(1-amp), base*(1+amp)]`` for any
    phase/period;
  * **per-event times are well-formed** — every session's per-modality
    arrival sequence is non-negative and non-decreasing (streams are
    exponential-gap cumulative sums), under any scenario and scale;
  * **time_scale is a pure intra-session dilation** — session start
    instants and event structure are invariant; only relative event
    times scale, exactly linearly.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.fleet import (diurnal_rate, diurnal_times, generate_workload,
                         merge_sessions, poisson_times)

SETTINGS = dict(max_examples=40, deadline=None)

rates = st.floats(min_value=0.2, max_value=30.0, allow_nan=False)
horizons = st.floats(min_value=0.1, max_value=20.0, allow_nan=False)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _flatten(sessions):
    return [(s.sid, s.t_start, s.scenario,
             tuple((e.index, e.modality, e.arrival_time)
                   for e in s.events))
            for s in sessions]


@settings(**SETTINGS)
@given(rate=rates, horizon=horizons, seed=seeds,
       process=st.sampled_from(["poisson", "diurnal"]),
       time_scale=st.floats(min_value=0.01, max_value=2.0))
def test_workload_is_a_pure_function_of_its_seed(rate, horizon, seed,
                                                 process, time_scale):
    kw = dict(seed=seed, process=process, time_scale=time_scale)
    a = generate_workload(rate, horizon, **kw)
    b = generate_workload(rate, horizon, **kw)
    assert _flatten(a) == _flatten(b)


@settings(**SETTINGS)
@given(rate=rates, horizon=horizons, seed=seeds)
def test_poisson_times_sorted_inside_horizon(rate, horizon, seed):
    ts = poisson_times(rate, horizon, seed)
    assert ts == sorted(ts)
    assert all(0.0 <= t < horizon for t in ts)


@settings(**SETTINGS)
@given(base=rates, amp=st.floats(min_value=0.0, max_value=0.99),
       period=st.floats(min_value=1.0, max_value=1e5),
       phase=st.floats(min_value=-1e4, max_value=1e4),
       t=st.floats(min_value=0.0, max_value=1e5))
def test_diurnal_rate_never_escapes_envelope(base, amp, period, phase, t):
    r = diurnal_rate(t, base, amp=amp, period=period, phase=phase)
    assert base * (1 - amp) - 1e-9 <= r <= base * (1 + amp) + 1e-9


@settings(**SETTINGS)
@given(base=rates, horizon=horizons, seed=seeds,
       amp=st.floats(min_value=0.0, max_value=0.9))
def test_diurnal_times_sorted_inside_horizon(base, horizon, seed, amp):
    ts = diurnal_times(base, horizon, seed, amp=amp, period=60.0)
    assert ts == sorted(ts)
    assert all(0.0 <= t < horizon for t in ts)


@settings(**SETTINGS)
@given(rate=rates, seed=seeds,
       time_scale=st.floats(min_value=0.01, max_value=2.0))
def test_per_event_times_nonnegative_and_merged_order(rate, seed,
                                                      time_scale):
    sessions = generate_workload(rate, 5.0, seed=seed,
                                 time_scale=time_scale)
    for s in sessions:
        per_mod = {}
        for e in s.events:
            assert e.arrival_time >= 0.0
            per_mod.setdefault(e.modality, []).append(e.arrival_time)
        for ts in per_mod.values():
            assert ts == sorted(ts)
    keys = [(t, sid) for t, sid, _ in merge_sessions(sessions)]
    assert keys == sorted(keys)


@settings(**SETTINGS)
@given(rate=rates, seed=seeds,
       scale=st.floats(min_value=0.05, max_value=0.95))
def test_time_scale_is_linear_intra_session_dilation(rate, seed, scale):
    ref = generate_workload(rate, 5.0, seed=seed, time_scale=1.0)
    got = generate_workload(rate, 5.0, seed=seed, time_scale=scale)
    assert len(ref) == len(got)
    for s1, s2 in zip(ref, got):
        assert s2.t_start == s1.t_start
        assert [e.modality for e in s2.events] == \
            [e.modality for e in s1.events]
        for e1, e2 in zip(s1.events, s2.events):
            assert e2.arrival_time == pytest.approx(
                scale * e1.arrival_time, rel=1e-9, abs=1e-12)
