"""Fault-injection scenario tier: crash -> heartbeat-detected failover
-> tier restart/rejoin (replica re-warm from the glass-side versioned
cache) -> re-crash, scripted over the N-tier engine.

The load-bearing claims (ISSUE 5):
  * outputs match the monolithic ``SplitModel.full`` / subset
    ``partial_forward`` at EVERY event, through both crashes and the
    rejoin (placement changes the clock, never the math);
  * the <=1-step cache-staleness invariant holds across the rejoin;
  * ``fallback``/``rejoin``/``evicted`` counters are exact;
  * after the dead tier rejoins, it is actually RE-SELECTED when it is
    the fastest candidate, and mid-outage traffic fails over to the
    next-best surviving tier (the phone), not all the way to glass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, ProfileTable, emsnet_zoo,
                        nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.models import emsnet as E
from repro.serving.api import build_engine

ALL = ("text", "vitals", "scene")
TIERS = ("glass", "ph1", "edge64x")
BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _engine(splits, params, **kw):
    kw.setdefault("max_history", None)
    return build_engine(
        splits, params, kw.pop("spec", "tiered"), share_encoders=True,
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(5.0)),
        tiers=TIERS,
        tier_traces={"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))},
        **kw)


def _assert_parity(rec, shared, cfg, payloads, observed):
    """Every emission equals the reference forward over its observed
    subset — finals bit-equal to the full fused forward."""
    assert rec.outputs is not None
    if set(observed) == set(ALL):
        assert rec.kind == "final"
        want = E.forward(shared, cfg, payloads)
    else:
        assert rec.kind == "partial"
        want = E.partial_forward(shared, cfg, payloads, observed)
    for k in want:
        np.testing.assert_allclose(rec.outputs[k], want[k], atol=1e-5)


def test_crash_failover_rejoin_recrash_scenario(zoo_models):
    """The full scripted lifecycle on one engine: healthy -> crash
    mid-flight -> heartbeat-detected glass fallback -> phone takes the
    outage traffic -> edge restarts, re-warms its replica, and is
    re-selected -> second crash -> second failover."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(2.1, "edge64x", rejoin_at=8.0)

    script = [
        # (modality, t_arrival, expected enc tier, fallback?)
        ("text", 0.0, "edge64x", False),    # healthy: fastest tier wins
        ("vitals", 1.0, "edge64x", False),  # completes before the crash
        # dispatched at 2.0, dies in flight at 2.1 -> stalls until the
        # missed heartbeat at 3.0, re-runs everything on glass
        ("scene", 2.0, "glass", True),
        ("vitals", 4.0, "ph1", False),      # outage: next-best, NOT glass
        ("vitals", 9.0, "edge64x", False),  # rejoined and re-selected
    ]
    observed = []
    for i, (m, t, tier, fb) in enumerate(script):
        if m not in observed:
            observed.append(m)
        rec = eng.submit("s0", Event(i, m, t), payloads[m])
        assert (rec.enc_tier, rec.fallback) == (tier, fb), (i, m)
        _assert_parity(rec, shared, cfg, payloads, observed)
    recs = eng.sessions["s0"].records

    # detection stalled the fallback until the first missed heartbeat
    assert recs[2].detect_s == pytest.approx(1.0)
    assert recs[2].t_start >= 3.0
    # exact counters after one crash + one rejoin
    assert eng.fallback_count == 1 and eng.rejoin_count == 1
    assert not eng._faults["edge64x"].dead

    # the rejoin re-warmed the replica from the glass-side versioned
    # cache: the warm shipment went over the glass->edge64x link and
    # the replica's version map covers every live cache entry
    versions = eng._replica_versions["edge64x"]
    for (key, m), e in eng.cache.entries():
        assert versions[(key, m)] == e.version
    assert eng.fabric.channel("glass", "edge64x").bytes_sent > 0

    # <=1-step staleness invariant holds across the rejoin
    st = eng.sessions["s0"]
    for (key, m), e in eng.cache.entries():
        assert st.input_step[m] - e.step <= 1

    # ---- re-crash the rejoined tier: second failover, exact counters
    eng.inject_crash(10.2, "edge64x")
    rec = eng.submit("s0", Event(5, "scene", 10.0), payloads["scene"])
    assert rec.fallback and rec.enc_tier == "glass"
    assert rec.detect_s == pytest.approx(1.0)      # detected at 11.0
    _assert_parity(rec, shared, cfg, payloads, ALL)
    rec = eng.submit("s0", Event(6, "vitals", 11.5), payloads["vitals"])
    assert rec.enc_tier == "ph1" and not rec.fallback
    _assert_parity(rec, shared, cfg, payloads, ALL)

    assert eng.fallback_count == 2 and eng.rejoin_count == 1
    assert eng.placement_counts() == {"glass": 2, "ph1": 2,
                                      "edge64x": 3, "fallbacks": 2}
    assert eng.tail_placement_counts() == {"glass": 2, "ph1": 2,
                                           "edge64x": 3}


def test_eviction_drops_every_tier_replica(zoo_models):
    """Cross-incident eviction under the session cap forgets the evicted
    session on EVERY tier's replica version map, and the evicted counter
    is exact."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, spec="stream+tiered", max_sessions=1)
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert any(k[0] == "s0" for vers in eng._replica_versions.values()
               for k in vers)
    eng.submit("s1", Event(0, "text", 20.0), payloads["text"])
    assert eng.evicted_count == 1 and set(eng.sessions) == {"s1"}
    assert not any(k[0] == "s0" for vers in eng._replica_versions.values()
                   for k in vers)
    assert ("s0", "text") not in eng.cache


def test_rejoined_tier_wins_only_when_fastest(zoo_models):
    """Rejoin restores eligibility, not priority: with the restarted
    tier forced SLOW (deep queue via a busy clock), the phone keeps the
    traffic — re-selection is a cost decision, not a flag flip."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(0.5, "edge64x", rejoin_at=2.0)
    rec = eng.submit("s0", Event(0, "text", 0.4), payloads["text"])
    assert rec.fallback                       # caught in flight
    # rejoin happens lazily at the next decision after t=2.0
    rec = eng.submit("s0", Event(1, "vitals", 4.0), payloads["vitals"])
    assert rec.enc_tier == "edge64x" and eng.rejoin_count == 1
    # pile simulated work onto the rejoined tier: contention-aware
    # decisions route around the queue
    eng.hosts["edge64x"].free_at = 1e6
    rec = eng.submit("s0", Event(2, "vitals", 5.0), payloads["vitals"])
    assert rec.enc_tier == "ph1"


def test_crash_before_any_traffic_then_rejoin(zoo_models):
    """A tier that dies and rejoins before ever serving still re-warms
    correctly: the first post-rejoin arrival finds a warm replica only
    for what the glass cache holds (nothing), ships its own payload,
    and parity holds."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(0.1, "edge64x", rejoin_at=1.0)
    rec = eng.submit("s0", Event(0, "text", 2.0), payloads["text"])
    assert rec.enc_tier == "edge64x" and not rec.fallback
    assert eng.rejoin_count == 1
    _assert_parity(rec, shared, cfg, payloads, ("text",))


def test_rejoin_requires_crash_and_future_time(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(5.0, "edge64x")
    with pytest.raises(ValueError):
        eng.schedule_rejoin(4.0, "edge64x")    # precedes the crash
    with pytest.raises(ValueError):
        eng.run_arrivals({}, lambda s, e: None, rejoin_at=1.0)  # no crash
