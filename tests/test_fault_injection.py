"""Fault-injection scenario tier: crash -> heartbeat-detected failover
-> tier restart/rejoin (replica re-warm from the glass-side versioned
cache) -> re-crash, scripted over the N-tier engine.

The load-bearing claims (ISSUE 5):
  * outputs match the monolithic ``SplitModel.full`` / subset
    ``partial_forward`` at EVERY event, through both crashes and the
    rejoin (placement changes the clock, never the math);
  * the <=1-step cache-staleness invariant holds across the rejoin;
  * ``fallback``/``rejoin``/``evicted`` counters are exact;
  * after the dead tier rejoins, it is actually RE-SELECTED when it is
    the fastest candidate, and mid-outage traffic fails over to the
    next-best surviving tier (the phone), not all the way to glass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, ProfileTable, emsnet_zoo,
                        nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.models import emsnet as E
from repro.serving.api import build_engine

ALL = ("text", "vitals", "scene")
TIERS = ("glass", "ph1", "edge64x")
BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _engine(splits, params, **kw):
    kw.setdefault("max_history", None)
    return build_engine(
        splits, params, kw.pop("spec", "tiered"), share_encoders=True,
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(5.0)),
        tiers=TIERS,
        tier_traces={"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))},
        **kw)


def _assert_parity(rec, shared, cfg, payloads, observed):
    """Every emission equals the reference forward over its observed
    subset — finals bit-equal to the full fused forward."""
    assert rec.outputs is not None
    if set(observed) == set(ALL):
        assert rec.kind == "final"
        want = E.forward(shared, cfg, payloads)
    else:
        assert rec.kind == "partial"
        want = E.partial_forward(shared, cfg, payloads, observed)
    for k in want:
        np.testing.assert_allclose(rec.outputs[k], want[k], atol=1e-5)


def test_crash_failover_rejoin_recrash_scenario(zoo_models):
    """The full scripted lifecycle on one engine: healthy -> crash
    mid-flight -> heartbeat-detected glass fallback -> phone takes the
    outage traffic -> edge restarts, re-warms its replica, and is
    re-selected -> second crash -> second failover."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(2.1, "edge64x", rejoin_at=8.0)

    script = [
        # (modality, t_arrival, expected enc tier, fallback?)
        ("text", 0.0, "edge64x", False),    # healthy: fastest tier wins
        ("vitals", 1.0, "edge64x", False),  # completes before the crash
        # dispatched at 2.0, dies in flight at 2.1 -> stalls until the
        # missed heartbeat at 3.0, re-runs everything on glass
        ("scene", 2.0, "glass", True),
        ("vitals", 4.0, "ph1", False),      # outage: next-best, NOT glass
        ("vitals", 9.0, "edge64x", False),  # rejoined and re-selected
    ]
    observed = []
    for i, (m, t, tier, fb) in enumerate(script):
        if m not in observed:
            observed.append(m)
        rec = eng.submit("s0", Event(i, m, t), payloads[m])
        assert (rec.enc_tier, rec.fallback) == (tier, fb), (i, m)
        _assert_parity(rec, shared, cfg, payloads, observed)
    recs = eng.sessions["s0"].records

    # detection stalled the fallback until the first missed heartbeat
    assert recs[2].detect_s == pytest.approx(1.0)
    assert recs[2].t_start >= 3.0
    # exact counters after one crash + one rejoin
    assert eng.fallback_count == 1 and eng.rejoin_count == 1
    assert not eng._faults["edge64x"].dead

    # the rejoin re-warmed the replica from the glass-side versioned
    # cache: the warm shipment went over the glass->edge64x link and
    # the replica's version map covers every live cache entry
    versions = eng._replica_versions["edge64x"]
    for (key, m), e in eng.cache.entries():
        assert versions[(key, m)] == e.version
    assert eng.fabric.channel("glass", "edge64x").bytes_sent > 0

    # <=1-step staleness invariant holds across the rejoin
    st = eng.sessions["s0"]
    for (key, m), e in eng.cache.entries():
        assert st.input_step[m] - e.step <= 1

    # ---- re-crash the rejoined tier: second failover, exact counters
    eng.inject_crash(10.2, "edge64x")
    rec = eng.submit("s0", Event(5, "scene", 10.0), payloads["scene"])
    assert rec.fallback and rec.enc_tier == "glass"
    assert rec.detect_s == pytest.approx(1.0)      # detected at 11.0
    _assert_parity(rec, shared, cfg, payloads, ALL)
    rec = eng.submit("s0", Event(6, "vitals", 11.5), payloads["vitals"])
    assert rec.enc_tier == "ph1" and not rec.fallback
    _assert_parity(rec, shared, cfg, payloads, ALL)

    assert eng.fallback_count == 2 and eng.rejoin_count == 1
    assert eng.placement_counts() == {"glass": 2, "ph1": 2,
                                      "edge64x": 3, "fallbacks": 2}
    assert eng.tail_placement_counts() == {"glass": 2, "ph1": 2,
                                           "edge64x": 3}


def test_eviction_drops_every_tier_replica(zoo_models):
    """Cross-incident eviction under the session cap forgets the evicted
    session on EVERY tier's replica version map, and the evicted counter
    is exact."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, spec="stream+tiered", max_sessions=1)
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert any(k[0] == "s0" for vers in eng._replica_versions.values()
               for k in vers)
    eng.submit("s1", Event(0, "text", 20.0), payloads["text"])
    assert eng.evicted_count == 1 and set(eng.sessions) == {"s1"}
    assert not any(k[0] == "s0" for vers in eng._replica_versions.values()
                   for k in vers)
    assert ("s0", "text") not in eng.cache


def test_rejoined_tier_wins_only_when_fastest(zoo_models):
    """Rejoin restores eligibility, not priority: with the restarted
    tier forced SLOW (deep queue via a busy clock), the phone keeps the
    traffic — re-selection is a cost decision, not a flag flip."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(0.5, "edge64x", rejoin_at=2.0)
    rec = eng.submit("s0", Event(0, "text", 0.4), payloads["text"])
    assert rec.fallback                       # caught in flight
    # rejoin happens lazily at the next decision after t=2.0
    rec = eng.submit("s0", Event(1, "vitals", 4.0), payloads["vitals"])
    assert rec.enc_tier == "edge64x" and eng.rejoin_count == 1
    # pile simulated work onto the rejoined tier: contention-aware
    # decisions route around the queue
    eng.hosts["edge64x"].free_at = 1e6
    rec = eng.submit("s0", Event(2, "vitals", 5.0), payloads["vitals"])
    assert rec.enc_tier == "ph1"


def test_crash_before_any_traffic_then_rejoin(zoo_models):
    """A tier that dies and rejoins before ever serving still re-warms
    correctly: the first post-rejoin arrival finds a warm replica only
    for what the glass cache holds (nothing), ships its own payload,
    and parity holds."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(0.1, "edge64x", rejoin_at=1.0)
    rec = eng.submit("s0", Event(0, "text", 2.0), payloads["text"])
    assert rec.enc_tier == "edge64x" and not rec.fallback
    assert eng.rejoin_count == 1
    _assert_parity(rec, shared, cfg, payloads, ("text",))


def test_rejoin_requires_crash_and_future_time(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    eng.inject_crash(5.0, "edge64x")
    with pytest.raises(ValueError):
        eng.schedule_rejoin(4.0, "edge64x")    # precedes the crash
    with pytest.raises(ValueError):
        eng.run_arrivals({}, lambda s, e: None, rejoin_at=1.0)  # no crash


# ======================================================================
# Mid-flight re-dispatch (ISSUE 6): a lost flight re-aims at the
# next-best SURVIVING remote instead of always re-running on glass
# ======================================================================

def test_redispatch_lost_flight_to_surviving_remote(zoo_models):
    """Same crash script as the failover scenario, but with
    ``redispatch`` on: the flight lost to the edge crash lands on the
    phone (the next-best survivor), pays only the detection stall plus
    the phone's round trip — NOT a glass re-run — and parity holds."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, redispatch=True)
    eng.inject_crash(2.1, "edge64x")
    for i, (m, t) in enumerate([("text", 0.0), ("vitals", 1.0)]):
        eng.submit("s0", Event(i, m, t), payloads[m])
    # dispatched at 2.0, edge dies at 2.1 -> detected at 3.0 -> the
    # SAME in-flight numerics re-aim at ph1
    rec = eng.submit("s0", Event(2, "scene", 2.0), payloads["scene"])
    assert rec.fallback and rec.enc_tier == "ph1"
    assert rec.detect_s == pytest.approx(1.0)
    assert rec.t_start >= 3.0
    _assert_parity(rec, shared, cfg, payloads, ALL)
    assert eng.redispatch_count == 1 and eng.fallback_count == 1
    # the re-dispatch target's replica was synced on the re-aimed
    # uplink: the phone now holds what it consumed and produced
    key = "s0"
    vers = eng._replica_versions["ph1"]
    for mm in ALL:
        assert (key, mm) in vers


def test_redispatch_falls_back_to_glass_when_no_survivor(zoo_models):
    """With EVERY remote dead by detection time, re-dispatch degrades
    to the glass re-run — never a dispatch at a dead box."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, redispatch=True)
    eng.inject_crash(2.05, "ph1")        # both detected at 3.0
    eng.inject_crash(2.1, "edge64x")
    rec = eng.submit("s0", Event(0, "scene", 2.0), payloads["scene"])
    assert rec.fallback and rec.enc_tier == "glass"
    _assert_parity(rec, shared, cfg, payloads, ("scene",))
    assert eng.redispatch_count == 0 and eng.fallback_count == 1


def test_redispatch_cascading_crash(zoo_models):
    """The re-dispatch target can itself die mid-flight: the flight
    cascades (edge -> phone -> glass), each hop paying its own
    detection, and the final emission still matches the reference."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, redispatch=True)
    eng.inject_crash(2.1, "edge64x")     # detected at 3.0
    eng.inject_crash(3.1, "ph1")         # kills the re-dispatched flight
    rec = eng.submit("s0", Event(0, "scene", 2.0), payloads["scene"])
    assert rec.fallback and rec.enc_tier == "glass"
    assert rec.t_start >= 4.0            # ph1's missed heartbeat
    _assert_parity(rec, shared, cfg, payloads, ("scene",))
    assert eng.redispatch_count == 1     # one re-aim, then glass
    assert eng.fallback_count == 1       # one arrival, one fallback


# ======================================================================
# Chaos schedules (ISSUE 6): seeded random crash/rejoin cycles
# ======================================================================

def test_chaos_schedule_generator_is_valid_and_reproducible():
    from repro.serving.chaos import FaultEvent, chaos_schedule, \
        validate_schedule
    a = chaos_schedule(11, horizon=30.0, tiers=("ph1", "edge64x"))
    b = chaos_schedule(11, horizon=30.0, tiers=("ph1", "edge64x"))
    assert a == b and len(a) >= 2
    assert a != chaos_schedule(12, horizon=30.0, tiers=("ph1", "edge64x"))
    for e in a:
        assert 0.0 < e.crash_at < 30.0
        assert e.rejoin_at is None or e.rejoin_at > e.crash_at
    # structural validation rejects overlap and crash-after-no-rejoin
    with pytest.raises(ValueError):
        validate_schedule([FaultEvent(1.0, "ph1", 3.0),
                           FaultEvent(2.0, "ph1", 4.0)])
    with pytest.raises(ValueError):
        validate_schedule([FaultEvent(1.0, "ph1", None),
                           FaultEvent(5.0, "ph1", 6.0)])
    with pytest.raises(ValueError):
        FaultEvent(2.0, "ph1", 2.0)


def test_chaos_cycles_replay_with_parity_and_staleness(zoo_models):
    """Repeated crash -> re-dispatch/fallback -> rejoin -> re-warm
    cycles from a seeded schedule: every emission stays bit-equal to
    the reference, the <=1-step staleness invariant holds throughout
    (every cache read asserts it live), commits stay duplicate-free,
    and the cycles actually replay (multiple rejoins observed)."""
    cfg, splits, shared, params, payloads = zoo_models
    from repro.core import async_episode, horizon
    from repro.serving.chaos import chaos_schedule
    eps = {f"s{i}": async_episode("text_first", seed=i) for i in range(2)}
    sched = chaos_schedule(5, horizon=horizon(eps),
                           tiers=("ph1", "edge64x"),
                           mean_up_s=1.5, mean_down_s=0.6,
                           min_up_s=0.4, min_down_s=0.3)
    assert len(sched) >= 4               # several cycles actually land
    eng = _engine(splits, params, redispatch=True)
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                     schedule=sched)
    observed = {}
    for r in eng.records:
        obs = observed.setdefault(r.sid, [])
        if r.modality not in obs:
            obs.append(r.modality)
        _assert_parity(r, shared, cfg, payloads, obs)
    assert eng.rejoin_count >= 2         # cycles, not a single outage
    ss = eng.speculation_stats()
    assert ss["duplicate_commits"] == 0 and ss["stale_commits"] == 0
    # end-state staleness: nothing in the cache lags its input > 1 step
    for sid, st in eng.sessions.items():
        for m, step in st.input_step.items():
            e = eng.cache.peek(sid, m)
            assert e is not None and step - e.step <= 1


def test_chaos_multiple_cycles_between_two_arrivals(zoo_models):
    """Several whole crash/rejoin cycles elapsing between two arrivals
    are all applied lazily at the next decision — the rejoin counter
    advances once per cycle, not once per arrival."""
    cfg, splits, shared, params, payloads = zoo_models
    from repro.serving.chaos import FaultEvent
    eng = _engine(splits, params)
    eng.inject_schedule([FaultEvent(1.0, "edge64x", 2.0),
                         FaultEvent(3.0, "edge64x", 4.0),
                         FaultEvent(5.0, "edge64x", 6.0)])
    rec = eng.submit("s0", Event(0, "text", 0.5), payloads["text"])
    assert rec.enc_tier == "edge64x" and not rec.fallback
    # next arrival is AFTER all three cycles have come and gone
    rec = eng.submit("s0", Event(1, "vitals", 20.0), payloads["vitals"])
    assert rec.enc_tier == "edge64x" and not rec.fallback
    assert eng.rejoin_count == 3
    assert not eng._faults["edge64x"].dead
    _assert_parity(rec, shared, cfg, payloads, ("text", "vitals"))
