import os

# Tests run against the single real CPU device — the 512-device flag is
# set ONLY by the dry-run entry point, never here.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def tiny_emsnet_cfg():
    from repro.configs.emsnet import tiny
    return tiny()


def reduced_cfg(arch, d_model=64):
    from repro.configs import get_config, reduced
    return reduced(get_config(arch), d_model=d_model)


@pytest.fixture(scope="session")
def tiny_dense_cfg():
    return reduced_cfg("mistral-nemo-12b")


@pytest.fixture(scope="session")
def tiny_moe_cfg():
    return reduced_cfg("olmoe-1b-7b")
