"""Ragged grouped flush path: one kernel per modality, one grouped tail.

Bit-parity tier (atol 0, ``np.array_equal``): the packing itself must
not change a single bit. The laws that make this possible on XLA CPU —
fixed flash-block reduction shapes (segment-masked kernel), block-
aligned row starts, a structurally identical scan body across the
natural/bucketed/ragged vitals paths, and exact zero contribution of
zero-filled fusion slices — are each pinned here at three levels:
kernel, encoder, and the full engine against the per-event unbucketed
reference (``core.engine.EMSServe``) on every LAG_SCENARIOS preset.

Regression tier: the three flush-accounting bugs that rode along —
duplicate-submission latency overwrites, the bucketer histogram
counting unserved modalities, and ``stack_bucketed`` silently dropping
mismatched dict keys.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.emsnet import tiny
from repro.core import (LAG_SCENARIOS, async_episode, emsnet_module,
                        emsnet_zoo, merge_arrivals, split)
from repro.core.bucketing import Bucketer, RaggedBatch, stack_bucketed
from repro.core.engine import EMSServe
from repro.core.episodes import Event
from repro.kernels.flash_attention import flash_attention
from repro.models import emsnet as E
from repro.serving.api import build_engine

ALL = ("text", "vitals", "scene")


# ======================================================================
# Fixtures: the bit-parity model config (segment flash on BOTH sides)
# ======================================================================

@pytest.fixture(scope="module")
def ragged_cfg():
    return tiny(text_encoder="microbert", use_flash_text=True,
                flash_segments=True)


@pytest.fixture(scope="module")
def ragged_zoo(ragged_cfg):
    cfg = ragged_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    return cfg, splits, shared, params


def _payload(cfg, sid, ev):
    r = np.random.default_rng(abs(hash((sid, ev.modality, ev.index)))
                              % 2**32)
    if ev.modality == "text":
        n = int(r.integers(1, cfg.max_text_len + 1))
        return jnp.asarray(r.integers(1, cfg.vocab_size, (1, n)), jnp.int32)
    if ev.modality == "vitals":
        n = int(r.integers(1, cfg.vitals_len + 1))
        return jnp.asarray(r.normal(size=(1, n, cfg.n_vitals)), jnp.float32)
    return jnp.asarray(r.integers(0, 2, (1, cfg.scene_dim)), jnp.float32)


def _lag_episodes(n_per_scenario=1):
    return {f"s{i}{j}": async_episode(name, seed=i * 7 + j,
                                      n_vitals=2, n_scene=2)
            for i, name in enumerate(sorted(LAG_SCENARIOS))
            for j in range(n_per_scenario)}


def _assert_bitwise(got, want, ctx=""):
    for k in want:
        g, w = np.asarray(got[k]), np.asarray(want[k])
        assert np.array_equal(g, w), \
            f"{ctx}[{k}]: max|diff|={np.abs(g - w).max()}"


# ======================================================================
# RaggedBatch builder invariants
# ======================================================================

def test_ragged_pack_text_layout():
    """Offsets start on align boundaries, segments tile the buffer
    exactly, surplus rows are (offset=total, length=0), and T/R are
    powers of two — including len-0 and len==cap rows."""
    rb = RaggedBatch(align=8, max_lengths={"text": 16})
    rng = np.random.default_rng(0)
    lens = [0, 16, 1, 5, 20]          # empty, ==cap, tiny, mid, > cap
    rows = [np.asarray(rng.integers(1, 99, (1, n)), np.int32)
            for n in lens]
    p = rb.pack("text", rows)
    offsets = np.asarray(p["offsets"])
    lengths = np.asarray(p["lengths"])
    toks = np.asarray(p["tokens"])
    T = toks.shape[1]
    assert T & (T - 1) == 0 and len(offsets) & (len(offsets) - 1) == 0
    assert len(offsets) == len(lengths) >= len(rows)
    # cropped-at-cap lengths; rows recoverable from the flat buffer
    for i, (r, n) in enumerate(zip(rows, lens)):
        want_n = min(n, 16)
        assert lengths[i] == want_n
        assert offsets[i] % 8 == 0
        got = toks[0, offsets[i]:offsets[i] + want_n]
        assert np.array_equal(got, r[0, :want_n])     # crop keeps head
    # surplus rows: zero-length at the packed extent -> segments tile
    # the buffer exactly (engine offset gathers stay in-bounds)
    total = max(int(o + -(-l // 8) * 8) for o, l in zip(offsets, lengths))
    for i in range(len(rows), len(offsets)):
        assert lengths[i] == 0 and offsets[i] == total <= T
    # row_ids: -1 exactly where no live row's tokens are
    seg = np.asarray(p["row_ids"])
    for i, (o, l) in enumerate(zip(offsets[:len(rows)],
                                   lengths[:len(rows)])):
        assert np.all(seg[o:o + l] == i)
    assert np.all(lengths >= 0) and rb.n_shapes() == 1


def test_ragged_pack_vitals_layout():
    """Vitals pack back-to-back (align 1) with reset flags on each
    row's first step; crop keeps the TAIL (latest vitals win)."""
    rb = RaggedBatch(max_lengths={"vitals": 8})
    rng = np.random.default_rng(1)
    lens = [3, 0, 8, 12, 1]
    rows = [rng.standard_normal((1, n, 2)).astype(np.float32)
            for n in lens]
    p = rb.pack("vitals", rows)
    x, reset = np.asarray(p["x"]), np.asarray(p["reset"])
    offsets, lengths = np.asarray(p["offsets"]), np.asarray(p["lengths"])
    o = 0
    for r, n in zip(rows, lens):
        keep = min(n, 8)
        assert np.array_equal(x[0, o:o + keep], r[0, n - keep:])
        if keep:
            assert reset[o, 0, 0]
            assert not reset[o + 1:o + keep, 0, 0].any()
        o += keep
    assert np.all(lengths[:len(rows)] == [min(n, 8) for n in lens])
    with pytest.raises(ValueError):
        rb.pack("scene", [np.zeros((1, 3), np.float32)])


# ======================================================================
# Kernel tier: segment-masked flash == per-row flash, bit for bit
# ======================================================================

def test_segment_flash_packed_equals_per_row():
    """Rows packed at block-aligned offsets through ONE segment-masked
    kernel call reproduce each per-row call bitwise: fixed (bq, bk)
    block shapes make the online-softmax reduction structure
    independent of how many rows share the buffer."""
    H, D, b = 2, 8, 8
    rng = np.random.default_rng(2)
    lens = [8, 3, 16, 1]
    offs = np.cumsum([0] + [-(-n // b) * b for n in lens])
    T = int(offs[-1])
    q = np.zeros((1, T, H, D), np.float32)      # flash layout (B, S, H, D)
    seg = np.full((T,), -1, np.int32)
    per_row = []
    for i, (n, o) in enumerate(zip(lens, offs[:-1])):
        x = rng.standard_normal((1, n, H, D)).astype(np.float32)
        q[:, o:o + n] = x
        seg[o:o + n] = i
        per_row.append(x)
    qj = jnp.asarray(q)
    packed = flash_attention(qj, qj, qj, causal=False,
                             segment_ids=jnp.asarray(seg)[None],
                             block_q=b, block_k=b, interpret=True)
    packed = np.asarray(packed)
    for i, (n, o, x) in enumerate(zip(lens, offs[:-1], per_row)):
        xp = np.zeros((1, -(-n // b) * b, H, D), np.float32)
        xp[:, :n] = x
        sr = np.full((xp.shape[1],), -1, np.int32)
        sr[:n] = 0
        solo = flash_attention(jnp.asarray(xp), jnp.asarray(xp),
                               jnp.asarray(xp), causal=False,
                               segment_ids=jnp.asarray(sr)[None],
                               block_q=b, block_k=b, interpret=True)
        assert np.array_equal(packed[:, o:o + n],
                              np.asarray(solo)[:, :n]), f"row {i}"


# ======================================================================
# Encoder tier: ragged == natural per-row, bit for bit
# ======================================================================

def test_text_encoder_ragged_bitwise(ragged_cfg):
    cfg = ragged_cfg
    p = E.init_params(cfg, jax.random.PRNGKey(0), ("text",))
    rng = np.random.default_rng(3)
    enc_nat = jax.jit(lambda t: E.encode(p, cfg, "text", t))
    enc_rag = jax.jit(lambda d: E.encode(p, cfg, "text", d))
    for trial in range(3):
        lens = ([0, cfg.max_text_len, 1, 5] if trial == 0 else
                [int(x) for x in rng.integers(0, cfg.max_text_len + 1,
                                              size=4)])
        rows = [np.asarray(rng.integers(1, cfg.vocab_size, (1, n)),
                           np.int32) for n in lens]
        rb = RaggedBatch(align=cfg.flash_block,
                         max_lengths={"text": cfg.max_text_len})
        out = np.asarray(enc_rag(rb.pack("text", rows)))
        for i, (r, n) in enumerate(zip(rows, lens)):
            want = (np.zeros((1, cfg.text_dims[1]), np.float32) if n == 0
                    else np.asarray(enc_nat(jnp.asarray(r))))
            assert np.array_equal(out[i:i + 1], want), \
                (trial, i, n, np.abs(out[i:i + 1] - want).max())


@pytest.mark.parametrize("kind", ["rnn", "gru", "lstm"])
def test_vitals_encoder_ragged_bitwise(kind):
    cfg = tiny(vitals_encoder=kind)
    p = E.init_params(cfg, jax.random.PRNGKey(1), ("vitals",))
    rng = np.random.default_rng(4)
    enc_nat = jax.jit(lambda v: E.encode(p, cfg, "vitals", v))
    enc_rag = jax.jit(lambda d: E.encode(p, cfg, "vitals", d))
    for trial in range(3):
        lens = ([0, cfg.vitals_len, 1] if trial == 0 else
                [int(x) for x in rng.integers(0, cfg.vitals_len + 1,
                                              size=4)])
        rows = [rng.standard_normal((1, n, cfg.n_vitals)).astype(np.float32)
                for n in lens]
        rb = RaggedBatch(max_lengths={"vitals": cfg.vitals_len})
        out = np.asarray(enc_rag(rb.pack("vitals", rows)))
        for i, (r, n) in enumerate(zip(rows, lens)):
            want = (np.zeros((1, cfg.vitals_hidden), np.float32) if n == 0
                    else np.asarray(enc_nat(jnp.asarray(r))))
            assert np.array_equal(out[i:i + 1], want), \
                (trial, i, n, np.abs(out[i:i + 1] - want).max())


# ======================================================================
# Tail tier: grouped full-head tail == sliced subset tails
# ======================================================================

def test_grouped_tail_equals_subset_tails(ragged_cfg):
    """For every non-empty modality subset, running the FULL fusion
    heads over features with zeros in the missing slices reproduces the
    subset-sliced heads bitwise at the same row count: a zero K-slice
    contributes exactly 0.0 to the fusion GEMM."""
    from itertools import combinations
    cfg = ragged_cfg
    params = E.init_params(cfg, jax.random.PRNGKey(0), ALL)
    dims = cfg.feature_dims
    rng = np.random.default_rng(5)
    R = 4
    feats = {m: jnp.asarray(rng.standard_normal((R, dims[m])),
                            jnp.float32) for m in ALL}
    for r in range(1, 4):
        for subset in combinations(ALL, r):
            ph = E.slice_heads(params["heads"], cfg, ALL, subset)
            want = E.fuse_and_heads(ph, feats, subset)
            filled = {m: (feats[m] if m in subset
                          else jnp.zeros((R, dims[m]), jnp.float32))
                      for m in ALL}
            got = E.fuse_and_heads(params["heads"], filled, ALL)
            _assert_bitwise(got, want, ctx=f"subset={subset}")


# ======================================================================
# Engine tier: ragged flush == the per-event unbucketed reference
# ======================================================================

def test_engine_ragged_matches_unbucketed_reference(ragged_zoo):
    """Ragged engine at the reference's own cadence (flush per event)
    == ``core.engine.EMSServe`` (per-event, natural shapes, no
    bucketing) bitwise on every LAG_SCENARIOS preset, with ONE packed
    encoder call and ONE grouped tail per flush."""
    cfg, splits, shared, params = ragged_zoo
    eps = _lag_episodes()
    refs = {sid: EMSServe(splits, params, cached=True, real_time=True,
                          session=sid) for sid in eps}
    eng = build_engine(splits, params, "batch+stream",
                       share_encoders=True, ragged=True,
                       deadline_s=0.0, max_history=None)
    checked = 0
    for _t, sid, ev in merge_arrivals(eps):
        p = _payload(cfg, sid, ev)
        rec = refs[sid].on_event(ev, p)
        rep = eng.submit(sid, ev, p)
        assert rep.n_encoder_calls <= 1 and rep.n_tail_calls <= 1
        if rec.recommendation is None:
            assert not rep.predictions
            continue
        (pred,) = rep.predictions
        assert pred.sid == sid
        _assert_bitwise(pred.outputs, rec.recommendation,
                        ctx=f"{sid}@{ev.index}")
        checked += 1
    assert checked > len(eps)


def test_engine_ragged_coalescing_bitwise_invariant(ragged_zoo):
    """Coalescing sessions into one packed flush changes NOTHING:
    deadline-coalesced ragged flushes emit bitwise the same predictions
    as flush-per-arrival ragged serving, while issuing O(modalities)+1
    kernels per flush and strictly less padded-FLOP than the bucketed
    baseline."""
    cfg, splits, shared, params = ragged_zoo
    eps = _lag_episodes(2)

    def run(sim_window, ragged):
        eng = build_engine(splits, params, "batch+stream",
                           share_encoders=True, ragged=ragged,
                           deadline_s=None, batch_bucket_min=2,
                           max_history=None)
        eng.run_arrivals(eps, lambda sid, ev: _payload(cfg, sid, ev),
                         sim_window=sim_window)
        return eng

    per_event = run(0.0, True)
    coalesced = run(3.0, True)
    bucketed = run(3.0, False)
    assert coalesced.flushes_total < per_event.flushes_total

    for f in coalesced.flushes:
        assert f.n_encoder_calls <= len(ALL)
        assert f.n_tail_calls <= 1
    # finals identical bit for bit; so is every prediction both
    # cadences emitted for the same (sid, step)
    a = {(p.sid, p.step): p for s in per_event.sessions.values()
         for p in s.predictions}
    b = {(p.sid, p.step): p for s in coalesced.sessions.values()
         for p in s.predictions}
    for sid in eps:
        pa = per_event.sessions[sid].predictions[-1]
        pb = coalesced.sessions[sid].predictions[-1]
        assert pa.kind == pb.kind == "final"
        _assert_bitwise(pb.outputs, pa.outputs, ctx=sid)
    common = set(a) & set(b)
    assert common
    for key in common:
        _assert_bitwise(b[key].outputs, a[key].outputs, ctx=str(key))

    # fewer dispatches, strictly less padding tax than bucketed
    assert sum(f.n_encoder_calls + f.n_tail_calls
               for f in coalesced.flushes) \
        < sum(f.n_encoder_calls + f.n_tail_calls for f in bucketed.flushes)
    frac_r = np.mean([f.padded_flop_frac for f in coalesced.flushes])
    frac_b = np.mean([f.padded_flop_frac for f in bucketed.flushes])
    assert frac_r < frac_b
    # the packed-shape histogram stays bounded (compile plateau)
    assert coalesced.ragged.n_shapes() <= 8


def test_engine_ragged_off_is_inert(ragged_zoo):
    """BatchPolicy.ragged defaults False: a default engine has no
    RaggedBatch and runs the legacy bucketed encode + per-model tails."""
    cfg, splits, shared, params = ragged_zoo
    eng = build_engine(splits, params, "batch+stream",
                       share_encoders=True, deadline_s=0.0)
    assert eng.ragged is None
    ev = Event(index=0, modality="scene", arrival_time=0.0)
    rep = eng.submit("s0", ev, _payload(cfg, "s0", ev))
    assert rep is not None and rep.n_events == 1


# ======================================================================
# Regressions: the three flush-accounting bugs
# ======================================================================

@pytest.fixture(scope="module")
def one_model(ragged_cfg):
    cfg = ragged_cfg
    mod = emsnet_module(cfg, ("scene",))
    splits = {"m": split(mod)}
    params = {"m": mod.init_fn(jax.random.PRNGKey(0))}
    return cfg, splits, params


def test_flush_latency_dedupes_duplicate_submission(one_model):
    """A duplicate (sid, idx) submission used to overwrite the first
    latency entry and double-count n_events; the report now keys by
    arrival and keeps the EARLIEST submit time."""
    cfg, splits, params = one_model
    clock = [10.0]
    eng = build_engine(splits, params, "batch",
                       time_fn=lambda: clock[0])
    ev = Event(index=0, modality="scene", arrival_time=0.0)
    x = jnp.zeros((1, cfg.scene_dim), jnp.float32)
    eng.submit("s0", ev, x)
    clock[0] = 11.0
    eng.submit("s0", ev, x)       # retransmit of the same arrival
    clock[0] = 12.0
    rep = eng.flush()
    assert rep.n_events == 1
    assert set(rep.latencies) == {("s0", 0)}
    assert rep.latencies[("s0", 0)] == pytest.approx(2.0)  # from t=10


def test_bucketer_histogram_counts_served_groups_only(one_model):
    """An arrival of a modality NO model consumes must not reach the
    bucketer: the histogram (and its compile/bucket stats) used to be
    inflated before the consumer filter ran."""
    cfg, splits, params = one_model        # consumes scene only
    bk = Bucketer(max_buckets={"vitals": 8})
    eng = build_engine(splits, params, "batch", bucketer=bk)
    eng.submit("s0", Event(index=0, modality="vitals", arrival_time=0.0),
               jnp.zeros((1, 5, cfg.n_vitals), jnp.float32))
    rep = eng.flush()
    assert rep.n_encoder_calls == 0
    assert bk.n_buckets() == 0 and bk.histogram == {}


def test_stack_bucketed_raises_on_key_mismatch():
    """Dict payloads with different key sets used to be silently merged
    using the first payload's keys; now a mismatch is an error."""
    a = {"x": jnp.zeros((1, 4)), "mask": jnp.ones((1, 4))}
    b = {"x": jnp.zeros((1, 4))}
    with pytest.raises(ValueError, match="key"):
        stack_bucketed([a, b], 2)
    # matching keys still stack fine
    out = stack_bucketed([a, {"x": jnp.ones((1, 4)),
                              "mask": jnp.zeros((1, 4))}], 4)
    assert out["x"].shape == (4, 4) and out["mask"].shape == (4, 4)
