"""Property-based tests (hypothesis) on the ragged packed layout.

Random length mixes — including empty rows and rows at the length cap —
must (a) produce offsets/lengths that tile the packed buffer exactly
and (b) leave every encoded row bit-identical (atol 0) to the per-row
natural-shape reference; the grouped fusion tail must equal the sliced
subset tail for every modality subset.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.configs.emsnet import tiny
from repro.core.bucketing import RaggedBatch
from repro.models import emsnet as E

SETTINGS = dict(max_examples=20, deadline=None)
TEXT_CAP = 16
VITALS_CAP = 8
ALL = ("text", "vitals", "scene")


@functools.lru_cache(maxsize=None)
def _text_setup():
    cfg = tiny(text_encoder="microbert", use_flash_text=True,
               flash_segments=True)
    p = E.init_params(cfg, jax.random.PRNGKey(0), ("text",))
    nat = jax.jit(lambda t: E.encode(p, cfg, "text", t))
    rag = jax.jit(lambda d: E.encode(p, cfg, "text", d))
    return cfg, nat, rag


@functools.lru_cache(maxsize=None)
def _vitals_setup(kind):
    cfg = tiny(vitals_encoder=kind)
    p = E.init_params(cfg, jax.random.PRNGKey(1), ("vitals",))
    nat = jax.jit(lambda v: E.encode(p, cfg, "vitals", v))
    rag = jax.jit(lambda d: E.encode(p, cfg, "vitals", d))
    return cfg, nat, rag


lens_strategy = st.lists(
    st.one_of(st.just(0), st.just(TEXT_CAP),
              st.integers(0, TEXT_CAP)),
    min_size=1, max_size=4)


@settings(**SETTINGS)
@given(lens_strategy, st.integers(0, 2**31 - 1))
def test_text_pack_tiles_buffer_exactly(lens, seed):
    """Row intervals are disjoint, align-started, in-bounds; surplus
    rows sit at the packed extent with length 0; row_ids mark exactly
    the live non-PAD tokens."""
    rng = np.random.default_rng(seed)
    rows = [np.asarray(rng.integers(1, 99, (1, n)), np.int32)
            for n in lens]
    rb = RaggedBatch(align=8, max_lengths={"text": TEXT_CAP})
    p = rb.pack("text", rows)
    offsets = np.asarray(p["offsets"])
    lengths = np.asarray(p["lengths"])
    seg = np.asarray(p["row_ids"])
    T = np.asarray(p["tokens"]).shape[1]
    covered = np.zeros(T, bool)
    extent = 0
    for i, n in enumerate(lens):
        o, l = int(offsets[i]), int(lengths[i])
        assert l == min(n, TEXT_CAP) and o % 8 == 0
        span = -(-l // 8) * 8
        assert o + span <= T
        assert not covered[o:o + span].any()        # disjoint
        covered[o:o + span] = True
        assert np.all(seg[o:o + l] == i)
        extent = max(extent, o + span)
    # surplus rows tile the remainder as zero-length at the extent
    for i in range(len(lens), len(offsets)):
        assert int(lengths[i]) == 0 and int(offsets[i]) == extent
    assert np.all(seg[~covered] == -1)
    assert not (T & (T - 1)) and not (len(offsets) & (len(offsets) - 1))


@settings(**SETTINGS)
@given(lens_strategy, st.integers(0, 2**31 - 1))
def test_text_ragged_rows_bitwise_equal_natural(lens, seed):
    cfg, nat, rag = _text_setup()
    rng = np.random.default_rng(seed)
    rows = [np.asarray(rng.integers(1, cfg.vocab_size, (1, n)), np.int32)
            for n in lens]
    rb = RaggedBatch(align=cfg.flash_block,
                     max_lengths={"text": cfg.max_text_len})
    out = np.asarray(rag(rb.pack("text", rows)))
    for i, (r, n) in enumerate(zip(rows, lens)):
        want = (np.zeros((1, cfg.text_dims[1]), np.float32) if n == 0
                else np.asarray(nat(jnp.asarray(r))))
        assert np.array_equal(out[i:i + 1], want), (i, n)


@settings(**SETTINGS)
@given(st.sampled_from(["rnn", "gru", "lstm"]),
       st.lists(st.integers(0, VITALS_CAP), min_size=1, max_size=3),
       st.integers(0, 2**31 - 1))
def test_vitals_ragged_rows_bitwise_equal_natural(kind, lens, seed):
    cfg, nat, rag = _vitals_setup(kind)
    rng = np.random.default_rng(seed)
    rows = [rng.standard_normal((1, n, cfg.n_vitals)).astype(np.float32)
            for n in lens]
    rb = RaggedBatch(max_lengths={"vitals": cfg.vitals_len})
    out = np.asarray(rag(rb.pack("vitals", rows)))
    for i, (r, n) in enumerate(zip(rows, lens)):
        want = (np.zeros((1, cfg.vitals_hidden), np.float32) if n == 0
                else np.asarray(nat(jnp.asarray(r))))
        assert np.array_equal(out[i:i + 1], want), (i, n, kind)


@settings(**SETTINGS)
@given(st.sets(st.sampled_from(ALL), min_size=1).map(
           lambda s: tuple(m for m in ALL if m in s)),
       st.integers(1, 4), st.integers(0, 2**31 - 1))
def test_grouped_full_tail_equals_sliced_subset_tail(subset, R, seed):
    """Zero-filling the missing modality slices and running the FULL
    fusion heads == the subset-sliced heads, bit for bit, at every row
    count (the law the engine's ONE grouped tail call rests on)."""
    cfg = tiny()
    params = E.init_params(cfg, jax.random.PRNGKey(2), ALL)
    dims = cfg.feature_dims
    rng = np.random.default_rng(seed)
    feats = {m: jnp.asarray(rng.standard_normal((R, dims[m])),
                            jnp.float32) for m in ALL}
    ph = E.slice_heads(params["heads"], cfg, ALL, subset)
    want = E.fuse_and_heads(ph, feats, subset)
    filled = {m: (feats[m] if m in subset
                  else jnp.zeros((R, dims[m]), jnp.float32))
              for m in ALL}
    got = E.fuse_and_heads(params["heads"], filled, ALL)
    for k in want:
        assert np.array_equal(np.asarray(got[k]), np.asarray(want[k])), \
            (subset, R, k)
