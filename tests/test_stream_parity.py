"""Parity tier: StreamingEMSServe vs the one-shot ``emsnet.forward``.

For every modality-arrival ordering, the streaming runtime's FINAL
prediction must match the one-shot full forward, and every INTERMEDIATE
prediction must match ``partial_forward`` restricted to the
arrived-modality subset — with zero encoder re-runs once a modality's
feature is cached."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.emsnet import tiny
from repro.core import Bucketer, emsnet_zoo, split
from repro.core.episodes import Event
from repro.models import emsnet as E
from repro.serving.stream_engine import StreamingEMSServe

ALL = ("text", "vitals", "scene")
ORDERINGS = list(itertools.permutations(ALL))
PAIRS = list(itertools.permutations(ALL, 2))


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _engine(cfg, splits, params, **kw):
    kw.setdefault("share_encoders", True)
    kw.setdefault("bucketer", Bucketer(max_buckets={
        "vitals": 8, "text": cfg.max_text_len}))
    return StreamingEMSServe(splits, params, **kw)


def _canon(arrived):
    return tuple(m for m in ALL if m in set(arrived))


def _assert_outputs_close(got, want, atol=1e-5):
    np.testing.assert_allclose(got["protocol_logits"],
                               want["protocol_logits"], atol=atol)
    np.testing.assert_allclose(got["medicine_logits"],
                               want["medicine_logits"], atol=atol)
    np.testing.assert_allclose(got["quantity"], want["quantity"], atol=atol)


# ------------------------------------------------- full-ordering parity

@pytest.mark.parametrize("order", ORDERINGS,
                         ids=["-".join(o) for o in ORDERINGS])
def test_every_arrival_order_matches_one_shot_forward(order, zoo_models):
    """Intermediate predictions == partial_forward on the arrived
    subset; the final prediction == the one-shot full forward; exactly
    ONE encoder call per arrival (no re-encodes on re-fusion)."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(cfg, splits, params)
    for i, m in enumerate(order):
        rep = eng.submit("s0", Event(i, m, float(i)), payloads[m])
        assert rep.n_encoder_calls == 1       # only the arriving modality
        assert len(rep.predictions) == 1
        pred = rep.predictions[0]
        subset = _canon(order[:i + 1])
        assert pred.modalities == subset
        assert pred.kind == ("final" if len(subset) == 3 else "partial")
        want = E.partial_forward(shared, cfg, payloads, subset)
        _assert_outputs_close(pred.outputs, want)
    final = eng.sessions["s0"].predictions[-1]
    want_full = E.forward(shared, cfg, payloads)
    _assert_outputs_close(final.outputs, want_full)
    # 3 arrivals -> exactly 3 encoder runs, and the re-fusions consumed
    # cached features (2 hits at the 2nd flush + 3 at the 3rd, plus the
    # newly-put entries read back)
    assert eng.encoder_calls_total() == 3
    assert eng.cache.hits >= 3


# ------------------------------------------------ 2-modality subsets

@pytest.mark.parametrize("pair", PAIRS, ids=["-".join(p) for p in PAIRS])
def test_two_modality_subsets_match_partial_forward(pair, zoo_models):
    """With only two modalities ever arriving (either order), the last
    prediction equals partial_forward on that pair and stays partial."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(cfg, splits, params)
    for i, m in enumerate(pair):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    pred = eng.sessions["s0"].predictions[-1]
    subset = _canon(pair)
    assert pred.modalities == subset and pred.kind == "partial"
    want = E.partial_forward(shared, cfg, payloads, subset)
    _assert_outputs_close(pred.outputs, want)
    assert eng.encoder_calls_total() == 2


# ------------------------------------------------- re-fusion economics

def test_refusion_never_reencodes_cached_modalities(zoo_models):
    """After warmup, re-arrivals of ONE modality re-encode only it; the
    other cached features are reused (hit counters) and the compile
    count stays flat (no new XLA programs)."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(cfg, splits, params)
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    warm_compiles = eng.compile_count()
    enc_before = eng.encoder_calls_total()
    hits_before = eng.cache.hits
    for i in range(3, 8):                       # vitals keep refreshing
        rep = eng.submit("s0", Event(i, "vitals", float(i)),
                         payloads["vitals"])
        assert rep.n_encoder_calls == 1         # vitals only
        assert rep.predictions[0].kind == "final"
    assert eng.encoder_calls_total() == enc_before + 5
    # each re-fusion read text+scene (and the fresh vitals) from cache
    assert eng.cache.hits >= hits_before + 10
    assert eng.compile_count() == warm_compiles


def test_multi_session_coalesced_matches_flush_per_event(zoo_models):
    """Deadline-coalesced flushes over 4 interleaved sessions produce
    the same final predictions as flush-per-arrival serving."""
    cfg, splits, shared, params, payloads = zoo_models
    orders = ORDERINGS[:4]

    def run(coalesce):
        eng = _engine(cfg, splits, params,
                      deadline_s=None, batch_bucket_min=2)
        for i in range(3):                       # tick i: one arrival each
            for s, order in enumerate(orders):
                eng.submit(f"s{s}", Event(i, order[i], float(i)),
                           payloads[order[i]])
                if not coalesce:
                    eng.flush()
            if coalesce:
                eng.flush()
        eng.drain()
        return {f"s{s}": eng.sessions[f"s{s}"].predictions[-1]
                for s in range(len(orders))}

    per_event, coalesced = run(False), run(True)
    for sid in per_event:
        assert coalesced[sid].kind == "final"
        _assert_outputs_close(coalesced[sid].outputs,
                              per_event[sid].outputs)
        want = E.forward(shared, cfg, payloads)
        _assert_outputs_close(coalesced[sid].outputs, want)


def test_deadline_policy_buffers_then_flushes(zoo_models):
    """deadline_s > 0 buffers submits until the oldest pending arrival
    exceeds the deadline on the injected clock; poll() also flushes."""
    cfg, splits, shared, params, payloads = zoo_models
    now = {"t": 0.0}
    eng = _engine(cfg, splits, params, deadline_s=1.0,
                  time_fn=lambda: now["t"])
    assert eng.submit("s0", Event(0, "text", 0.0), payloads["text"]) is None
    now["t"] = 0.5
    assert eng.poll() is None                   # not old enough yet
    now["t"] = 1.5
    rep = eng.submit("s0", Event(1, "vitals", 1.5), payloads["vitals"])
    assert rep is not None and rep.n_events == 2
    assert rep.predictions[0].modalities == ("text", "vitals")
    # nothing pending -> poll is a no-op
    assert eng.poll() is None


def test_history_bounded_but_totals_keep_counting(zoo_models):
    """max_history bounds the retained reports/predictions (they hold
    device arrays) while the lifetime counters keep the true totals."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(cfg, splits, params, max_history=2)
    for i in range(7):
        m = ALL[i % 3]
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert len(eng.flushes) == 2                       # window
    assert len(eng.sessions["s0"].predictions) == 2
    assert eng.flushes_total == 7                      # totals
    assert eng.encoder_calls_total() == 7
    assert eng.flushes[-1].flush_id == 6               # ids keep advancing
    # the retained tail is the newest data
    assert eng.sessions["s0"].predictions[-1].kind == "final"


def test_run_arrivals_sim_window_coalesces_like_deadline(zoo_models):
    """sim_window batches arrivals on episode time with the deadline
    rule; window 0 flushes per arrival and both yield the same finals."""
    from repro.core.episodes import merge_arrivals
    cfg, splits, shared, params, payloads = zoo_models
    eps = {"a": [Event(0, "text", 0.0), Event(1, "vitals", 0.2),
                 Event(2, "scene", 3.0)],
           "b": [Event(0, "vitals", 0.1), Event(1, "text", 2.9),
                 Event(2, "scene", 3.1)]}
    assert [sid for _, sid, _ in merge_arrivals(eps)] == \
        ["a", "b", "a", "b", "a", "b"]

    def finals(window):
        eng = _engine(cfg, splits, params, deadline_s=None,
                      batch_bucket_min=2)
        eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                         sim_window=window)
        return eng, {sid: eng.sessions[sid].predictions[-1] for sid in eps}

    per_arrival, fa = finals(0.0)
    coalesced, fb = finals(1.0)
    assert per_arrival.flushes_total == 6
    assert coalesced.flushes_total < 6                 # batching happened
    for sid in eps:
        assert fa[sid].kind == fb[sid].kind == "final"
        _assert_outputs_close(fb[sid].outputs, fa[sid].outputs)


def test_partial_forward_full_subset_equals_forward(zoo_models):
    """slice_heads over the full subset reassembles the exact heads."""
    cfg, splits, shared, params, payloads = zoo_models
    a = E.partial_forward(shared, cfg, payloads, ALL)
    b = E.forward(shared, cfg, payloads)
    for k in b:
        np.testing.assert_allclose(a[k], b[k], atol=0)


# ---------------------------------------------- cross-incident eviction

def test_idle_sessions_evicted_with_their_cache_entries(zoo_models):
    """A finalized incident that goes quiet leaves the session table AND
    the FeatureCache once idle_timeout_s passes; live sessions stay."""
    cfg, splits, shared, params, payloads = zoo_models
    now = {"t": 0.0}
    eng = _engine(cfg, splits, params, idle_timeout_s=5.0,
                  time_fn=lambda: now["t"])
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert eng.sessions["s0"].finalized
    cache_before = len(eng.cache)
    assert cache_before >= 3
    now["t"] = 3.0
    eng.submit("s1", Event(0, "text", 3.0), payloads["text"])
    assert eng.poll() is None and "s0" in eng.sessions   # not idle yet
    now["t"] = 8.5                    # s0 idle 8.5s, s1 idle 5.5s
    eng.poll()
    assert "s0" not in eng.sessions and "s1" not in eng.sessions
    assert eng.evicted_count == 2
    assert len(eng.cache) == 0        # every entry left with its session
    # an evicted responder id that speaks again is a fresh incident
    rep = eng.submit("s0", Event(0, "vitals", 9.0), payloads["vitals"])
    assert rep.predictions[0].modalities == ("vitals",)
    assert eng.sessions["s0"].step == 1


def test_lru_eviction_is_recency_primary_and_respects_cap(zoo_models):
    """Over max_sessions, the sweep evicts the least-recently-active
    evictable session — a finalized incident still streaming updates
    outlives an abandoned partial one (finalized only breaks ties)."""
    cfg, splits, shared, params, payloads = zoo_models
    now = {"t": 0.0}
    eng = _engine(cfg, splits, params, max_sessions=2,
                  time_fn=lambda: now["t"])
    for i, m in enumerate(ALL):      # s0: finalized, oldest activity
        now["t"] = float(i)
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    now["t"] = 10.0
    eng.submit("s1", Event(0, "text", 10.0), payloads["text"])   # partial
    now["t"] = 11.0
    eng.submit("s2", Event(0, "text", 11.0), payloads["text"])   # partial
    assert "s0" not in eng.sessions          # least recently active left
    assert set(eng.sessions) == {"s1", "s2"}
    assert eng.evicted_count == 1
    # an ACTIVE finalized session outlives an idle partial one: s1 goes
    # quiet while s2 keeps refreshing vitals, then s3 overflows the cap
    now["t"] = 20.0
    eng.submit("s2", Event(1, "vitals", 20.0), payloads["vitals"])
    now["t"] = 21.0
    eng.submit("s3", Event(0, "scene", 21.0), payloads["scene"])
    assert "s1" not in eng.sessions          # idle partial evicted
    assert set(eng.sessions) == {"s2", "s3"}


def test_eviction_never_drops_pending_or_dirty_work(zoo_models):
    """Sessions with buffered arrivals are not evictable even when the
    table is over the cap."""
    cfg, splits, shared, params, payloads = zoo_models
    now = {"t": 0.0}
    eng = _engine(cfg, splits, params, max_sessions=1, deadline_s=None,
                  time_fn=lambda: now["t"])
    eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    eng.submit("s1", Event(0, "vitals", 0.0), payloads["vitals"])
    assert eng.evict_sessions(now["t"]) == 0       # both have pending work
    assert set(eng.sessions) == {"s0", "s1"}
    eng.flush()            # drains the work; the flush's own sweep trims
    assert len(eng.sessions) == 1
    assert eng.evicted_count == 1
