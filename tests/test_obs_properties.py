"""Property tests (hypothesis) for the observability layer.

Three exact claims:

  * **rank-error bound** — for ANY sample multiset and any q, the
    DDSketch-style ``QuantileSketch`` answer is within ``rel_err``
    (relative) of the true sample quantile
    ``sorted(xs)[floor(q*(n-1))]`` (values under the zero-bucket
    epsilon report exactly 0.0);
  * **merge associativity** — ``(a+b)+c`` and ``a+(b+c)`` have
    IDENTICAL bucket state (merging adds integer bucket counts, so it
    is exact, unlike a float running sum);
  * **byte conservation** — under ANY random send/cancel schedule on a
    traced ``TransportChannel``, the trace-replay auditor accepts the
    trace against the channel's live stats and
    ``sent == delivered + cancelled`` holds in bytes and messages.
"""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.obs import Metrics, QuantileSketch, Tracer, audit_doc

SETTINGS = dict(max_examples=60, deadline=None)

values = st.floats(min_value=0.0, max_value=1e9, allow_nan=False,
                   allow_infinity=False)


def _true_quantile(xs, q):
    return sorted(xs)[int(math.floor(q * (len(xs) - 1)))]


@settings(**SETTINGS)
@given(xs=st.lists(values, min_size=1, max_size=300),
       q=st.floats(min_value=0.0, max_value=1.0),
       rel_err=st.sampled_from([0.005, 0.01, 0.05]))
def test_quantile_rank_error_bound(xs, q, rel_err):
    sk = QuantileSketch(rel_err=rel_err)
    for x in xs:
        sk.add(x)
    got = sk.quantile(q)
    true = _true_quantile(xs, q)
    if true < 1e-12:
        assert got <= true * (1 + rel_err) + 1e-12
    else:
        # 1e-9 absolute slack: float log/pow round-off, not sketch error
        assert abs(got - true) <= rel_err * true + 1e-9


@settings(**SETTINGS)
@given(parts=st.lists(st.lists(values, max_size=80), min_size=3,
                      max_size=3))
def test_merge_is_associative_and_commutative_on_state(parts):
    sks = []
    for xs in parts:
        sk = QuantileSketch(rel_err=0.01)
        for x in xs:
            sk.add(x)
        sks.append(sk)
    a, b, c = sks
    assert a.merge(b).merge(c).state() == a.merge(b.merge(c)).state()
    assert a.merge(b).state() == b.merge(a).state()
    total = a.merge(b).merge(c)
    assert total.count == sum(len(p) for p in parts)


@settings(**SETTINGS)
@given(schedule=st.lists(
    st.tuples(st.integers(min_value=1, max_value=100000),   # nbytes
              st.floats(min_value=0.001, max_value=0.2),    # inter-send gap
              st.one_of(st.none(),                          # no cancel ...
                        st.floats(min_value=0.0, max_value=0.999))),
    min_size=1, max_size=40))
def test_byte_conservation_under_random_cancel_schedules(schedule):
    from repro.core.offload import BandwidthTrace
    from repro.serving.transport import TransportChannel

    tr = Tracer()
    ch = TransportChannel(BandwidthTrace.static(5e4), name="g->e",
                          metrics=Metrics(), tracer=tr, max_history=None)
    t = 0.0
    for nbytes, gap, frac in schedule:
        t += gap
        d = ch.send(nbytes, t)
        if frac is not None:
            # strictly before the delivery instant -> must cancel
            assert ch.cancel(d.flight,
                             d.t_send + frac * (d.t_deliver - d.t_send))
    s = ch.stats()
    rep = audit_doc(tr.to_chrome({"transport": {ch.name: s}}))
    assert rep.ok, rep.violations
    delivered_b = sum(d.nbytes for d in ch.completed())
    delivered_m = len(ch.completed())
    assert delivered_b + s["cancelled_bytes"] == s["bytes"]
    assert delivered_m + s["cancelled_msgs"] == s["msgs"]
    assert rep.checks["flights"] == len(schedule)


@settings(**SETTINGS)
@given(i=st.integers(min_value=-200, max_value=400),
       rel_err=st.sampled_from([0.005, 0.01, 0.02, 0.05]))
def test_boundary_values_land_in_their_own_bucket(i, rel_err):
    """v = gamma^i is the TOP of bucket i — float slop in the log-ratio
    must never push it into bucket i+1 (regression: off-by-one broke
    the rel_err bound exactly at bucket boundaries)."""
    sk = QuantileSketch(rel_err=rel_err)
    v = sk._gamma ** i
    if not (v >= 1e-12 and math.isfinite(v)):
        return
    sk.add(v)
    assert sk._buckets == {i: 1}
