"""Distribution extensions: a2a expert parallelism, fsdp strategy specs,
loop-aware HLO analyzer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import hlo_analyzer as H
from repro.configs import get_config, reduced
from repro.distributed.sharding import Policy, abstract_mesh
from repro.models import moe as M
from repro.models import transformer as T


def _mesh8():
    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip("needs 8 host devices (run under dryrun env)")
    return Mesh(np.array(devs[:8]).reshape(2, 4), ("data", "model"))


# --------------------------------------------------------- a2a MoE

@pytest.fixture(scope="module")
def a2a_setup():
    cfg = dataclasses.replace(reduced(get_config("olmoe-1b-7b")),
                              n_experts=4, experts_per_tok=2,
                              capacity_factor=8.0)
    p = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                          jnp.float32)
    return cfg, p, x


def test_a2a_single_device_matches_ref(a2a_setup):
    """On a 1x1 mesh the a2a path degenerates to the dense reference."""
    cfg, p, x = a2a_setup
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    want = M.moe_ref(p, x, cfg)
    with mesh:
        y, aux = jax.jit(lambda p, x: M.moe_forward_a2a(
            p, x, cfg, mesh=mesh, token_axes=("data", "model"),
            expert_axes=("model",), pair_capacity_factor=8.0))(p, x)
    np.testing.assert_allclose(y, want, atol=1e-5)
    assert float(aux) > 0


def test_a2a_grads_flow(a2a_setup):
    cfg, p, x = a2a_setup
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))

    def loss(p):
        y, aux = M.moe_forward_a2a(p, x, cfg, mesh=mesh,
                                   token_axes=("data", "model"),
                                   expert_axes=("model",),
                                   pair_capacity_factor=8.0)
        return jnp.sum(y * y) + aux

    with mesh:
        g = jax.jit(jax.grad(loss))(p)
    assert float(jnp.abs(g["gate"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0


# ----------------------------------------------------- fsdp strategy

def test_fsdp_strategy_drops_tensor_parallel():
    cfg = get_config("mistral-nemo-12b")
    mesh = abstract_mesh((16, 16), ("data", "model"))
    pol = Policy(cfg, mesh, tuned=True, strategy="fsdp")
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    specs = pol.param_pspecs(aparams)
    flat = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    for s in flat:
        axes = [a for a in s if a is not None]
        # weights ZeRO-sharded over both axes together or replicated
        for a in axes:
            assert a == ("data", "model") or a in ("data", "model") and False, s
    assert pol.dp == ("data", "model")


def test_fsdp_strategy_keeps_expert_dim():
    cfg = get_config("deepseek-v3-671b")
    mesh = abstract_mesh((16, 16), ("data", "model"))
    pol = Policy(cfg, mesh, tuned=True, strategy="fsdp")
    assert pol.experts_2d
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    specs = pol.param_pspecs(aparams)
    gate = specs["groups"]["1"]["0"]["mlp"]["gate"]
    assert tuple(gate) == (None, ("data", "model"), None, None)


def test_tuned_head_aware_sharding():
    """kv=8 heads can't shard over model=16: tuned policy replicates."""
    cfg = get_config("mistral-nemo-12b")
    mesh = abstract_mesh((16, 16), ("data", "model"))
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    base = Policy(cfg, mesh).param_pspecs(aparams)
    tuned = Policy(cfg, mesh, tuned=True).param_pspecs(aparams)
    wk_base = base["groups"]["0"]["0"]["mixer"]["wk"]["w"]
    wk_tuned = tuned["groups"]["0"]["0"]["mixer"]["wk"]["w"]
    assert tuple(wk_base)[-1] == "model"       # flat-divisible, head-splitting
    assert tuple(wk_tuned)[-1] is None         # head-aware: replicated


# ------------------------------------------------------ HLO analyzer

HLO_SAMPLE = """
%body (param: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %param = (s32[], f32[4,8]{1,0}) parameter(0)
  %gte0 = s32[] get-tuple-element(%param), index=0
  %gte1 = f32[4,8]{1,0} get-tuple-element(%param), index=1
  %dot = f32[4,8]{1,0} dot(%gte1, %gte1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %ag = f32[4,8]{1,0} all-gather(%dot), channel_id=1, dimensions={1}
  ROOT %tuple = (s32[], f32[4,8]{1,0}) tuple(%gte0, %ag)
}

%cond (param.1: (s32[], f32[4,8])) -> pred[] {
  %param.1 = (s32[], f32[4,8]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%param.1), index=0
  %c = s32[] constant(7)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

ENTRY %main (p0: f32[4,8]) -> f32[4,8] {
  %p0 = f32[4,8]{1,0} parameter(0)
  %c0 = s32[] constant(0)
  %t = (s32[], f32[4,8]{1,0}) tuple(%c0, %p0)
  %w = (s32[], f32[4,8]{1,0}) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  %ar = f32[4,8]{1,0} all-reduce(%p0), channel_id=2
  ROOT %out = f32[4,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyzer_multiplies_loop_trips():
    t = H.analyze_hlo(HLO_SAMPLE)
    # dot: 2 * 4*8(result) * 8(contracted) = 512 flops x 7 trips
    assert t.flops == pytest.approx(512 * 7)
    ag = 4 * 8 * 4  # f32[4,8] bytes
    assert t.coll["all-gather"] == pytest.approx(ag * 7)
    assert t.coll["all-reduce"] == pytest.approx(ag)
    assert t.coll_count["all-gather"] == 7


def test_analyzer_trip_count_from_condition():
    hlo = HLO_SAMPLE.replace(', backend_config={"known_trip_count":{"n":"7"}}', "")
    t = H.analyze_hlo(hlo)
    assert t.coll_count["all-gather"] == 7   # from constant(7) in %cond


def test_analyzer_on_real_compiled_module():
    def f(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        c, _ = jax.lax.scan(body, x, w)
        return c.sum()
    L, B, D = 3, 4, 16
    comp = jax.jit(f).lower(jnp.zeros((L, D, D)), jnp.zeros((B, D))).compile()
    t = H.analyze_hlo(comp.as_text())
    assert t.flops == pytest.approx(L * 2 * B * D * D)
