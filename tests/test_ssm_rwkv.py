import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import rwkv as R
from repro.models import ssm as S


@pytest.fixture(scope="module")
def jamba_cfg():
    return reduced(get_config("jamba-v0.1-52b"), d_model=64)


@pytest.fixture(scope="module")
def rwkv_cfg():
    return reduced(get_config("rwkv6-1.6b"), d_model=64)


def test_mamba_decode_matches_forward(key, jamba_cfg):
    cfg = jamba_cfg
    p = S.mamba_init(key, cfg)
    B, T = 2, 9
    x = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    full, _ = S.mamba_forward(p, x, cfg)
    cache = S.mamba_cache_init(cfg, B, jnp.float32)
    outs = []
    for t in range(T):
        o, cache = S.mamba_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-4)


def test_mamba_state_threading(key, jamba_cfg):
    """forward(x) final state == decode-accumulated state."""
    cfg = jamba_cfg
    p = S.mamba_init(key, cfg)
    x = jax.random.normal(key, (1, 6, cfg.d_model), jnp.float32)
    _, (conv_f, h_f) = S.mamba_forward(p, x, cfg)
    cache = S.mamba_cache_init(cfg, 1, jnp.float32)
    for t in range(6):
        _, cache = S.mamba_decode(p, x[:, t:t + 1], cache, cfg)
    np.testing.assert_allclose(cache[1], h_f, atol=1e-4)
    np.testing.assert_allclose(cache[0], conv_f, atol=1e-5)


def test_mamba_causality(key, jamba_cfg):
    cfg = jamba_cfg
    p = S.mamba_init(key, cfg)
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32)
    y1, _ = S.mamba_forward(p, x, cfg)
    x2 = x.at[:, 5:].set(0.0)
    y2, _ = S.mamba_forward(p, x2, cfg)
    np.testing.assert_allclose(y1[:, :5], y2[:, :5], atol=1e-5)


def test_rwkv_tmix_decode_consistency(key, rwkv_cfg):
    cfg = rwkv_cfg
    p = R.rwkv6_init(key, cfg)
    B, T = 2, 7
    x = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    full, (lx, st) = R.rwkv6_tmix(p, x, cfg)
    state = None
    x_prev = None
    outs = []
    for t in range(T):
        o, (x_prev, state) = R.rwkv6_tmix(p, x[:, t:t + 1], cfg,
                                          state=state, x_prev=x_prev)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(dec, full, atol=1e-4)
    np.testing.assert_allclose(state, st, atol=1e-4)


def test_rwkv_cmix_shift(key, rwkv_cfg):
    cfg = rwkv_cfg
    p = R.cmix_init(key, cfg)
    x = jax.random.normal(key, (1, 5, cfg.d_model), jnp.float32)
    full, last = R.rwkv6_cmix(p, x, cfg)
    x_prev = None
    outs = []
    for t in range(5):
        o, x_prev = R.rwkv6_cmix(p, x[:, t:t + 1], cfg, x_prev=x_prev)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), full, atol=1e-5)
    np.testing.assert_allclose(x_prev, last, atol=1e-6)


def test_rwkv_decay_in_unit_interval(key, rwkv_cfg):
    cfg = rwkv_cfg
    p = R.rwkv6_init(key, cfg)
    x = jax.random.normal(key, (1, 4, cfg.d_model), jnp.float32) * 3
    r, k, v, w, g = R._tmix_projections(p, x, x, cfg)
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0
