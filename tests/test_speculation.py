"""Speculative dual placement tier (ISSUE 6): cancel-on-commit racing.

The load-bearing claims:
  * a deadline-pressured arrival races glass against the best remote,
    commits exactly ONE result (bit-equal to the reference — the racers
    share the numerics), and cancels the loser at the commit instant;
  * a cancelled flight never delivers and a released racer frees its
    host's clock — no phantom occupancy, no phantom bytes;
  * a remote crash mid-race is absorbed with NO detection stall (the
    glass racer is the hedge), counted as a crash save, not a fallback;
  * the commit protocol is duplicate-safe end to end: zero duplicate or
    stale cache commits under racing;
  * speculation defaults OFF — historical timelines never race.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, ProfileTable, emsnet_zoo,
                        nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.core.offload import SpeculationPolicy
from repro.models import emsnet as E
from repro.serving.api import build_engine

ALL = ("text", "vitals", "scene")
TIERS = ("glass", "ph1", "edge64x")
BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}
RACE_ALWAYS = SpeculationPolicy(deadline_s=0.0, margin_s=0.0)


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _engine(splits, params, *, bandwidth=5.0, **kw):
    kw.setdefault("max_history", None)
    kw.setdefault("tier_traces",
                  {"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))})
    kw.setdefault("trace", BandwidthTrace.static(nlos_bandwidth(bandwidth)))
    return build_engine(
        splits, params, "tiered", share_encoders=True,
        profile=ProfileTable(base=dict(BASE)), tiers=TIERS, **kw)


def _assert_parity(rec, shared, cfg, payloads, observed):
    assert rec.outputs is not None
    if set(observed) == set(ALL):
        want = E.forward(shared, cfg, payloads)
    else:
        want = E.partial_forward(shared, cfg, payloads, observed)
    for k in want:
        np.testing.assert_allclose(rec.outputs[k], want[k], atol=1e-5)


def test_speculation_off_by_default(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params)
    rec = eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert not rec.speculative and rec.race_winner is None
    assert not rec.decision.speculate
    assert eng.spec_count == 0


def test_race_commits_once_remote_wins(zoo_models):
    """With a fast remote, the remote racer wins: the record carries
    the remote timeline, the glass racer's un-run booking is released
    (no phantom occupancy), exactly one commit lands, and the output is
    bit-equal to the reference."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, speculation=RACE_ALWAYS)
    observed = []
    for i, m in enumerate(ALL):
        observed.append(m)
        rec = eng.submit("s0", Event(i, m, float(i)), payloads[m])
        assert rec.speculative and rec.race_winner == rec.tier
        assert rec.race_winner != "glass"      # fast remote wins here
        # the loser would have emitted strictly later
        assert rec.race_loser_emit > rec.t_emit
        _assert_parity(rec, shared, cfg, payloads, observed)
    assert eng.spec_count == 3
    assert sum(eng.spec_wins.values()) == 3 and eng.spec_wins["glass"] == 0
    ss = eng.speculation_stats()
    assert ss["duplicate_commits"] == 0 and ss["stale_commits"] == 0
    # the glass racer's booking was released: only the run-before-commit
    # stubs remain on the glass clock, less than 3 full racer bookings
    full_racer = (sum(eng.glass.time(f"enc:{m}") for m in ALL)
                  + 3 * eng.glass.time("tail"))
    assert eng.glass.busy_s < full_racer


def test_race_glass_wins_cancels_inflight_uplink(zoo_models):
    """Starve the wire: the payload cannot reach the remote before the
    glass racer finishes, so glass commits and the in-flight uplink is
    cancelled — it never delivers, the remote never computes, and the
    cancelled bytes are audited."""
    cfg, splits, shared, params, payloads = zoo_models
    # starve EVERY radio link (the phone's near-field tether included):
    # a couple hundred bytes/s means no payload lands before the glass
    # racer finishes
    eng = _engine(splits, params, trace=BandwidthTrace.static(200.0),
                  tier_traces={}, speculation=RACE_ALWAYS)
    rec = eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec.speculative and rec.race_winner == "glass"
    assert rec.race_loser_emit > rec.t_emit
    _assert_parity(rec, shared, cfg, payloads, ("text",))
    up = eng.fabric.channel("glass", rec.decision.best_remote)
    assert up.cancelled_msgs == 1 and up.cancelled_bytes > 0
    assert up.completed() == []                  # nothing ever delivered
    # the loser's host never computed: its clock is untouched
    assert eng.hosts[rec.decision.best_remote].busy_s == 0.0
    ss = eng.speculation_stats()
    assert ss["cancelled_msgs"] == 1 and ss["duplicate_commits"] == 0


def test_race_absorbs_crash_without_detection_stall(zoo_models):
    """The remote racer's tier dies mid-race: the glass racer commits
    at ITS OWN finish — no missed-heartbeat stall, no fallback — and
    the crash save is counted. The same arrival WITHOUT speculation
    pays the full detection stall, which is exactly the latency the
    hedge buys back."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, speculation=RACE_ALWAYS)
    eng.inject_crash(0.05, "edge64x")
    rec = eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec.speculative and rec.race_winner == "glass"
    assert not rec.fallback and rec.detect_s == 0.0
    _assert_parity(rec, shared, cfg, payloads, ("text",))
    assert eng.spec_crash_saves == 1 and eng.fallback_count == 0

    plain = _engine(splits, params)
    plain.inject_crash(0.05, "edge64x")
    rec2 = plain.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec2.fallback and rec2.detect_s > 0.0
    assert rec.latency_s < rec2.latency_s        # the hedge pays


def test_race_loser_late_result_cannot_regress_cache(zoo_models):
    """Direct duplicate-safety regression on the live engine: replaying
    a losing racer's commit (same step) and a crash-delayed straggler
    (older step) against the committed cache is refused — version and
    step stand, and the audit counters record both refusals."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, speculation=RACE_ALWAYS)
    eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    eng.submit("s0", Event(1, "text", 1.0), payloads["text"])
    e = eng.cache.peek("s0", "text")
    step, version = e.step, e.version
    # the losing racer's commit: same (session, modality, step)
    assert not eng.cache.put("s0", "text", e.feature, step=step,
                             tier="glass")
    # a straggler from before the second arrival: older step
    assert not eng.cache.put("s0", "text", e.feature, step=step - 1,
                             tier="edge64x")
    e2 = eng.cache.peek("s0", "text")
    assert (e2.step, e2.version) == (step, version)
    assert eng.cache.duplicate_commits == 1
    assert eng.cache.stale_commits == 1


def test_race_under_stream_glass_partials(zoo_models):
    """The stream composition does not double-serve a racing arrival:
    the glass racer IS the immediate answer, so no separate provisional
    partial is emitted for speculative events."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(
        splits, params, "stream+tiered", share_encoders=True,
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(5.0)),
        tiers=TIERS,
        tier_traces={"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))},
        speculation=RACE_ALWAYS, max_history=None)
    for i, m in enumerate(ALL):
        rec = eng.submit("s0", Event(i, m, float(i)), payloads[m])
        assert rec.speculative and rec.glass_partial is None
    assert eng.spec_count == 3
    assert eng.speculation_stats()["duplicate_commits"] == 0


def test_margin_thresholds_gate_racing(zoo_models):
    """Speculation triggers on thin margins only: a generous deadline
    never races, a hopeless one always does, and the decision carries
    the computed margin either way."""
    cfg, splits, shared, params, payloads = zoo_models
    lazy = _engine(splits, params,
                   speculation=SpeculationPolicy(deadline_s=1e3))
    rec = lazy.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert not rec.speculative and lazy.spec_count == 0
    assert rec.decision.margin_s > 0.0
    tight = _engine(splits, params,
                    speculation=SpeculationPolicy(deadline_s=1e-6,
                                                  margin_s=0.0))
    rec = tight.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec.speculative and tight.spec_count == 1
