"""Data pipeline invariants, EMSNet training, PMI, optimizer, losses,
checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic_nemsis as D
from repro.training import checkpoint as CKPT
from repro.training import emsnet_trainer as ET
from repro.training import losses as LS
from repro.training import optimizer as OPT


@pytest.fixture(scope="module")
def d1(tiny_emsnet_cfg):
    return D.generate(tiny_emsnet_cfg, 1200, seed=0)


# ------------------------------------------------------------------ data

def test_dataset_shapes(tiny_emsnet_cfg, d1):
    cfg = tiny_emsnet_cfg
    assert d1.text.shape == (1200, cfg.max_text_len)
    assert d1.vitals.shape == (1200, cfg.vitals_len, cfg.n_vitals)
    assert d1.scene.shape == (1200, 3)
    assert d1.protocol.max() < cfg.n_protocols
    assert d1.medicine.max() < cfg.n_medicines


def test_vitals_normalized_and_outlier_free(d1):
    """Post-pipeline vitals: z-scored over valid entries, no default-value
    artifacts (HR=500 etc. would be >> 5 sigma)."""
    v = d1.vitals
    assert np.abs(v).max() < 12.0
    nz = v[np.abs(v) > 0]
    assert abs(float(nz.mean())) < 0.3


def test_vitals_left_padded(d1):
    """Padding is at the START of the series (paper Appendix A)."""
    v = d1.vitals
    # find a sample with padding; all-zero prefix rows
    has_pad = np.abs(v).sum(-1) == 0
    for i in range(50):
        pad_rows = np.where(has_pad[i])[0]
        real_rows = np.where(~has_pad[i])[0]
        if len(pad_rows) and len(real_rows):
            assert pad_rows.max() < real_rows.min() or len(real_rows) == 0
            break


def test_quantity_labels_standardized(d1):
    q = d1.quantity
    assert abs(float(q.mean())) < 0.1
    assert 0.8 < float(q.std()) < 1.2


def test_split_ratios(d1):
    tr, va, te = D.splits(d1)
    assert len(tr) == 720 and len(va) == 240 and len(te) == 240
    # disjoint
    assert len(tr) + len(va) + len(te) == len(d1)


def test_loader_batches(tiny_emsnet_cfg, d1):
    ld = D.loader(d1, 32, modalities=("text", "vitals"))
    b = next(ld)
    assert b["text"].shape[0] == 32
    assert "scene" not in b
    assert set(b["labels"]) == {"protocol", "medicine", "quantity"}


# ---------------------------------------------------------------- losses

def test_cross_entropy_matches_manual(key):
    logits = jax.random.normal(key, (7, 5))
    labels = jnp.array([0, 1, 2, 3, 4, 0, 1])
    got = LS.cross_entropy(logits, labels)
    p = jax.nn.log_softmax(logits)
    want = -jnp.mean(p[jnp.arange(7), labels])
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_topk_accuracy():
    logits = jnp.array([[0.1, 0.9, 0.0], [0.8, 0.1, 0.1]])
    labels = jnp.array([1, 2])
    m = LS.topk_accuracy(logits, labels, ks=(1, 3))
    assert float(m["top1"]) == 0.5
    assert float(m["top3"]) == 1.0


def test_pearson_spearman_vs_numpy(rng):
    x = rng.normal(size=200).astype(np.float32)
    y = 0.7 * x + rng.normal(size=200).astype(np.float32) * 0.5
    got_p = float(LS.pearsonr(jnp.asarray(x), jnp.asarray(y)))
    want_p = float(np.corrcoef(x, y)[0, 1])
    assert got_p == pytest.approx(want_p, abs=1e-4)
    # spearman == pearson of ranks
    rx = np.argsort(np.argsort(x)).astype(np.float32)
    ry = np.argsort(np.argsort(y)).astype(np.float32)
    want_s = float(np.corrcoef(rx, ry)[0, 1])
    got_s = float(LS.spearmanr(jnp.asarray(x), jnp.asarray(y)))
    assert got_s == pytest.approx(want_s, abs=1e-3)


# ------------------------------------------------------------- optimizer

@pytest.mark.parametrize("name", ["adamw", "sgd", "adafactor"])
def test_optimizer_decreases_quadratic(name, key):
    _, init, update = OPT.make_optimizer(name, lr=0.1, weight_decay=0.0,
                                         grad_clip=100.0, warmup_steps=0,
                                         decay_steps=1000)
    params = {"w": jax.random.normal(key, (8, 4))}
    target = jnp.zeros((8, 4))
    state = init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(30):
        g = jax.grad(loss)(params)
        g = jax.tree.map(lambda a: a.astype(jnp.float32), g)
        params, state, _ = update(g, state, params)
    assert float(loss(params)) < 0.2 * l0


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = OPT.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(100.0 * np.sqrt(10), rel=1e-4)
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-4)


def test_schedule_warmup_and_decay():
    cfg = OPT.OptConfig(lr=1.0, warmup_steps=10, decay_steps=100,
                        min_lr_ratio=0.1)
    assert float(OPT.schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(OPT.schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(OPT.schedule(cfg, jnp.int32(100))) == pytest.approx(0.1)


# ------------------------------------------------------- EMSNet training

def test_training_reduces_loss(tiny_emsnet_cfg, d1):
    cfg = tiny_emsnet_cfg
    tr, _, _ = D.splits(d1)
    ld = D.loader(tr, 64, modalities=("text", "vitals"))
    _, losses = ET.train(cfg, ld, modalities=("text", "vitals"), steps=60)
    assert np.mean(losses[-10:]) < 0.7 * np.mean(losses[:10])


def test_multimodal_beats_unimodal_vitals(tiny_emsnet_cfg, d1):
    """Paper Table 3 direction: text+vitals >> vitals-only on protocol."""
    cfg = tiny_emsnet_cfg
    tr, _, te = D.splits(d1)
    ld2 = D.loader(tr, 64, modalities=("text", "vitals"))
    p2, _ = ET.train(cfg, ld2, modalities=("text", "vitals"), steps=120)
    m2 = ET.evaluate(p2, cfg, te, ("text", "vitals"))
    ldv = D.loader(tr, 64, modalities=("vitals",))
    pv, _ = ET.train(cfg, ldv, modalities=("vitals",), steps=120)
    mv = ET.evaluate(pv, cfg, te, ("vitals",))
    assert m2["protocol_top1"] > mv["protocol_top1"] + 0.15


def test_pmi_beats_scratch_on_small_d2(tiny_emsnet_cfg, d1):
    """Paper Table 4 direction: PMI fine-tuning > training from scratch
    when the 3-modal dataset is tiny."""
    cfg = tiny_emsnet_cfg
    tr, _, _ = D.splits(d1)
    ld2 = D.loader(tr, 64, modalities=("text", "vitals"))
    p2, _ = ET.train(cfg, ld2, modalities=("text", "vitals"), steps=120)

    d2 = D.generate(cfg, 300, seed=5, modal3=True)
    tr2, _, te2 = D.splits(d2)
    ld3 = D.loader(tr2, 32)
    p3, _ = ET.pmi_finetune(cfg, p2, ld3, steps=60)
    m_pmi = ET.evaluate(p3, cfg, te2, ("text", "vitals", "scene"))
    p3s, _ = ET.train(cfg, ld3, modalities=("text", "vitals", "scene"),
                      steps=60)
    m_scr = ET.evaluate(p3s, cfg, te2, ("text", "vitals", "scene"))
    assert m_pmi["protocol_top1"] >= m_scr["protocol_top1"]


def test_pmi_frozen_backbone_is_untouched(tiny_emsnet_cfg, d1):
    cfg = tiny_emsnet_cfg
    tr, _, _ = D.splits(d1)
    ld2 = D.loader(tr, 32, modalities=("text", "vitals"))
    p2, _ = ET.train(cfg, ld2, modalities=("text", "vitals"), steps=10)
    d2 = D.generate(cfg, 200, seed=5, modal3=True)
    tr2, _, _ = D.splits(d2)
    p3, _ = ET.pmi_finetune(cfg, p2, D.loader(tr2, 16), steps=10)
    for sub in ("text", "vitals"):
        same = jax.tree.map(lambda a, b: np.array_equal(a, b), p2[sub], p3[sub])
        assert all(jax.tree.leaves(same))


# ------------------------------------------------------------ checkpoint

def test_checkpoint_roundtrip(tmp_path, key, tiny_emsnet_cfg):
    from repro.models import emsnet as E
    params = E.init_params(tiny_emsnet_cfg, key, ("text", "vitals"))
    path = tmp_path / "ckpt.npz"
    CKPT.save(path, params, metadata={"note": "test"})
    restored = CKPT.restore(path, jax.tree.map(np.asarray, params))
    same = jax.tree.map(lambda a, b: np.array_equal(a, b), params, restored)
    assert all(jax.tree.leaves(same))
    assert CKPT.metadata(path)["note"] == "test"


def test_checkpoint_shape_mismatch_raises(tmp_path, key):
    CKPT.save(tmp_path / "c.npz", {"w": np.zeros((2, 2))})
    with pytest.raises(ValueError):
        CKPT.restore(tmp_path / "c.npz", {"w": np.zeros((3, 3))})
