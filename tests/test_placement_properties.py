"""Property tests (hypothesis) for the N-tier contention-aware
placement rule (``core.offload.MultiTierPolicy``).

The decision model is ``cost_k = Δt_k + queue_k + t_k(submodule)`` over
the local host and every usable remote, so its invariants are exact at
the estimate level (anything beyond them — per-message link latency,
in-order head-of-line blocking, replica-sync bytes — is transport
accounting the rule deliberately does not see):

  * the chosen placement never loses to all-local;
  * decisions are monotone in bandwidth (offloading is upward-closed)
    and in queue depth (a deeper queue never attracts work);
  * a tier with infinite queueing delay is never chosen;
  * per-submodule force pins exactly what it names.
"""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.offload import (BandwidthTrace, HeartbeatMonitor,
                                MultiTierPolicy, ProfileTable)

SETTINGS = dict(max_examples=50, deadline=None)

REMOTES = ("ph1", "edge4c", "edge64x")
LOCAL = "glass"

bw_st = st.floats(min_value=1e3, max_value=1e9)
t_st = st.floats(min_value=1e-4, max_value=10.0)
q_st = st.floats(min_value=0.0, max_value=30.0)
pay_st = st.integers(min_value=1, max_value=10_000_000)


def _policy(base_enc, base_tail, bws, **kw):
    prof = ProfileTable(base={"enc:m": base_enc, "tail": base_tail})
    monitors = {n: HeartbeatMonitor(BandwidthTrace.static(bw))
                for n, bw in bws.items()}
    return MultiTierPolicy(prof, monitors, local=LOCAL,
                           tier_of={LOCAL: LOCAL, **{n: n for n in bws}},
                           **kw)


@st.composite
def scenarios(draw, n_remotes=len(REMOTES)):
    remotes = REMOTES[:draw(st.integers(1, n_remotes))]
    bws = {n: draw(bw_st) for n in remotes}
    queues = {n: draw(q_st) for n in (LOCAL, *remotes)}
    return (draw(t_st), draw(t_st), bws, queues, draw(pay_st))


@given(scenarios())
@settings(**SETTINGS)
def test_chosen_placement_never_loses_to_all_local(sc):
    """argmin construction: the winner's estimated cost is <= the local
    host's for the SAME arrival — adaptive placement can only tie or
    beat all-local, up to the transport accounting the rule does not
    model."""
    base_enc, base_tail, bws, queues, payload = sc
    pol = _policy(base_enc, base_tail, bws)
    d = pol.decide("enc:m", payload, 0.0, queues=queues)
    assert d.estimates[d.tier].cost <= d.estimates[LOCAL].cost
    # and the decision is deterministic for identical inputs
    assert pol.decide("enc:m", payload, 0.0, queues=queues).tier == d.tier


@given(scenarios(), st.floats(min_value=1.0, max_value=1e4))
@settings(**SETTINGS)
def test_offloading_monotone_in_bandwidth(sc, scale):
    """Scaling every link's bandwidth UP never pulls work back to the
    local host: the offloaded set is upward-closed in bandwidth."""
    base_enc, base_tail, bws, queues, payload = sc
    d_lo = _policy(base_enc, base_tail, bws).decide(
        "enc:m", payload, 0.0, queues=queues)
    d_hi = _policy(base_enc, base_tail,
                   {n: bw * scale for n, bw in bws.items()}).decide(
        "enc:m", payload, 0.0, queues=queues)
    if d_lo.tier != LOCAL:
        assert d_hi.tier != LOCAL


@given(scenarios(), st.floats(min_value=1e-3, max_value=100.0))
@settings(**SETTINGS)
def test_decision_monotone_in_queue_depth(sc, extra):
    """Deepening a LOSER's queue never changes the winner; deepening
    the WINNER's queue past every alternative evicts it — queues repel
    work, never attract it."""
    base_enc, base_tail, bws, queues, payload = sc
    pol = _policy(base_enc, base_tail, bws)
    d = pol.decide("enc:m", payload, 0.0, queues=queues)
    for loser in d.estimates:
        if loser == d.tier:
            continue
        deeper = dict(queues)
        deeper[loser] = deeper.get(loser, 0.0) + extra
        assert pol.decide("enc:m", payload, 0.0,
                          queues=deeper).tier == d.tier
    if d.tier != LOCAL:
        worst = dict(queues)
        worst[d.tier] = max(e.cost for e in d.estimates.values()) + extra
        assert pol.decide("enc:m", payload, 0.0,
                          queues=worst).tier != d.tier


@given(scenarios())
@settings(**SETTINGS)
def test_infinite_queue_tier_never_chosen(sc):
    base_enc, base_tail, bws, queues, payload = sc
    pol = _policy(base_enc, base_tail, bws)
    for jammed in bws:
        q = dict(queues)
        q[jammed] = math.inf
        d = pol.decide("enc:m", payload, 0.0, queues=q)
        assert d.tier != jammed
        dt = pol.decide_tail(payload, payload, LOCAL, 0.0, queues=q)
        assert dt.tier != jammed


@given(scenarios())
@settings(**SETTINGS)
def test_unavailable_tier_never_chosen(sc):
    """A crashed tier (absent from ``available``) is not a candidate —
    the fault path's availability filter is honored by construction."""
    base_enc, base_tail, bws, queues, payload = sc
    pol = _policy(base_enc, base_tail, bws)
    dead = sorted(bws)[0]
    alive = [n for n in bws if n != dead]
    d = pol.decide("enc:m", payload, 0.0, queues=queues, available=alive)
    assert d.tier != dead
    assert dead not in d.estimates


@given(scenarios())
@settings(**SETTINGS)
def test_tail_placement_never_loses_to_local_tail(sc):
    base_enc, base_tail, bws, queues, payload = sc
    pol = _policy(base_enc, base_tail, bws)
    for enc_tier in (LOCAL, *bws):
        d = pol.decide_tail(payload, payload // 2, enc_tier, 0.0,
                            queues=queues)
        assert d.estimates[d.tier].cost <= d.estimates[LOCAL].cost


@given(scenarios())
@settings(**SETTINGS)
def test_per_submodule_force_pins_exactly_what_it_names(sc):
    base_enc, base_tail, bws, queues, payload = sc
    target = sorted(bws)[-1]
    pol = _policy(base_enc, base_tail, bws,
                  force={"enc:m": target, "tail": LOCAL})
    assert pol.decide("enc:m", payload, 0.0,
                      queues=queues).tier == target
    assert pol.decide_tail(payload, payload, target, 0.0,
                           queues=queues).tier == LOCAL
    # a forced-but-dead tier falls back to the local host
    assert pol.decide("enc:m", payload, 0.0, queues=queues,
                      available=[]).tier == LOCAL


def test_force_rejects_unknown_tier():
    with pytest.raises(ValueError):
        _policy(0.1, 0.01, {"ph1": 1e6}, force="warp9")
