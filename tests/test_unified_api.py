"""Unified session-engine API: spec parsing, cross-engine parity, and
policy composition.

Parity tier (ISSUE 4 acceptance): ``build_engine`` with each single
policy enabled reproduces the corresponding legacy engine's predictions
event-for-event on the LAG_SCENARIOS async episodes. Composition tier:
``batch+stream`` coalesces without changing finals, and
``stream+tiered`` serves on-glass provisional partials (matching
``partial_forward``) while the edge computes finals (matching
``SplitModel.full``), with the <=1-step cache-staleness invariant still
asserted live.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, Bucketer, LAG_SCENARIOS,
                        ProfileTable, async_episode, emsnet_module,
                        emsnet_zoo, merge_arrivals, nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.core.feature_cache import StalenessError
from repro.models import emsnet as E
from repro.serving.api import (Arrival, BatchPolicy, EngineSpec,
                               EMSServeEngine, PlacementPolicy,
                               StreamPolicy, build_engine, parse_spec)
from repro.serving.batch_engine import BatchedEMSServe
from repro.serving.stream_engine import StreamingEMSServe
from repro.serving.tiered_runtime import TieredEMSServe

ALL = ("text", "vitals", "scene")

BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}


def _lag_episodes(n_per_scenario=1, **kw):
    """One async episode per LAG_SCENARIOS preset (the ISSUE's parity
    workload): every preset's arrival ordering is exercised."""
    eps = {}
    for i, name in enumerate(sorted(LAG_SCENARIOS)):
        for j in range(n_per_scenario):
            eps[f"s{i}{j}"] = async_episode(name, seed=i * 7 + j,
                                            n_vitals=2, n_scene=2, **kw)
    return eps


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


@pytest.fixture(scope="module")
def indep_models(tiny_emsnet_cfg):
    """Independently-parameterized m1/m2/m3 (the batch engine regime)."""
    cfg = tiny_emsnet_cfg
    key = jax.random.PRNGKey(0)
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    rng = np.random.default_rng(1)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 9)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, params, payloads


def _assert_close(got, want, atol=1e-5):
    for k in want:
        np.testing.assert_allclose(got[k], want[k], atol=atol)


# ======================================================================
# Spec parsing / factory contract
# ======================================================================

def test_parse_spec_strings_and_aliases():
    es = parse_spec("batch+stream")
    assert es.batch is not None and es.stream is not None
    assert es.placement is None
    assert es.enabled() == ("batch", "stream")
    # aliases normalize
    es2 = parse_spec("batched+streaming")
    assert es2.enabled() == ("batch", "stream")
    # pre-built specs pass through
    es3 = EngineSpec(stream=StreamPolicy())
    assert parse_spec(es3) is es3


def test_parse_spec_dict_sections_and_overrides():
    es = parse_spec({"batch": {"max_coalesce": 32}, "stream": True,
                     "share_encoders": True},
                    deadline_s=0.25, batch_bucket_min=4)
    assert es.batch.max_coalesce == 32
    assert es.batch.batch_bucket_min == 4          # routed override
    assert es.stream.deadline_s == 0.25
    assert es.share_encoders is True
    # batch-machinery knobs are addressable without a batch token (the
    # coalescing machinery exists in every flush-mode engine)
    es2 = parse_spec("stream", bucketer=None, batch_bucket_min=2)
    assert es2.batch is not None and es2.batch.batch_bucket_min == 2


def test_parse_spec_rejects_bad_input():
    with pytest.raises(ValueError):
        parse_spec("batch+warp")                   # unknown token
    with pytest.raises(ValueError):
        parse_spec("")                             # empty
    with pytest.raises(ValueError):
        parse_spec("tiered")                       # no profile/trace
    with pytest.raises(ValueError):
        parse_spec("batch", deadline_s=1.0)        # stream knob, no stream
    with pytest.raises(ValueError):
        parse_spec({"stream": {"warp_factor": 9}})  # unknown option
    with pytest.raises(TypeError):
        parse_spec(42)


def test_build_engine_wires_policies(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(splits, params, "batch+stream", share_encoders=True,
                       deadline_s=None, batch_bucket_min=2)
    assert isinstance(eng, EMSServeEngine) and not eng.tiered
    assert eng.deadline_s is None and eng.batch_bucket_min == 2
    assert eng.bucketer is not None                # derived by default
    tiered = build_engine(splits, params, "tiered", share_encoders=True,
                          profile=ProfileTable(base=dict(BASE)),
                          trace=BandwidthTrace.static(nlos_bandwidth(0.0)))
    assert tiered.tiered and tiered.bucketer is None   # tiered default
    # Arrival is the canonical intake type
    rec = tiered.ingest(Arrival("s0", Event(0, "text", 0.0),
                                payloads["text"]))
    assert rec.sid == "s0" and rec.outputs is not None


# ======================================================================
# Parity tier: each single policy == its legacy engine, event for event
# ======================================================================

def test_batch_spec_matches_legacy_batched(indep_models):
    """build_engine('batch') == BatchedEMSServe on the LAG_SCENARIOS
    interleaving: same flush cadence, same recommendations, same
    dispatch counts."""
    cfg, splits, params, payloads = indep_models
    eps = _lag_episodes()
    mk = lambda: Bucketer(max_buckets={"vitals": 8})  # noqa: E731

    def drive(eng):
        reports = []
        for _t, sid, ev in merge_arrivals(eps):
            eng.submit(sid, ev, payloads[ev.modality])
            reports.append(eng.flush())
        return reports

    legacy = drive(BatchedEMSServe(splits, params, bucketer=mk()))
    unified = drive(build_engine(splits, params, "batch", bucketer=mk()))
    assert len(legacy) == len(unified)
    for a, b in zip(legacy, unified):
        assert (a.n_events, a.n_encoder_calls, a.n_tail_calls) == \
            (b.n_events, b.n_encoder_calls, b.n_tail_calls)
        assert sorted(a.recommendations) == sorted(b.recommendations)
        for sid in a.recommendations:
            _assert_close(b.recommendations[sid], a.recommendations[sid],
                          atol=0)


def test_stream_spec_matches_legacy_streaming(zoo_models):
    """build_engine('stream') == StreamingEMSServe prediction-for-
    prediction over the LAG_SCENARIOS interleaving."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = _lag_episodes()

    def drive(eng):
        eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                         sim_window=0.0)
        return [p for f in eng.flushes for p in f.predictions]

    legacy = drive(StreamingEMSServe(splits, params, share_encoders=True,
                                     deadline_s=None, max_history=None))
    unified = drive(build_engine(splits, params, "stream",
                                 share_encoders=True, deadline_s=None,
                                 max_history=None))
    assert len(legacy) == len(unified) > 0
    for a, b in zip(legacy, unified):
        assert (a.sid, a.step, a.model, a.modalities, a.kind,
                a.flush_id) == (b.sid, b.step, b.model, b.modalities,
                                b.kind, b.flush_id)
        _assert_close(b.outputs, a.outputs, atol=0)
    # finals match the one-shot forward (the legacy parity anchor)
    want = E.forward(shared, cfg, payloads)
    finals = [p for p in unified if p.kind == "final"]
    assert finals
    _assert_close(finals[-1].outputs, want)


def test_tiered_spec_matches_legacy_tiered(zoo_models):
    """build_engine('tiered') == TieredEMSServe record-for-record:
    placement, clocks, and outputs."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = _lag_episodes()
    mk = lambda: dict(  # noqa: E731
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(5.0)))

    legacy = TieredEMSServe(splits, params, share_encoders=True, **mk())
    legacy.run_arrivals(eps, lambda sid, ev: payloads[ev.modality])
    unified = build_engine(splits, params, "tiered", share_encoders=True,
                           **mk())
    unified.run_arrivals(eps, lambda sid, ev: payloads[ev.modality])

    assert len(legacy.records) == len(unified.records) > 0
    for a, b in zip(legacy.records, unified.records):
        assert (a.sid, a.index, a.modality, a.model, a.tier, a.kind) == \
            (b.sid, b.index, b.modality, b.model, b.tier, b.kind)
        assert a.t_start == pytest.approx(b.t_start)
        assert a.t_emit == pytest.approx(b.t_emit)
        if a.outputs is not None:
            _assert_close(b.outputs, a.outputs, atol=0)
    assert legacy.placement_counts() == unified.placement_counts()
    # legacy construction = stream policy off: no glass partials anywhere
    assert all(r.glass_partial is None for r in legacy.records)


# ======================================================================
# Composition tier
# ======================================================================

def test_batch_stream_composition_coalesces_without_changing_finals(
        zoo_models):
    """batch+stream: deadline-coalesced flushes over interleaved
    sessions batch the work (fewer flushes) yet the finals equal the
    flush-per-arrival engine's."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = _lag_episodes()

    def run(sim_window):
        eng = build_engine(splits, params, "batch+stream",
                           share_encoders=True, deadline_s=None,
                           batch_bucket_min=2, max_history=None)
        eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                         sim_window=sim_window)
        return eng

    per_arrival = run(0.0)
    coalesced = run(2.0)
    assert coalesced.flushes_total < per_arrival.flushes_total
    for sid in eps:
        a = per_arrival.sessions[sid].predictions[-1]
        b = coalesced.sessions[sid].predictions[-1]
        assert a.kind == b.kind == "final"
        _assert_close(b.outputs, a.outputs, atol=0)


def test_stream_tiered_composition_glass_partials(zoo_models):
    """The newly-possible composition: while an offloaded arrival is in
    flight, the glasses emit a provisional partial from cached
    (<=1-step stale) features — matching ``partial_forward`` on the
    previously-observed subset and landing BEFORE the edge's refreshed
    prediction — and the finals still match ``SplitModel.full``."""
    cfg, splits, shared, params, payloads = zoo_models
    # degraded-but-offloadable link (10 m NLOS): raw-payload-heavy
    # uplinks make the edge round trip slower than the on-glass tail,
    # which is the regime where provisional partials buy real lead time
    eng = build_engine(splits, params, "stream+tiered",
                       share_encoders=True,
                       profile=ProfileTable(base=dict(BASE)),
                       trace=BandwidthTrace.static(nlos_bandwidth(10.0)))
    recs = []
    for i, m in enumerate(ALL):
        recs.append(eng.submit("s0", Event(i, m, float(i)), payloads[m]))

    # everything still offloads; the first arrival has no cached subset
    # yet, later ones serve a glass partial over what was there
    assert [r.tier for r in recs] == ["edge", "edge", "edge"]
    assert recs[0].glass_partial is None
    for i in (1, 2):
        gp = recs[i].glass_partial
        assert gp is not None and gp.kind == "partial"
        assert gp.modalities == ALL[:i]            # the pre-arrival subset
        _assert_close(gp.outputs,
                      E.partial_forward(shared, cfg, payloads, ALL[:i]))
    # the camera-frame offload pays a ~0.4 s uplink: its provisional
    # partial lands on-glass while the refresh is still in flight
    assert recs[2].glass_partial.t_emit < recs[2].t_emit
    # the refreshed predictions are unchanged by the composition
    for i, r in enumerate(recs):
        _assert_close(r.outputs,
                      E.partial_forward(shared, cfg, payloads, ALL[:i + 1]))
    assert recs[-1].kind == "final"
    _assert_close(recs[-1].outputs, E.forward(shared, cfg, payloads))

    # a re-arrival serves the FULL fused subset from 1-step-stale cache
    # (the paper's tolerated bound) while the edge refreshes vitals
    rec = eng.submit("s0", Event(3, "vitals", 4.0), payloads["vitals"])
    gp = rec.glass_partial
    assert gp is not None and gp.modalities == ALL and gp.kind == "partial"
    _assert_close(gp.outputs, E.forward(shared, cfg, payloads))
    # sessions expose the full progressive stream under stream policy
    kinds = [p.kind for p in eng.sessions["s0"].predictions]
    assert kinds.count("partial") >= 3 and "final" in kinds
    # TTFP counts the glass provisional (it IS what the EMT sees first)
    assert eng.time_to_first_prediction("s0") is not None


def test_glass_partial_emitted_for_local_enc_remote_tail_split(zoo_models):
    """Per-submodule tail placement composes with stream: even when the
    ENCODER stays home, a remotely-placed tail is an offload round trip
    the EMT should not wait behind — a provisional partial from cached
    features is emitted, matching ``partial_forward`` on the
    previously-observed subset, and the refreshed final is unchanged."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(
        splits, params, "stream+tiered", share_encoders=True,
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(0.0)),
        tiers=("glass", "ph1", "edge64x"),
        force={"enc:text": "glass", "enc:vitals": "glass",
               "enc:scene": "glass", "tail": "ph1"})
    recs = [eng.submit("s0", Event(i, m, float(i)), payloads[m])
            for i, m in enumerate(ALL)]
    assert all(r.enc_tier == "glass" and r.tail_tier == "ph1"
               for r in recs)
    assert recs[0].glass_partial is None       # nothing cached yet
    for i in (1, 2):
        gp = recs[i].glass_partial
        assert gp is not None and gp.kind == "partial"
        assert gp.modalities == ALL[:i]
        _assert_close(gp.outputs,
                      E.partial_forward(shared, cfg, payloads, ALL[:i]))
        assert gp.t_emit < recs[i].t_emit      # lands before the refresh
    assert recs[-1].kind == "final"
    _assert_close(recs[-1].outputs, E.forward(shared, cfg, payloads))


def test_stream_tiered_staleness_invariant_still_asserted(zoo_models):
    """The glass-partial path reads through the live staleness assert:
    an artificially outdated cache entry raises StalenessError instead
    of silently serving stale-beyond-bound features."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(splits, params, "stream+tiered",
                       share_encoders=True,
                       profile=ProfileTable(base=dict(BASE)),
                       trace=BandwidthTrace.static(nlos_bandwidth(0.0)))
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    # corrupt the vitals entry to be 2+ steps behind its input
    eng.cache.peek("s0", "vitals").step -= 2
    with pytest.raises(StalenessError):
        eng.submit("s0", Event(3, "vitals", 4.0), payloads["vitals"])


def test_all_three_policies_compose(zoo_models):
    """batch+stream+tiered builds one runtime: shape bucketing from the
    batch policy bounds the tiered encoder shapes, glass partials flow,
    and parity with the monolithic forward holds."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(
        splits, params, "batch+stream+tiered", share_encoders=True,
        bucketer=Bucketer(max_buckets={"vitals": 8,
                                       "text": cfg.max_text_len}),
        profile=ProfileTable(base=dict(BASE)),
        trace=BandwidthTrace.static(nlos_bandwidth(0.0)))
    assert eng.tiered and eng.bucketer is not None
    for i, m in enumerate(ALL):
        rec = eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert rec.kind == "final"
    _assert_close(rec.outputs, E.forward(shared, cfg, payloads))
    assert any(r.glass_partial is not None
               for r in eng.sessions["s0"].records)


def test_stream_tiered_eviction_runs_on_the_simulated_clock(zoo_models):
    """Cross-incident eviction composes with tiered placement: over the
    max_sessions cap, the least-recently-active incident leaves with its
    cache entries and edge-replica version bookkeeping."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(splits, params, "stream+tiered",
                       share_encoders=True, max_sessions=1,
                       profile=ProfileTable(base=dict(BASE)),
                       trace=BandwidthTrace.static(nlos_bandwidth(0.0)))
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    assert ("s0", "text") in eng.cache
    eng.submit("s1", Event(0, "text", 10.0), payloads["text"])
    assert set(eng.sessions) == {"s1"} and eng.evicted_count == 1
    assert ("s0", "text") not in eng.cache
    assert not any(k[0] == "s0" for k in eng._edge_versions)


def test_parse_spec_override_beats_dict_section():
    es = parse_spec({"stream": {"deadline_s": 0.1}}, deadline_s=0.05)
    assert es.stream.deadline_s == 0.05


def test_tiered_flush_mode_guards():
    """Mode misuse fails loudly, not silently."""
    with pytest.raises(ValueError):
        # crash_at only makes sense with placement
        StreamingEMSServe({}, {}).run_arrivals({}, lambda s, e: None,
                                               crash_at=1.0)
