"""End-to-end behaviour tests for the paper's system.

Train EMSNet on synthetic NEMSIS data, split it with EMSServe, stream a
Table-6 episode through the engine, and check the serving stack end to
end (tasks 1-5, caching, offloading, fault tolerance). Also lowers the
dry-run step functions on a 1x1 host mesh to validate the spec
machinery without the 512-device flag.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, reduced
from repro.core import (AdaptiveOffloadPolicy, BandwidthTrace, EMSServe,
                        HeartbeatMonitor, ProfileTable, emsnet_module,
                        nlos_bandwidth, split, table6)
from repro.core import medmath as MM
from repro.data import synthetic_nemsis as D
from repro.training import emsnet_trainer as ET


@pytest.fixture(scope="module")
def trained_system(tiny_emsnet_cfg):
    """Train M1/M2/M3 on synthetic D1/D2, return split models + params."""
    cfg = tiny_emsnet_cfg
    d1 = D.generate(cfg, 1000, seed=0)
    tr, _, te = D.splits(d1)
    p1, _ = ET.train(cfg, D.loader(tr, 64, modalities=("text",)),
                     modalities=("text",), steps=60)
    p2, _ = ET.train(cfg, D.loader(tr, 64, modalities=("text", "vitals")),
                     modalities=("text", "vitals"), steps=60)
    d2 = D.generate(cfg, 300, seed=5, modal3=True)
    tr2, _, _ = D.splits(d2)
    p3, _ = ET.pmi_finetune(cfg, p2, D.loader(tr2, 32), steps=40)
    mods = {"m1": emsnet_module(cfg, ("text",)),
            "m2": emsnet_module(cfg, ("text", "vitals")),
            "m3": emsnet_module(cfg, ("text", "vitals", "scene"))}
    splits = {k: split(m) for k, m in mods.items()}
    params = {"m1": p1, "m2": p2, "m3": p3}
    return cfg, splits, params, te


def _episode_payloads(cfg, te):
    return {
        "text": jnp.asarray(te.text[:1]),
        "vitals": jnp.asarray(te.vitals[:1]),
        "scene": jnp.asarray(te.scene[:1]),
    }


def test_e2e_episode_with_trained_models(trained_system):
    """Full pipeline: episode stream -> recommendations -> med-math."""
    cfg, splits, params, te = trained_system
    payloads = _episode_payloads(cfg, te)
    pol = AdaptiveOffloadPolicy(
        ProfileTable(base={"enc:text": 0.05, "enc:vitals": 0.001,
                           "enc:scene": 0.001, "tail": 0.001, "full": 0.06}),
        HeartbeatMonitor(BandwidthTrace.static(nlos_bandwidth(5))))
    eng = EMSServe(splits, params, policy=pol, cached=True)
    eng.run_episode(table6()[1], lambda ev: payloads[ev.modality])

    final = eng.records[-1].recommendation
    assert final is not None
    assert final["protocol_logits"].shape == (1, cfg.n_protocols)
    assert final["medicine_logits"].shape == (1, cfg.n_medicines)
    # tasks 4 & 5 post-processing on the quantity head output
    qty = abs(float(final["quantity"][0])) + 0.5
    dosage = MM.dosage_from_label(qty, "adrenaline")
    assert dosage["dosage_ml"] > 0
    # the model used at the end integrates all three modalities
    assert eng.records[-1].model == "m3"


def test_e2e_recommendations_track_model_upgrades(trained_system):
    """As modalities arrive, the engine upgrades M1 -> M2 -> M3."""
    cfg, splits, params, te = trained_system
    payloads = _episode_payloads(cfg, te)
    eng = EMSServe(splits, params, cached=True, real_time=True)
    models_used = []
    for ev in table6()[1]:
        rec = eng.on_event(ev, payloads[ev.modality])
        models_used.append(rec.model)
    assert models_used[0] == "m1"          # speech only
    assert models_used[1] == "m2"          # + vitals
    assert models_used[-1] == "m3"         # + scene
    assert eng.cache.hits > 0


def test_e2e_accuracy_sanity(trained_system):
    """Trained 2-modal model is far above chance on protocol selection."""
    cfg, splits, params, te = trained_system
    m = ET.evaluate(params["m2"], cfg, te, ("text", "vitals"))
    assert m["protocol_top1"] > 5.0 / cfg.n_protocols


def test_lowering_on_host_mesh():
    """input_specs + jit.lower works for reduced archs on the 1x1 mesh
    (the real 256/512-device lowering is covered by launch/dryrun.py)."""
    from repro.distributed.sharding import Policy
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import input_specs
    import dataclasses

    cfg = reduced(get_config("olmoe-1b-7b"))
    shape = dataclasses.replace(SHAPES["decode_32k"], seq_len=64,
                                global_batch=2)
    mesh = make_host_mesh()
    pol = Policy(cfg, mesh)
    fn, args = input_specs(cfg, shape, pol)
    with mesh:
        compiled = jax.jit(fn).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_dryrun_artifacts_complete():
    """The committed dry-run sweep covers all 40 pairs x 2 meshes OK."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "benchmarks" / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(p.read_text()) for p in art.glob("*.json")]
    from repro.configs import ARCHS
    ok = {(r["arch"], r["shape"], r["mesh"]) for r in recs if r["ok"]}
    missing = [(a, s, m) for a in ARCHS for s in SHAPES
               for m in ("single", "multi") if (a, s, m) not in ok]
    assert not missing, f"missing/failed dry-runs: {missing[:5]}"
