"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dep (requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.episodes import random_episode
from repro.core.feature_cache import FeatureCache
from repro.core.medmath import med_math
from repro.core.offload import (AdaptiveOffloadPolicy, BandwidthTrace,
                                HeartbeatMonitor, ProfileTable)
from repro.kernels import ref
from repro.models.attention import flash_attention_jnp
from repro.training import losses as LS

SETTINGS = dict(max_examples=25, deadline=None)


@settings(**SETTINGS)
@given(st.integers(1, 2), st.integers(1, 48), st.integers(1, 3),
       st.integers(1, 2), st.integers(2, 16), st.booleans(),
       st.integers(0, 12), st.randoms(use_true_random=False))
def test_flash_matches_ref_any_shape(B, Sq, G, KV, D, causal, window, pyrng):
    """flash(q,k,v) == materialized softmax attention for arbitrary
    shapes, GQA ratios, causal flags and windows."""
    H = KV * G
    Sk = Sq  # self-attention shapes
    seed = pyrng.randint(0, 2**31)
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    got = flash_attention_jnp(q, k, v, causal=causal, window=window,
                              q_chunk=16, kv_chunk=16)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=3e-5)


@settings(**SETTINGS)
@given(st.lists(st.tuples(st.integers(0, 50), st.sampled_from(
    ["text", "vitals", "scene"])), min_size=1, max_size=40))
def test_cache_last_write_wins(ops):
    """Whatever sequence of puts happens, get returns the latest put and
    version counts the number of overwrites."""
    c = FeatureCache()
    last = {}
    counts = {}
    for step, (val, mod) in enumerate(ops):
        c.put("s", mod, val, step=step)
        last[mod] = val
        counts[mod] = counts.get(mod, -1) + 1
    for mod, val in last.items():
        e = c.get("s", mod)
        assert e.feature == val
        assert e.version == counts[mod]


@settings(**SETTINGS)
@given(st.floats(0.001, 1000), st.floats(0.001, 1000))
def test_med_math_positive(q, c):
    d = med_math(q, c)
    assert d > 0
    assert d * c == np.float64(q) or abs(d * c - q) / q < 1e-9


@settings(**SETTINGS)
@given(st.integers(1, 200), st.integers(0, 10_000))
def test_random_episode_invariants(n, seed):
    ev = random_episode(n, seed)
    assert len(ev) == n
    assert sum(e.modality == "text" for e in ev) >= 1
    times = [e.arrival_time for e in ev]
    assert times == sorted(times)


@settings(**SETTINGS)
@given(st.floats(1e3, 1e9), st.floats(1e-4, 10.0), st.integers(1, 10**7))
def test_offload_decision_consistent(bw, base_t, payload):
    """The decision always picks the smaller modeled latency."""
    prof = ProfileTable(base={"m": base_t})
    pol = AdaptiveOffloadPolicy(prof, HeartbeatMonitor(BandwidthTrace.static(bw)))
    d = pol.decide("m", payload, now=0.0)
    edge_cost = d.delta_t + d.t_edge
    glass_cost = d.t_glass
    assert (d.tier == "edge") == (edge_cost < glass_cost)


def _static_decision(bw, base_t, payload):
    pol = AdaptiveOffloadPolicy(
        ProfileTable(base={"m": base_t}),
        HeartbeatMonitor(BandwidthTrace.static(bw)))
    return pol.decide("m", payload, now=0.0).tier


@settings(**SETTINGS)
@given(st.floats(1e3, 1e9), st.floats(1e3, 1e9),
       st.floats(1e-4, 10.0), st.integers(1, 10**7))
def test_offload_decision_monotone_in_bandwidth(bw_a, bw_b, base_t, payload):
    """More bandwidth can only flip glass -> edge, never the reverse:
    the offloaded set is upward-closed in bandwidth."""
    lo, hi = sorted((bw_a, bw_b))
    if _static_decision(lo, base_t, payload) == "edge":
        assert _static_decision(hi, base_t, payload) == "edge"


@settings(**SETTINGS)
@given(st.floats(1e3, 1e9), st.floats(1e-4, 10.0),
       st.integers(1, 10**7), st.integers(1, 10**7))
def test_offload_decision_monotone_in_payload(bw, base_t, pay_a, pay_b):
    """A bigger payload can only flip edge -> glass, never the reverse:
    the offloaded set is downward-closed in payload size."""
    small, big = sorted((pay_a, pay_b))
    if _static_decision(bw, base_t, big) == "edge":
        assert _static_decision(bw, base_t, small) == "edge"


@settings(**SETTINGS)
@given(st.floats(1e-4, 10.0), st.floats(1.0, 100.0),
       st.integers(1, 10**7))
def test_offload_never_chosen_when_edge_slower_at_infinite_bw(
        base_t, slowdown, tiny_payload):
    """If the 'edge' tier is no faster than the 'glass' tier, even free
    transfer (infinite bandwidth, Δt -> 0) must not offload: Δt + t^e <
    t^g is unsatisfiable with t^e >= t^g and Δt > 0."""
    factors = {"g": 1.0, "e": float(slowdown)}    # edge >= glass cost
    prof = ProfileTable(base={"m": base_t}, factors=factors, host_tier="e")
    pol = AdaptiveOffloadPolicy(
        prof, HeartbeatMonitor(BandwidthTrace.static(1e30)),
        glass_tier="g", edge_tier="e")
    assert pol.decide("m", tiny_payload, now=0.0).tier == "glass"


_CACHE_OPS = st.lists(st.tuples(
    st.sampled_from(["put", "touch", "get", "features", "drop"]),
    st.sampled_from(["text", "vitals", "scene"]),
    st.sampled_from(["glass", "edge"]),
    st.integers(0, 4)), max_size=60)


@settings(**SETTINGS)
@given(_CACHE_OPS, st.integers(0, 3))
def test_cache_never_serves_stale_features(ops, max_staleness):
    """Any put/get/touch/drop_tier sequence: a returned entry is never
    staler than max_staleness relative to the probed input_step, and
    StalenessError is raised EXACTLY when an entry exists whose lag
    exceeds it (None exactly when absent)."""
    from repro.core.feature_cache import FeatureCache, StalenessError
    c = FeatureCache(max_staleness=max_staleness)
    model = {}                          # modality -> [feature, step, tier]
    step = 0
    for op, m, tier, k in ops:
        if op == "put":
            step += 1
            c.put("s", m, step, step=step, tier=tier)
            model[m] = [step, step, tier]
        elif op == "touch":
            c.touch("s", m, step)
            if m in model:
                model[m][1] = step       # re-stamped, feature unchanged
        elif op == "drop":
            c.drop_tier(tier)
            model = {mm: v for mm, v in model.items() if v[2] != tier}
        elif op == "get":
            # probe input_steps both within and beyond the window
            input_step = max(0, step + k - 2)
            if m not in model:
                assert c.get("s", m, input_step=input_step) is None
            elif input_step - model[m][1] > max_staleness:
                with pytest.raises(StalenessError):
                    c.get("s", m, input_step=input_step)
            else:
                e = c.get("s", m, input_step=input_step)
                assert e.feature == model[m][0]
                assert input_step - e.step <= max_staleness
        else:                                  # features(): the fuse path
            mods = ("text", "vitals", "scene")
            input_steps = {mm: max(0, step + k - 2) for mm in mods}
            stale = [mm for mm in mods if mm in model and
                     input_steps[mm] - model[mm][1] > max_staleness]
            if stale:
                # the model would fuse a stale feature -> must raise,
                # unless an earlier missing modality short-circuits
                first_missing = next((i for i, mm in enumerate(mods)
                                      if mm not in model), len(mods))
                first_stale = min(mods.index(mm) for mm in stale)
                if first_stale < first_missing:
                    with pytest.raises(StalenessError):
                        c.features("s", mods, input_steps=input_steps)
                else:
                    assert c.features("s", mods,
                                      input_steps=input_steps) is None
            else:
                out = c.features("s", mods, input_steps=input_steps)
                if all(mm in model for mm in mods):
                    assert out == {mm: model[mm][0] for mm in mods}
                else:
                    assert out is None


@settings(**SETTINGS)
@given(st.lists(st.sampled_from(["text", "vitals", "scene"]),
                min_size=1, max_size=20))
def test_cache_features_all_or_nothing(puts):
    """features() returns every requested modality or None — it never
    hands the fuse path a partial dict."""
    from repro.core.feature_cache import FeatureCache
    c = FeatureCache()
    for i, m in enumerate(puts):
        c.put("s", m, i, step=i)
    for mods in (("text",), ("text", "vitals"), ("text", "vitals", "scene")):
        out = c.features("s", mods)
        assert out is None or set(out) == set(mods)


@settings(**SETTINGS)
@given(st.integers(2, 64), st.integers(2, 8), st.randoms(use_true_random=False))
def test_softmax_ce_nonnegative_and_bounded(n, v, pyrng):
    key = jax.random.PRNGKey(pyrng.randint(0, 2**31))
    logits = jax.random.normal(key, (n, v)) * 5
    labels = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, v)
    ce = float(LS.cross_entropy(logits, labels))
    assert ce >= 0.0
    assert np.isfinite(ce)


# ------------------------------------------------- transport (ISSUE 6)

_SENDS = st.lists(st.tuples(st.integers(1, 10**6),      # nbytes
                            st.floats(0.0, 2.0)),       # inter-send gap
                  min_size=1, max_size=30)


def _channel(bw=1e5):
    from repro.serving.transport import TransportChannel
    return TransportChannel(BandwidthTrace.static(bw), latency_s=0.005)


@settings(**SETTINGS)
@given(_SENDS, st.floats(1e3, 1e9))
def test_transport_in_order_delivery(sends, bw):
    """A channel is a stream: whatever the send sizes and gaps, the
    delivery sequence never reorders and every delivery respects the
    link latency + serialization floor."""
    ch = _channel(bw)
    t, prev = 0.0, 0.0
    for nbytes, gap in sends:
        t += gap
        d = ch.send(nbytes, t)
        assert d.t_deliver >= prev              # in-order, never overtakes
        assert d.t_deliver >= t + ch.latency_s + d.transfer_s - 1e-12
        prev = d.t_deliver


@settings(**SETTINGS)
@given(_SENDS, st.integers(1, 10**6), st.integers(1, 10**6),
       st.floats(0.0, 5.0))
def test_transport_eta_monotone_and_never_early(sends, na, nb, t_probe):
    """eta() is monotone in nbytes, never before the probe time, and
    non-mutating: probing it never changes what a later send does."""
    ch = _channel()
    t = 0.0
    for nbytes, gap in sends:
        t += gap
        ch.send(nbytes, t)
    small, big = sorted((na, nb))
    assert ch.eta(small, t_probe) <= ch.eta(big, t_probe)
    assert ch.eta(big, t_probe) >= t_probe
    before = ch.eta(small, t_probe)
    ch.eta(big, t_probe * 2 + 1.0)              # probe again, elsewhere
    assert ch.eta(small, t_probe) == before     # state untouched


@settings(**SETTINGS)
@given(_SENDS, st.data())
def test_transport_cancellation_never_delivers(sends, data):
    """A flight cancelled before its delivery instant NEVER delivers:
    it leaves completed(), its delivered_at is None, the cancel is
    audited, and a flight already delivered cannot be recalled."""
    ch = _channel()
    t, flights = 0.0, []
    for nbytes, gap in sends:
        t += gap
        flights.append(ch.send(nbytes, t))
    victim = data.draw(st.sampled_from(flights))
    t_cancel = data.draw(st.floats(victim.t_send, victim.t_deliver * 2))
    ok = ch.cancel(victim.flight, t=t_cancel)
    assert ok == (t_cancel < victim.t_deliver)  # too late -> refused
    if ok:
        assert victim.cancelled and victim.delivered_at is None
        assert victim not in ch.completed()
        assert ch.cancelled_msgs == 1 and ch.cancelled_bytes == victim.nbytes
        assert not ch.cancel(victim.flight, t=t_cancel)   # idempotent
    else:
        assert victim in ch.completed()
    # the wire stays consistent: later sends still deliver in order
    prev = max((d.t_deliver for d in ch.completed()), default=0.0)
    d = ch.send(100, t + 1.0)
    assert d.t_deliver >= prev and not d.cancelled


@settings(**SETTINGS)
@given(st.floats(1e3, 1e9), st.floats(1e3, 1e9),
       st.lists(st.floats(0.0, 100.0), min_size=1, max_size=20))
def test_mintrace_bottlenecks_both_components(bw_a, bw_b, probes):
    """A remote<->remote path runs at the slower of the two radio
    links: MinTrace.at is <= both components everywhere."""
    from repro.serving.transport import MinTrace
    a, b = BandwidthTrace.static(bw_a), BandwidthTrace.static(bw_b)
    mt = MinTrace(a, b)
    for t in probes:
        assert mt.at(t) <= a.at(t) and mt.at(t) <= b.at(t)
        assert mt.at(t) == min(a.at(t), b.at(t))


@settings(**SETTINGS)
@given(st.sampled_from(["ph1", "edge64x"]), st.integers(1, 10**5))
def test_fabric_channels_cached_and_no_self_wire(dst, nbytes):
    """The fabric hands out ONE channel per (src, dst) direction — so
    in-order state and byte accounting live exactly once — and refuses
    a wire from a tier to itself. Fabric-wide flight ids stay unique
    across channels."""
    from repro.serving.transport import TierFabric
    fab = TierFabric("glass", {"ph1": BandwidthTrace.static(1e6),
                               "edge64x": BandwidthTrace.static(1e7)})
    ch = fab.channel("glass", dst)
    assert ch is fab.channel("glass", dst)           # cached identity
    assert fab.channel(dst, "glass") is not ch       # directions differ
    with pytest.raises(ValueError):
        fab.channel("glass", "glass")
    d1 = fab.channel("glass", dst).send(nbytes, 0.0)
    d2 = fab.channel(dst, "glass").send(nbytes, 0.0)
    assert d1.flight != d2.flight                    # one id space
    assert fab.cancel(d1.flight, t=0.0) or d1.t_deliver <= 0.0
    assert fab.cancelled_msgs() == 1


@settings(**SETTINGS)
@given(st.integers(3, 100), st.randoms(use_true_random=False))
def test_spearman_invariant_to_monotone_transform(n, pyrng):
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    s1 = float(LS.spearmanr(jnp.asarray(x), jnp.asarray(y)))
    s2 = float(LS.spearmanr(jnp.asarray(np.exp(x)), jnp.asarray(y)))
    assert abs(s1 - s2) < 1e-4
