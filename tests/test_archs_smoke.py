"""Per-architecture smoke tests (deliverable f): every assigned arch, as
a reduced variant of the same family (<=2 periods, d_model<=128, <=4
experts), runs one forward AND one train step on CPU with output-shape
and finite-ness assertions, plus prefill/decode agreement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced
from repro.models import transformer as T
from repro.training.trainer import make_train_step


def _batch(cfg, key, B=2, S=12):
    shape = (B, S) if cfg.n_codebooks == 1 else (B, S, cfg.n_codebooks)
    toks = jax.random.randint(key, (shape[0], S + 1) + shape[2:], 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.cond_dim:
        batch["cond"] = jax.random.normal(
            key, (B, cfg.cond_seq_len, cfg.cond_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, extras = T.forward_train(params, cfg, batch["tokens"],
                                     cond=batch.get("cond"),
                                     next_tokens=batch["labels"])
    B, S = batch["tokens"].shape[:2]
    want = (B, S, cfg.vocab_size) if cfg.n_codebooks == 1 else \
        (B, S, cfg.n_codebooks, cfg.vocab_size)
    assert logits.shape == want
    assert not jnp.isnan(logits).any()
    if cfg.mtp:
        assert "mtp_logits" in extras and extras["mtp_logits"].shape == want


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, key):
    cfg = reduced(get_config(arch))
    step_fn, opt_init = make_train_step(cfg)
    params = T.init_params(cfg, key)
    opt_state = opt_init(params)
    batch = _batch(cfg, key)
    new_params, new_opt, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).sum()),
                     params, new_params))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch, key):
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, key)
    batch = _batch(cfg, key, B=2, S=10)
    toks = batch["tokens"]
    S = toks.shape[1]
    cond = batch.get("cond")
    full, _ = T.forward_train(params, cfg, toks, cond=cond)
    lp, cache = T.prefill(params, cfg, toks[:, :S - 1], cond=cond,
                          cache_len=S + 3)
    ld, _ = T.decode_step(params, cfg, toks[:, S - 1:S], cache,
                          jnp.int32(S - 1))
    np.testing.assert_allclose(ld, full[:, S - 1:S], atol=5e-4)


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "rwkv6-1.6b",
                                  "jamba-v0.1-52b", "deepseek-v3-671b"])
def test_multi_step_decode(arch, key):
    """Greedy decode several tokens without NaN and with cache reuse."""
    cfg = reduced(get_config(arch))
    params = T.init_params(cfg, key)
    B, S = 1, 6
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, cache = T.prefill(params, cfg, toks, cache_len=S + 8)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for i in range(4):
        logits, cache = T.decode_step(params, cfg, tok, cache,
                                      jnp.int32(S + i))
        assert not jnp.isnan(logits).any()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_param_counts_are_plausible():
    """Full configs land within 40% of the published sizes."""
    expect = {
        "deepseek-v3-671b": 671e9, "nemotron-4-15b": 15e9,
        "codeqwen1.5-7b": 7e9, "qwen1.5-32b": 32e9, "rwkv6-1.6b": 1.6e9,
        "jamba-v0.1-52b": 52e9, "mistral-nemo-12b": 12e9,
        "olmoe-1b-7b": 7e9, "musicgen-large": 3.3e9,
        "llama-3.2-vision-11b": 11e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.6 * n < got < 1.4 * n, f"{arch}: {got/1e9:.1f}B vs {n/1e9:.1f}B"
