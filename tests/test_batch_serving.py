"""Shape bucketing + BatchedEMSServe: padding must not change the math,
coalesced multi-session serving must match the per-event engine, and the
compile count must plateau once the bucket grid is warm."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.emsnet import tiny
from repro.core import (Bucketer, EMSServe, bucket_length, emsnet_module,
                        next_pow2, split, table6)
from repro.core.bucketing import pad_axis, stack_bucketed
from repro.core.episodes import Event
from repro.models import emsnet as E
from repro.serving.batch_engine import BatchedEMSServe


# ------------------------------------------------------------ bucketing

def test_bucket_length_grid():
    assert next_pow2(1) == 1 and next_pow2(5) == 8 and next_pow2(8) == 8
    assert bucket_length(3) == 8                      # min_bucket floor
    assert bucket_length(9) == 16
    assert bucket_length(100, max_bucket=16) == 16    # clamp
    # distinct buckets for n in 1..64 is O(log): bounded compile count
    assert len({bucket_length(n, max_bucket=64) for n in range(1, 65)}) <= 4


def test_pad_axis_pads_and_crops():
    x = jnp.arange(6).reshape(1, 6)
    assert pad_axis(x, 8, axis=1).shape == (1, 8)
    # crop keeps the trailing (most recent) slice
    np.testing.assert_array_equal(pad_axis(x, 3, axis=1)[0], [3, 4, 5])


def test_bucketer_payloads():
    b = Bucketer(min_bucket=4, max_buckets={"vitals": 8})
    toks = b.fit("text", jnp.ones((1, 5), jnp.int32))
    assert toks.shape == (1, 8) and int(toks[0, -1]) == 0
    vit = b.fit("vitals", jnp.ones((1, 13, 6)))
    assert vit["x"].shape == (1, 8, 6) and int(vit["len"][0]) == 8
    assert b.n_buckets() == 2
    # text crops keep the valid prefix, not the PAD suffix
    b2 = Bucketer(min_bucket=4, max_buckets={"text": 4})
    t = b2.fit("text", jnp.asarray([[1, 2, 3, 0, 0, 0]], jnp.int32))
    np.testing.assert_array_equal(np.asarray(t[0]), [1, 2, 3, 0])


def test_stack_bucketed_rows():
    rows = [{"x": jnp.ones((1, 4, 2)), "len": jnp.array([3], jnp.int32)}
            for _ in range(3)]
    s = stack_bucketed(rows, 4)
    assert s["x"].shape == (4, 4, 2) and int(s["len"][3]) == 0


@pytest.mark.parametrize("kind", ["rnn", "gru", "lstm"])
def test_masked_vitals_encoder_equals_unpadded(kind):
    cfg = tiny(vitals_encoder=kind)
    p = E.vitals_encoder_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, cfg.n_vitals))
    want = E.vitals_encoder(p, cfg, x)
    got = E.vitals_encoder(p, cfg, Bucketer().fit("vitals", x))
    np.testing.assert_allclose(got, want, atol=1e-6)


# -------------------------------------------------- Pallas text encoder

def test_flash_text_encoder_matches_einsum_padded_batch():
    """Acceptance: fused text path within 1e-3 of the einsum reference
    on a padded batch (variable lengths incl. an all-PAD row)."""
    cfg = tiny()
    p = E.text_encoder_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    toks = np.zeros((4, cfg.max_text_len), np.int32)
    for i, n in enumerate([cfg.max_text_len, 9, 3, 0]):
        toks[i, :n] = rng.integers(1, cfg.vocab_size, n)
    toks = jnp.asarray(toks)
    want = E.text_encoder(p, cfg, toks)
    got = E.text_encoder(p, dataclasses.replace(cfg, use_flash_text=True),
                         toks)
    np.testing.assert_allclose(got, want, atol=1e-3)


# --------------------------------------------------- batched engine

@pytest.fixture(scope="module")
def models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    key = jax.random.PRNGKey(0)
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 1, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, params, payloads


def _aggregate(old, new):
    if old is not None and new.ndim == 3:
        return jnp.concatenate([old, new], axis=1)
    return new


def test_batched_single_session_matches_per_event(models):
    """One session, flush per event == the per-event EMSServe (both
    bucketed), recommendation for recommendation."""
    cfg, splits, params, payloads = models
    mk = lambda: Bucketer(max_buckets={"vitals": 8})
    eng = EMSServe(splits, params, cached=True, real_time=True,
                   bucketer=mk())
    eng.run_episode(table6()[2], lambda ev: payloads[ev.modality],
                    aggregate=_aggregate)
    want = [r.recommendation for r in eng.records
            if r.recommendation is not None]

    beng = BatchedEMSServe(splits, params, bucketer=mk())
    got = []
    for ev in table6()[2]:
        beng.submit("s0", ev, payloads[ev.modality], aggregate=_aggregate)
        rep = beng.flush()
        if "s0" in rep.recommendations:
            got.append(rep.recommendations["s0"])
    assert len(got) == len(want)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a["protocol_logits"],
                                   b["protocol_logits"], atol=1e-5)
        np.testing.assert_allclose(a["quantity"], b["quantity"], atol=1e-5)


def test_batched_multi_session_matches_per_event(models):
    """N coalesced sessions produce the same final recommendation each
    as N independent per-event engines."""
    cfg, splits, params, payloads = models
    eps = {f"s{i}": table6()[1 + i % 3] for i in range(4)}

    want = {}
    for sid, events in eps.items():
        eng = EMSServe(splits, params, cached=True, real_time=True,
                       bucketer=Bucketer(max_buckets={"vitals": 8}))
        eng.run_episode(events, lambda ev: payloads[ev.modality],
                        aggregate=_aggregate)
        want[sid] = eng.records[-1].recommendation

    beng = BatchedEMSServe(splits, params,
                           bucketer=Bucketer(max_buckets={"vitals": 8}))
    beng.run_episodes(eps, lambda sid, ev: payloads[ev.modality],
                      aggregate=_aggregate)
    for sid in eps:
        got = beng.sessions[sid].last_recommendation
        np.testing.assert_allclose(got["protocol_logits"],
                                   want[sid]["protocol_logits"], atol=1e-5)


def test_batched_flush_coalesces_calls(models):
    """A flush runs ONE encoder call per (modality, bucket) per consumer,
    not one per session."""
    cfg, splits, params, payloads = models
    beng = BatchedEMSServe(splits, params,
                           bucketer=Bucketer(max_buckets={"vitals": 8}))
    ev = Event(0, "vitals", 0.0)
    for i in range(6):
        beng.submit(f"s{i}", ev, payloads["vitals"], aggregate=_aggregate)
    rep = beng.flush()
    assert rep.n_events == 6
    assert rep.n_encoder_calls == 2       # m2 and m3 consume vitals
    assert rep.n_tail_calls == 0          # no text yet -> no model selected


def test_compile_count_plateaus_with_growing_vitals(models):
    """Once the bucket grid is warm, growing vitals streams add ZERO
    XLA compiles (the recompile-bound acceptance criterion)."""
    cfg, splits, params, payloads = models
    beng = BatchedEMSServe(splits, params,
                           bucketer=Bucketer(min_bucket=4,
                                             max_buckets={"vitals": 4}),
                           batch_bucket_min=2)
    sids = ("a", "b")
    t = 0

    def send(kind):
        nonlocal t
        for sid in sids:
            beng.submit(sid, Event(t, kind, float(t)), payloads[kind],
                        aggregate=_aggregate)
        beng.flush()
        t += 1

    # warmup: every modality + enough vitals growth to hit the max bucket
    for kind in ("text", "scene", "vitals", "vitals", "vitals", "vitals",
                 "vitals"):
        send(kind)
    warm = beng.compile_count()
    for _ in range(6):                     # streams keep growing
        send("vitals")
    assert beng.compile_count() == warm
    # but the streams really did grow past the bucket
    assert beng.sessions["a"].inputs["vitals"].shape[1] > 4


def test_per_event_engine_bucketed_bounds_compiles(models):
    """EMSServe with a bucketer also plateaus on growing streams."""
    cfg, splits, params, payloads = models
    eng = EMSServe(splits, params, cached=True, real_time=True,
                   bucketer=Bucketer(min_bucket=4, max_buckets={"vitals": 4}))
    ev = lambda t, k: Event(t, k, float(t))
    eng.on_event(ev(0, "text"), payloads["text"])
    for t in range(1, 6):
        eng.on_event(ev(t, "vitals"), payloads["vitals"],
                     aggregate=_aggregate)
    warm = eng.compile_count()
    for t in range(6, 12):
        eng.on_event(ev(t, "vitals"), payloads["vitals"],
                     aggregate=_aggregate)
    assert eng.compile_count() == warm


def test_cumulative_running_total(models):
    """cumulative_s is a running total (O(1) per event) and still equals
    the sum over records."""
    cfg, splits, params, payloads = models
    eng = EMSServe(splits, params, cached=True, real_time=True)
    eng.run_episode(table6()[1], lambda ev: payloads[ev.modality])
    total = sum(r.total_s for r in eng.records)
    assert eng.cumulative_time() == pytest.approx(total)
    assert [r.cumulative_s for r in eng.records] == sorted(
        r.cumulative_s for r in eng.records)
