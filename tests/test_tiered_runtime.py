"""Tiered glass<->edge split-serving runtime: placement parity,
transport byte accounting, heartbeat crash detection + on-glass
failover with cache recovery, and the wall-clock event-loop driver.

The load-bearing invariant (ISSUE acceptance): TieredEMSServe's
predictions equal the monolithic ``SplitModel.full`` baseline for EVERY
tier placement — adaptive, forced-glass, forced-edge — including after
an injected edge crash mid-episode, with the feature cache's <=1-step
staleness invariant asserted live on every re-fusion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, ProfileTable, emsnet_zoo,
                        nlos_bandwidth, split)
from repro.core.episodes import Event, async_episode
from repro.models import emsnet as E
from repro.serving.event_loop import WallClockDriver
from repro.serving.tiered_runtime import TieredEMSServe, TierHost
from repro.serving.transport import TransportChannel, payload_nbytes

ALL = ("text", "vitals", "scene")

BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _engine(splits, params, *, bw_m=0.0, trace=None, **kw):
    kw.setdefault("share_encoders", True)
    return TieredEMSServe(
        splits, params, profile=ProfileTable(base=dict(BASE)),
        trace=trace or BandwidthTrace.static(nlos_bandwidth(bw_m)), **kw)


def _episode():
    return [Event(i, m, float(i)) for i, m in enumerate(ALL)]


def _assert_close(got, want, atol=1e-5):
    for k in want:
        np.testing.assert_allclose(got[k], want[k], atol=atol)


# --------------------------------------------- tiered <-> monolithic parity

@pytest.mark.parametrize("force", [None, "glass", "edge"],
                         ids=["adaptive", "all-glass", "all-edge"])
def test_every_placement_matches_monolithic_full(force, zoo_models):
    """Placement changes the clock, never the math: final outputs equal
    the one-shot full forward, intermediates equal partial_forward."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, force=force)
    for ev in _episode():
        rec = eng.submit("s0", ev, payloads[ev.modality])
        assert rec.outputs is not None
        subset = ALL[:ev.index + 1]
        want = E.partial_forward(shared, cfg, payloads, subset)
        _assert_close(rec.outputs, want)
        if force is not None:
            assert rec.tier == force
    final = eng.sessions["s0"].records[-1]
    assert final.kind == "final"
    _assert_close(final.outputs, E.forward(shared, cfg, payloads))


def test_parity_after_midepisode_edge_crash(zoo_models):
    """Edge dies while an offload is in flight: the runtime detects the
    missed heartbeat, re-runs on glass, resumes from the cache (<=1-step
    staleness asserted by every re-fusion), and the outputs still match
    the monolithic baseline bit for bit."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0)   # great link: everything edge
    # crash while the 3rd arrival's offload is in flight (dispatched at
    # t=2.0, edge compute finishes ~2.19): the result never comes back
    eng.inject_edge_crash(2.1)
    for ev in _episode():
        rec = eng.submit("s0", ev, payloads[ev.modality])
        assert rec.outputs is not None
        _assert_close(rec.outputs,
                      E.partial_forward(shared, cfg, payloads,
                                        ALL[:ev.index + 1]))
    recs = eng.sessions["s0"].records
    assert [r.tier for r in recs] == ["edge", "edge", "glass"]
    assert recs[2].fallback and eng.fallback_count == 1
    assert eng.edge_known_dead
    # detection waited for the first missed heartbeat after the crash
    assert eng.detect_at == 3.0
    assert recs[2].t_start >= eng.detect_at
    # post-crash serving is pinned on-glass
    rec = eng.submit("s0", Event(3, "vitals", 3.0), payloads["vitals"])
    assert rec.tier == "glass" and not rec.fallback
    _assert_close(eng.sessions["s0"].records[-1].outputs,
                  E.forward(shared, cfg, payloads))


def test_crash_before_detection_window_pays_timeout(zoo_models):
    """An arrival in the undetected window (crash happened, heartbeat
    not yet missed) dispatches to the dead edge and stalls until the
    detection tick before falling back."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0, hb_period=1.0)
    eng.inject_edge_crash(0.25)
    rec = eng.submit("s0", Event(0, "text", 0.5), payloads["text"])
    assert rec.tier == "glass" and rec.fallback
    assert rec.detect_s == pytest.approx(0.5)      # stalled 0.5 -> 1.0
    assert rec.t_start >= 1.0
    _assert_close(rec.outputs,
                  E.partial_forward(shared, cfg, payloads, ("text",)))


def test_crash_during_downlink_transfer_loses_result(zoo_models):
    """The edge must survive through the END of its downlink
    transmission: a death mid-transfer loses the result (no delivery,
    no glass-cache commit) and triggers the same failover path as one
    mid-encode."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0, link_latency_s=0.5)
    # edge compute done ~0.65s, downlink delivers ~1.15s: die between
    eng.inject_edge_crash(0.9)
    rec = eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec.fallback and rec.tier == "glass"
    assert eng.downlink.msgs_sent == 0           # nothing ever arrived
    assert eng.cache.peek("s0", "text").tier == "glass"
    _assert_close(rec.outputs,
                  E.partial_forward(shared, cfg, payloads, ("text",)))


def test_adaptive_beats_forced_placements_on_the_clock(zoo_models):
    """Simulated-clock sanity: adaptive <= forced glass at close range,
    adaptive <= forced edge under a degraded link."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = {f"s{i}": async_episode("text_first", seed=i, n_vitals=3,
                                  n_scene=2) for i in range(2)}

    def total(trace, force):
        eng = _engine(splits, params, trace=trace, force=force)
        eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality])
        return eng.total_latency_s()

    near = BandwidthTrace.static(nlos_bandwidth(0.0))
    far = BandwidthTrace.static(nlos_bandwidth(60.0))
    assert total(near, None) < total(near, "glass")
    assert total(far, None) <= total(far, "edge") * 1.05


def test_offload_ships_bytes_and_fallback_does_not(zoo_models):
    """Byte accounting: edge placements pay uplink (raw payload + cache
    sync) and downlink (feature + outputs); a crashed offload wastes the
    uplink but ships nothing back."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0)
    eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    up0, down0 = eng.uplink.msgs_sent, eng.downlink.msgs_sent
    assert up0 == 1 and down0 == 1
    # downlink carried the text feature + the 3 head outputs
    feat = eng.cache.peek("s0", "text").feature
    outs = eng.sessions["s0"].records[0].outputs
    want = (payload_nbytes(feat) + payload_nbytes(outs)
            + eng.downlink.overhead_bytes)
    assert eng.downlink.bytes_sent == want
    eng.inject_edge_crash(0.9)
    eng.submit("s0", Event(1, "vitals", 0.95), payloads["vitals"])
    assert eng.sessions["s0"].records[-1].fallback
    assert eng.uplink.msgs_sent == up0 + 1        # wasted dispatch
    assert eng.downlink.msgs_sent == down0        # nothing came back


def test_edge_replica_sync_only_ships_stale_features(zoo_models):
    """The uplink re-ships a cached feature to the edge only when the
    edge replica is stale: two consecutive edge re-fusions of the same
    modalities sync nothing the second time."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0)
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    b0 = eng.uplink.bytes_sent
    # vitals re-arrival: the edge already holds text+scene features
    eng.submit("s0", Event(3, "vitals", 4.0), payloads["vitals"])
    shipped = eng.uplink.bytes_sent - b0
    vitals_declared = splits["vitals"].module.payload_bytes["vitals"]
    assert shipped == vitals_declared + eng.uplink.overhead_bytes


# ------------------------------------------- N-tier exhaustive placement

def test_exhaustive_per_submodule_placements_match_full_atol0(zoo_models):
    """EVERY per-submodule tier assignment over a 3-tier config — all
    3^4 (enc:text, enc:vitals, enc:scene, tail) placements — lands on
    the forced hosts and produces BIT-IDENTICAL outputs: the final
    fused prediction equals the monolithic ``SplitModel.full`` at
    atol 0, and every intermediate row is bitwise equal across all 81
    assignments. Placement changes the clock, never the math."""
    import itertools
    cfg, splits, shared, params, payloads = zoo_models
    tiers = ("glass", "ph1", "edge64x")
    submods = splits["text+vitals+scene"].submodules()
    assert submods == ("enc:text", "enc:vitals", "enc:scene", "tail")
    want = splits["text+vitals+scene"].full(shared, payloads)
    ref_rows = None
    for combo in itertools.product(tiers, repeat=len(submods)):
        force = dict(zip(submods, combo))
        eng = _engine(splits, params, tiers=tiers, force=force,
                      trace=BandwidthTrace.static(nlos_bandwidth(5.0)))
        rows = []
        for ev in _episode():
            rec = eng.submit("s0", ev, payloads[ev.modality])
            assert rec.enc_tier == force[f"enc:{ev.modality}"], combo
            assert rec.outputs is not None
            assert rec.tail_tier == force["tail"], combo
            rows.append(rec.outputs)
        final = eng.sessions["s0"].records[-1]
        assert final.kind == "final"
        for k in want:
            np.testing.assert_array_equal(final.outputs[k], want[k],
                                          err_msg=str(combo))
        if ref_rows is None:
            ref_rows = rows
        else:
            for got, ref in zip(rows, ref_rows):
                for k in ref:
                    np.testing.assert_array_equal(got[k], ref[k],
                                                  err_msg=str(combo))


def test_contention_aware_decisions_spread_sessions(zoo_models):
    """With two remotes of similar speed, queue-aware decisions fan
    concurrent same-instant arrivals across both instead of stampeding
    the faster one; the contention-blind rule sends everything to the
    single argmin tier."""
    cfg, splits, shared, params, payloads = zoo_models
    # custom factor table: phone nearly as fast as the edge box, so a
    # single queued event flips the argmin
    profile = ProfileTable(
        base=dict(BASE),
        factors={"glass": 40.0, "ph1": 1.2, "edge4c": 2.7,
                 "edge64x": 1.0})

    def run(contention_aware):
        eng = TieredEMSServe(
            splits, params, share_encoders=True, profile=profile,
            trace=BandwidthTrace.static(1e9),
            tiers=("glass", "ph1", "edge64x"),
            contention_aware=contention_aware)
        for i in range(4):
            eng.submit(f"s{i}", Event(0, "text", 0.0), payloads["text"])
        return eng

    aware = run(True)
    assert aware.place_counts["ph1"] > 0 \
        and aware.place_counts["edge64x"] > 0
    blind = run(False)
    assert blind.place_counts["edge64x"] == 4
    # spreading helped: last emission lands earlier than the stampede's
    assert aware.makespan_s() <= blind.makespan_s()


def test_legacy_two_tier_surface_is_unchanged(zoo_models):
    """The historical attribute surface still works on the legacy pair:
    ``edge``/``uplink``/``downlink``/``crash_at`` map onto the (single)
    remote, and N-tier capabilities stay off by default there."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _engine(splits, params, bw_m=0.0)
    assert not eng.contention_aware and not eng.tail_placement
    assert eng.edge.name == "edge" and eng.glass.name == "glass"
    eng.inject_edge_crash(1.5)
    assert eng.crash_at == 1.5 and eng.detect_at == 2.0
    assert not eng.edge_known_dead
    eng.submit("s0", Event(0, "text", 2.5), payloads["text"])
    assert eng.edge_known_dead


# ------------------------------------------------------------- transport

def test_transport_in_order_delivery_under_bandwidth_dip():
    """A message sent during a bandwidth dip blocks the next one: the
    later send cannot be delivered before the earlier (TCP-like)."""
    tr = BandwidthTrace([(0.0, 1000.0), (1.0, 10.0), (2.0, 1000.0)])
    ch = TransportChannel(tr, latency_s=0.0, overhead_bytes=0)
    slow = ch.send(100, 1.0)          # 10 s of serialization at 10 B/s
    fast = ch.send(100, 2.1)          # would take 0.1 s on its own
    assert slow.t_deliver == pytest.approx(11.0)
    assert fast.t_deliver >= slow.t_deliver
    assert fast.queued_s > 0
    assert ch.bytes_sent == 200 and ch.msgs_sent == 2


def test_payload_nbytes_counts_pytree_leaves():
    tree = {"x": jnp.zeros((2, 3), jnp.float32),
            "len": jnp.zeros((2,), jnp.int32), "scalar": 1.5}
    assert payload_nbytes(tree) == 2 * 3 * 4 + 2 * 4 + 8


def test_tier_host_occupies_serially():
    host = TierHost("edge", "edge4c", ProfileTable(base=dict(BASE)))
    s0, d0 = host.occupy(1.0, 0.0)
    s1, d1 = host.occupy(1.0, 0.5)      # arrives while busy -> queues
    assert (s0, d0) == (0.0, 1.0)
    assert (s1, d1) == (1.0, 2.0)
    assert host.busy_s == pytest.approx(2.0)


# ------------------------------------------------- wall-clock event loop

class FakeClock:
    """Deterministic wall clock: sleep() advances it."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def sleep(self, dt):
        self.t += max(dt, 1e-4)


def test_wall_clock_driver_fires_deadline_flush_in_a_lull(zoo_models):
    """The trailing arrivals of a lull flush when their deadline expires
    on the monotonic clock — no manual tick() anywhere."""
    from repro.serving.stream_engine import StreamingEMSServe
    cfg, splits, shared, params, payloads = zoo_models
    clk = FakeClock()
    eng = StreamingEMSServe(splits, params, share_encoders=True,
                            deadline_s=0.5, time_fn=clk)
    eps = {"s0": [Event(0, "text", 0.0), Event(1, "vitals", 0.1)]}
    drv = WallClockDriver(eng, clock=clk, sleep_fn=clk.sleep,
                          poll_interval_s=0.05)
    stats = drv.run(eps, lambda sid, ev: payloads[ev.modality])
    assert stats.arrivals == 2
    # the flush came from a poll after the deadline, not from a submit
    assert stats.flushes_fired >= 1
    assert eng.flushes_total == 1
    pred = eng.sessions["s0"].predictions[-1]
    assert pred.modalities == ("text", "vitals")
    _assert_close(pred.outputs,
                  E.partial_forward(shared, cfg, payloads,
                                    ("text", "vitals")))


def test_wall_clock_driver_paces_tiered_runtime(zoo_models):
    """The driver drives TieredEMSServe arrival by arrival (poll is a
    no-op there) and produces the same records as a direct replay."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = {"s0": _episode()}

    clk = FakeClock()
    eng = _engine(splits, params, bw_m=5.0)
    WallClockDriver(eng, clock=clk, sleep_fn=clk.sleep,
                    speed=10.0).run(eps, lambda s, ev: payloads[ev.modality])

    ref = _engine(splits, params, bw_m=5.0)
    ref.run_arrivals(eps, lambda s, ev: payloads[ev.modality])

    assert len(eng.records) == len(ref.records) == 3
    for a, b in zip(eng.records, ref.records):
        assert (a.tier, a.kind, a.t_emit) == (b.tier, b.kind, b.t_emit)
        _assert_close(a.outputs, b.outputs)
