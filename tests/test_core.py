"""EMSServe core: splitter equivalence, feature cache invariants,
offloading decisions, episodes, med-math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AdaptiveOffloadPolicy, BandwidthTrace, EMSServe,
                        FeatureCache, HeartbeatMonitor, ProfileTable,
                        StalenessError, emsnet_module, nlos_bandwidth, split,
                        table6)
from repro.core import episodes as EP
from repro.core import medmath as MM


@pytest.fixture(scope="module")
def tiny_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    key = jax.random.PRNGKey(0)
    mods = {
        "m1": emsnet_module(cfg, ("text",)),
        "m2": emsnet_module(cfg, ("text", "vitals")),
        "m3": emsnet_module(cfg, ("text", "vitals", "scene")),
    }
    splits = {k: split(m) for k, m in mods.items()}
    params = {k: m.init_fn(jax.random.fold_in(key, i))
              for i, (k, m) in enumerate(mods.items())}
    return cfg, splits, params


def _payloads(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size,
                                         (1, cfg.max_text_len)), jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, cfg.vitals_len,
                                               cfg.n_vitals)), jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }


# ------------------------------------------------------------- splitter

def test_split_equals_full(tiny_models):
    """tail(encoders(x)) == full(x): the split is lossless."""
    cfg, splits, params = tiny_models
    batch = _payloads(cfg)
    sm = splits["m3"]
    feats = {m: sm.encoders[m](params["m3"], batch[m])
             for m in sm.modalities()}
    via_split = sm.tail(params["m3"], feats)
    via_full = sm.full(params["m3"], batch)
    for k in via_full:
        np.testing.assert_allclose(via_split[k], via_full[k], atol=1e-5)


class _FakeSplit:
    """select_model only needs .modalities()."""
    def __init__(self, *mods):
        self._mods = tuple(mods)

    def modalities(self):
        return self._mods


def test_select_model_prefers_largest_subset_deterministically():
    """Regression: when several models consume equally many observed
    modalities, the winner must not depend on dict insertion order."""
    from repro.core.splitter import select_model
    tv, ts, vs = (_FakeSplit("text", "vitals"), _FakeSplit("text", "scene"),
                  _FakeSplit("vitals", "scene"))
    observed = {"text", "vitals", "scene"}
    winners = {select_model(dict(order), observed)
               for order in [
                   [("a", tv), ("b", ts), ("c", vs)],
                   [("c", vs), ("b", ts), ("a", tv)],
                   [("b", ts), ("a", tv), ("c", vs)]]}
    assert winners == {"a"}      # ("text","vitals") sorts above the others
    # largest subset still beats any tie-break
    full = _FakeSplit("text", "vitals", "scene")
    assert select_model({"a": tv, "z": full}, observed) == "z"
    assert select_model({"z": full, "a": tv}, observed) == "z"
    # same modality set under two names: the greater name wins, any order
    assert select_model({"x": tv, "y": _FakeSplit("text", "vitals")},
                        {"text", "vitals"}) == "y"
    assert select_model({"y": _FakeSplit("text", "vitals"), "x": tv},
                        {"text", "vitals"}) == "y"
    # nothing satisfiable -> None
    assert select_model({"a": tv}, {"scene"}) is None


# -------------------------------------------------------- feature cache

def test_cache_staleness_invariant():
    c = FeatureCache(max_staleness=1)
    c.put("s", "text", 1.0, step=1)
    assert c.get("s", "text", input_step=2).feature == 1.0   # 1 step: OK
    with pytest.raises(StalenessError):
        c.get("s", "text", input_step=3)


def test_cache_versioning_and_tiers():
    c = FeatureCache()
    c.put("s", "v", 1, step=1, tier="edge")
    c.put("s", "v", 2, step=2, tier="edge")
    assert c.get("s", "v").version == 1
    c.drop_tier("edge")
    assert c.get("s", "v") is None
    assert c.misses == 1


def test_cache_touch_restamps():
    c = FeatureCache(max_staleness=1)
    c.put("s", "t", 0, step=1)
    c.touch("s", "t", 5)
    assert c.get("s", "t", input_step=5).feature == 0


def test_cache_double_commit_is_structural_noop():
    """Idempotent commits (ISSUE 6): re-committing the step the entry
    already holds — a losing speculative racer landing late — changes
    NOTHING: feature, step, and version all stand (the version not
    bumping is what keeps tier replicas from re-shipping), and the
    refusal is audited."""
    c = FeatureCache()
    assert c.put("s", "t", 1.0, step=3, tier="glass")
    assert not c.put("s", "t", 2.0, step=3, tier="edge")
    e = c.get("s", "t")
    assert (e.feature, e.step, e.version, e.tier) == (1.0, 3, 0, "glass")
    assert c.duplicate_commits == 1 and c.stale_commits == 0


def test_cache_stale_late_commit_refused():
    """Monotone commits (ISSUE 6): a commit at an OLDER step than the
    stored entry — a crash-delayed straggler — is refused outright, so
    a late flight can never regress the staleness clock."""
    c = FeatureCache(max_staleness=1)
    assert c.put("s", "t", 1.0, step=5)
    assert not c.put("s", "t", 0.0, step=4, tier="edge")
    e = c.get("s", "t", input_step=6)     # still 1 step: still fresh
    assert (e.feature, e.step, e.version) == (1.0, 5, 0)
    assert c.stale_commits == 1 and c.duplicate_commits == 0
    # a genuinely newer commit still lands and bumps the version
    assert c.put("s", "t", 2.0, step=6)
    assert c.get("s", "t").version == 1


# ----------------------------------------------------------- offloading

def test_offload_rule_exact():
    prof = ProfileTable(base={"enc:text": 0.1}, host_tier="edge4c")
    mon = HeartbeatMonitor(BandwidthTrace.static(1e6))
    pol = AdaptiveOffloadPolicy(prof, mon)
    # t_edge = 0.1, t_glass = 0.1*107/2.7 ≈ 3.96
    d = pol.decide("enc:text", payload_bytes=int(0.5e6), now=0.0)  # dt=0.5
    assert d.tier == "edge" and d.delta_t == pytest.approx(0.5)
    d = pol.decide("enc:text", payload_bytes=int(10e6), now=0.0)   # dt=10
    assert d.tier == "glass"


def test_heartbeat_quantization():
    tr = BandwidthTrace([(0.0, 100.0), (1.0, 200.0)])
    mon = HeartbeatMonitor(tr, period=1.0)
    assert mon.bandwidth(0.4) == 100.0
    assert mon.bandwidth(1.7) == 200.0


def test_nlos_bandwidth_monotone():
    bws = [nlos_bandwidth(d) for d in (0, 5, 10, 20, 30)]
    assert all(a > b for a, b in zip(bws, bws[1:]))


def test_bandwidth_trace_piecewise_constant_boundaries():
    """bisect boundary semantics: right-continuous steps, clamped at
    and before the first point, held after the last."""
    tr = BandwidthTrace([(1.0, 10.0), (2.0, 20.0), (4.0, 40.0)])
    assert tr.at(-5.0) == 10.0      # before the first point: clamp back
    assert tr.at(0.0) == 10.0
    assert tr.at(1.0) == 10.0       # exactly ON a breakpoint: its value
    assert tr.at(1.999) == 10.0     # just before the next: old value
    assert tr.at(2.0) == 20.0       # a new measurement applies at its t
    assert tr.at(3.0) == 20.0
    assert tr.at(4.0) == 40.0
    assert tr.at(100.0) == 40.0     # after the last point: hold


def test_bandwidth_trace_sorts_validates_and_breaks_ties():
    # unsorted points are normalized at construction
    tr = BandwidthTrace([(2.0, 20.0), (0.0, 5.0)])
    assert tr.at(1.0) == 5.0 and tr.at(2.0) == 20.0
    # duplicate timestamps: the last-listed measurement wins
    tr = BandwidthTrace([(0.0, 1.0), (1.0, 2.0), (1.0, 3.0)])
    assert tr.at(1.0) == 3.0 and tr.at(1.5) == 3.0
    # an empty trace fails eagerly, not inside a lookup mid-serve
    with pytest.raises(ValueError):
        BandwidthTrace([])


def test_heartbeat_monitor_sees_quantized_trace_at_boundaries():
    """The monitor samples on the heartbeat grid: a bandwidth change at
    t=1.0 is visible exactly from the 1.0 tick, not before."""
    tr = BandwidthTrace([(0.0, 100.0), (1.0, 200.0)])
    mon = HeartbeatMonitor(tr, period=1.0)
    assert mon.bandwidth(0.999) == 100.0
    assert mon.bandwidth(1.0) == 200.0
    # delta_t uses the same quantized measurement
    assert mon.delta_t(400, 1.3) == pytest.approx(2.0)


# -------------------------------------------------------------- episodes

def test_table6_matches_paper():
    eps = table6()
    for i in (1, 2, 3):
        kinds = [e.modality for e in eps[i]]
        assert len(kinds) == 21
        assert kinds.count("text") == 1
        assert kinds.count("vitals") == 10
        assert kinds.count("scene") == 10
    assert [e.modality for e in eps[1][:2]] == ["text", "vitals"]


def test_random_episode_has_text():
    ev = EP.random_episode(15, seed=3)
    assert any(e.modality == "text" for e in ev)
    assert [e.index for e in ev] == list(range(15))


@pytest.mark.parametrize("scenario", sorted(EP.LAG_SCENARIOS))
def test_async_episode_invariants(scenario):
    ev = EP.async_episode(scenario, seed=7, n_vitals=4, n_scene=3)
    assert len(ev) == 1 + 4 + 3
    assert sum(e.modality == "text" for e in ev) == 1
    times = [e.arrival_time for e in ev]
    assert times == sorted(times) and all(t >= 0 for t in times)
    assert [e.index for e in ev] == list(range(len(ev)))
    # deterministic per seed
    again = EP.async_episode(scenario, seed=7, n_vitals=4, n_scene=3)
    assert ev == again


def test_async_episode_scenarios_reorder_modalities():
    """The presets really change which modality arrives first."""
    first = {s: EP.async_episode(s, seed=0, n_vitals=2, n_scene=2)[0].modality
             for s in ("text_first", "vitals_first")}
    assert first["text_first"] == "text"
    assert first["vitals_first"] == "vitals"
    # scene-late: the scene feed onsets after text and vitals
    ev = EP.async_episode("scene_late", seed=0, n_vitals=2, n_scene=2)
    t_scene = min(e.arrival_time for e in ev if e.modality == "scene")
    t_other = max(e.arrival_time for e in ev
                  if e.modality == "text")
    assert t_scene > t_other


def test_async_episode_custom_lags():
    ev = EP.async_episode(lags={"text": (0.0, 0.0), "vitals": (1.0, 0.0)},
                          seed=0, n_vitals=2)
    assert [e.modality for e in ev] == ["text", "vitals", "vitals"]
    assert ev[1].arrival_time == pytest.approx(1.0)


# ---------------------------------------------------------------- engine

def test_engine_cached_matches_direct_outputs(tiny_models):
    """The feature cache must not change recommendations, only cost."""
    cfg, splits, params = tiny_models
    payloads = _payloads(cfg)
    outs = {}
    for cached in (False, True):
        eng = EMSServe(splits, params, cached=cached, real_time=True)
        eng.run_episode(table6()[2], lambda ev: payloads[ev.modality])
        recs = [r.recommendation for r in eng.records
                if r.recommendation is not None]
        outs[cached] = recs
    assert len(outs[True]) == len(outs[False])
    for a, b in zip(outs[True], outs[False]):
        np.testing.assert_allclose(a["protocol_logits"],
                                   b["protocol_logits"], atol=1e-5)


def test_engine_cache_cheaper_than_direct(tiny_models):
    cfg, splits, params = tiny_models
    payloads = _payloads(cfg)
    times = {}
    for cached in (False, True):
        eng = EMSServe(splits, params, cached=cached, real_time=True)
        # warmup compile
        eng.run_episode(table6()[1], lambda ev: payloads[ev.modality])
        eng2 = EMSServe(splits, params, cached=cached, real_time=True)
        eng2.run_episode(table6()[1], lambda ev: payloads[ev.modality])
        times[cached] = eng2.cumulative_time()
    assert times[True] < times[False]


def test_engine_fault_tolerance(tiny_models):
    """Edge crash mid-episode: serving continues on-glass, recommendations
    keep flowing, staleness invariant holds throughout."""
    cfg, splits, params = tiny_models
    payloads = _payloads(cfg)
    prof_base = {"enc:text": 0.05, "enc:vitals": 0.001, "enc:scene": 0.001,
                 "tail": 0.001, "full": 0.06}
    pol = AdaptiveOffloadPolicy(
        ProfileTable(base=prof_base),
        HeartbeatMonitor(BandwidthTrace.static(nlos_bandwidth(0))))
    eng = EMSServe(splits, params, policy=pol, cached=True)
    events = table6()[1]
    for i, ev in enumerate(events):
        if i == 8:
            eng.crash_edge()
        rec = eng.on_event(ev, payloads[ev.modality])
        if i > 8:
            assert rec.tier == "glass"
    assert eng.records[-1].recommendation is not None


def test_engine_adaptive_beats_forced_edge_under_mobility(tiny_models):
    """Scenario 3: with degrading bandwidth, adaptive < always-offload."""
    cfg, splits, params = tiny_models
    payloads = _payloads(cfg)
    prof_base = {"enc:text": 0.05, "enc:vitals": 0.001, "enc:scene": 0.005,
                 "tail": 0.001, "full": 0.06}
    dist = list(np.linspace(0, 60, 21))     # walking away
    results = {}
    for adaptive in (True, False):
        pol = AdaptiveOffloadPolicy(
            ProfileTable(base=prof_base),
            HeartbeatMonitor(BandwidthTrace.walk(dist, nlos_bandwidth)),
            adaptive=adaptive)
        eng = EMSServe(splits, params, policy=pol, cached=True)
        eng.run_episode(table6()[1], lambda ev: payloads[ev.modality])
        results[adaptive] = eng.cumulative_time()
    assert results[True] <= results[False]


# -------------------------------------------------------------- med math

def test_med_math_paper_example():
    assert MM.med_math(21.0, 4.2) == pytest.approx(5.0)


def test_med_math_rejects_bad_concentration():
    with pytest.raises(ValueError):
        MM.med_math(1.0, 0.0)


def test_ed_match_corrects_ocr_noise():
    assert MM.ed_match("nal0xone") == "naloxone"
    assert MM.ed_match("atrovnet") == "atrovent"
    assert MM.ed_match("zzzzqqqq") is None


def test_dosage_pipeline():
    out = MM.dosage_from_label(10.0, "naloxon")
    assert out["medicine"] == "naloxone"
    assert out["dosage_ml"] == pytest.approx(
        10.0 / out["concentration_mg_per_ml"])
    assert len(out["disease_history"]) > 0
    assert all(0 <= d < MM.N_DISEASES for d in out["disease_history"])
