"""Quantized glass tier: kernel parity, the int8 sidecar parameter
factory, packed feature transport, and the joint (tier, precision)
placement co-decision.

Tolerances documented here ARE the contract:

  * quantize->dequantize round trip: <= scale/2 per element (symmetric
    round-to-nearest over 127 levels);
  * fused int8 GEMM vs the int8 reference: exact (both accumulate in
    int32 and apply the identical scale product);
  * quantized_matmul vs the fp32 GEMM: the analytical first-order bound
    ``|err_ij| <= sw_j/2 * sum_k|x_ik| + sx_i/2 * sum_k|w_hat_kj|``
    elementwise (quantization error propagated through the dot);
  * precision OFF: the tiered engine is bit-identical (atol 0) to the
    precision-less engine on every LAG_SCENARIOS arrival ordering.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, LAG_SCENARIOS, ProfileTable,
                        async_episode, emsnet_zoo, nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.core.modular import MultimodalModule
from repro.core.offload import HeartbeatMonitor, MultiTierPolicy
from repro.core.splitter import payload_nbytes
from repro.kernels import ops, ref
from repro.models import quantized as Q
from repro.serving.api import build_engine

ALL = ("text", "vitals", "scene")
TIERS = ("glass", "ph1", "edge64x")
BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}

# (M, K, N) including non-divisible-by-block padding paths
GEMM_SHAPES = [(8, 32, 16), (32, 64, 128), (33, 100, 130), (1, 7, 5),
               (64, 128, 256)]


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _lag_episodes():
    return {f"s{i}": async_episode(name, seed=i * 7, n_vitals=2,
                                   n_scene=2)
            for i, name in enumerate(sorted(LAG_SCENARIOS))}


def _tiered(splits, params, *, bandwidth=5.0, **kw):
    kw.setdefault("max_history", None)
    kw.setdefault("tier_traces",
                  {"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))})
    kw.setdefault("trace", BandwidthTrace.static(nlos_bandwidth(bandwidth)))
    kw.setdefault("tiers", TIERS)
    return build_engine(
        splits, params, "tiered", share_encoders=True,
        profile=ProfileTable(base=dict(BASE)), **kw)


# ====================================================== kernel parity

@pytest.mark.parametrize("shape", [(4, 16), (33, 100), (1, 7), (32, 128)])
def test_quantize_roundtrip_error_bound(key, shape):
    """Round-trip error <= scale/2 per element, per row."""
    x = jax.random.normal(key, shape) * 3.0
    q, s = ops.quantize_rowwise(x, interpret=True)
    assert q.dtype == jnp.int8 and s.shape == (shape[0], 1)
    back = ops.dequantize_rowwise(q, s, interpret=True)
    bound = np.asarray(s) / 2.0 + 1e-7
    assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()


def test_quantize_zero_row_guard(key):
    """An all-zero row must quantize to zeros with a finite scale, not
    divide by zero."""
    x = jnp.zeros((3, 16)).at[1].set(jax.random.normal(key, (16,)))
    q, s = ops.quantize_rowwise(x, interpret=True)
    assert np.isfinite(np.asarray(s)).all()
    assert np.abs(np.asarray(q)[0]).max() == 0
    assert np.abs(np.asarray(q)[2]).max() == 0


@pytest.mark.parametrize("shape", [(4, 16), (33, 100)])
def test_quantize_rowwise_matches_ref(key, shape):
    """Kernel q values match the jnp oracle exactly; scales to 1 ulp
    (jit may turn /127 into a multiply by reciprocal)."""
    x = jax.random.normal(key, shape) * 2.0
    q, s = ops.quantize_rowwise(x, interpret=True)
    qr, sr = ref.quantize_rowwise_ref(x)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("shape", GEMM_SHAPES)
def test_int8_matmul_exact_vs_ref(key, shape):
    """Given identical int8 inputs, the fused Pallas GEMM and the jnp
    oracle agree EXACTLY: both accumulate in int32 (no overflow below
    MAX_K) and apply the same scale product."""
    M, K, N = shape
    k1, k2 = jax.random.split(key)
    xq, sx = ref.quantize_rowwise_ref(jax.random.normal(k1, (M, K)))
    wq, sw = ref.quantize_rowwise_ref(jax.random.normal(k2, (N, K)))
    wq, sw = wq.T, sw.T                      # colwise layout (K, N), (1, N)
    got = ops.int8_matmul(xq, sx, wq, sw, interpret=True)
    want = ref.int8_matmul_ref(xq, sx, wq, sw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", GEMM_SHAPES)
def test_quantized_matmul_within_analytical_bound(key, shape):
    """quantized_matmul vs the fp32 GEMM, elementwise under the
    propagated first-order quantization bound (the documented
    tolerance — not an arbitrary atol)."""
    M, K, N = shape
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (M, K))
    w = jax.random.normal(k2, (K, N)) / np.sqrt(K)
    wq, sw = ops.quantize_colwise(w, interpret=True)
    got = np.asarray(ops.quantized_matmul(x, wq, sw, interpret=True))
    want = np.asarray(x @ w)
    xn = np.asarray(x)
    w_hat = np.asarray(wq, np.float32) * np.asarray(sw)
    _, sx = ref.quantize_rowwise_ref(x)
    bound = (np.asarray(sw) / 2.0 * np.abs(xn).sum(1, keepdims=True)
             + np.asarray(sx) / 2.0 * np.abs(w_hat).sum(0, keepdims=True))
    assert (np.abs(got - want) <= bound + 1e-5).all()


def test_int8_matmul_k_guard():
    from repro.kernels.quantized import MAX_K
    K = MAX_K + 1
    xq = jnp.zeros((1, K), jnp.int8)
    wq = jnp.zeros((K, 4), jnp.int8)
    with pytest.raises(ValueError, match="int32 accumulator"):
        ops.int8_matmul(xq, jnp.ones((1, 1)), wq, jnp.ones((1, 4)),
                        interpret=True)


def test_quantized_matmul_leading_dims(key):
    """(B, S, K) activations flatten through the GEMM and reshape back."""
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (2, 5, 32))
    w = jax.random.normal(k2, (32, 16)) / np.sqrt(32)
    wq, sw = ops.quantize_colwise(w, interpret=True)
    got = ops.quantized_matmul(x, wq, sw, interpret=True)
    assert got.shape == (2, 5, 16)
    flat = ops.quantized_matmul(x.reshape(10, 32), wq, sw, interpret=True)
    np.testing.assert_array_equal(np.asarray(got).reshape(10, 16),
                                  np.asarray(flat))


# =========================================== hypothesis property tier

def test_roundtrip_property_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           m=st.integers(1, 9), k=st.integers(1, 65),
           scale=st.floats(1e-3, 1e3))
    def check(seed, m, k, scale):
        x = jax.random.normal(jax.random.PRNGKey(seed), (m, k)) * scale
        q, s = ops.quantize_rowwise(x, interpret=True)
        back = ops.dequantize_rowwise(q, s, interpret=True)
        bound = np.asarray(s) / 2.0 * (1 + 1e-6) + 1e-12
        assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()

    check()


# ============================================= sidecar param factory

def test_sidecar_shares_fp32_by_reference(zoo_models):
    """Only GEMM-heavy dense weights are replaced; embeddings, norms,
    the recurrent wh, and the heads are the SAME objects (id-shared),
    so fleet placement ships each fp32 tensor once."""
    cfg, splits, shared, params, payloads = zoo_models
    qp = Q.quantize_emsnet_params(shared)
    assert qp["heads"] is shared["heads"]
    assert qp["text"]["tok"] is shared["text"]["tok"]
    assert qp["text"]["blocks"][0]["ln1"] is \
        shared["text"]["blocks"][0]["ln1"]
    assert qp["vitals"]["wh"] is shared["vitals"]["wh"]
    blk = qp["text"]["blocks"][0]
    for k in ("wqkv", "wo", "w1", "w2"):
        assert set(blk[k]) >= {"w_q", "w_scale"} and "w" not in blk[k]
        assert blk[k]["w_q"].dtype == jnp.int8
    assert qp["vitals"]["wx"]["w_q"].dtype == jnp.int8
    assert qp["scene"]["fc"]["w_q"].dtype == jnp.int8


def test_quantized_encoders_track_fp32(zoo_models):
    """The sidecar pytree through the UNMODIFIED jitted encoders stays
    within a few percent of fp32 on every modality."""
    cfg, splits, shared, params, payloads = zoo_models
    sm = splits["text+vitals+scene"]
    qp = sm.quantize_params(shared)
    for m in ALL:
        f32 = np.asarray(sm.encoders[m](shared, payloads[m]))
        q = np.asarray(sm.encoders[m](qp, payloads[m]))
        rel = np.abs(q - f32).max() / (np.abs(f32).max() + 1e-9)
        assert rel < 0.08, (m, rel)


def test_quantize_params_requires_quantize_fn(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    from dataclasses import replace as dc_replace
    bare = dc_replace(splits["text"].module, quantize_fn=None)
    sm = split(bare)
    with pytest.raises(ValueError, match="quantize_fn"):
        sm.quantize_params(shared)


# ============================================ packed feature transport

def test_feature_pack_shrinks_payload_and_roundtrips(zoo_models):
    """payload_nbytes of the packed wire form is >= 3x smaller and the
    round trip stays within scale/2 per element."""
    cfg, splits, shared, params, payloads = zoo_models
    sm = splits["text+vitals+scene"]
    for m in ALL:
        f = sm.encoders[m](shared, payloads[m])
        pack = Q.quantize_feature(f)
        assert Q.is_quantized_feature(pack)
        assert not Q.is_quantized_feature(f)
        raw_b, pack_b = payload_nbytes(f), payload_nbytes(pack)
        # ~4x asymptotically; the per-row f32 scale is the only
        # overhead, so tiny features (scene d=8 here) still shrink but
        # land under 3x
        assert pack_b < raw_b, (m, raw_b, pack_b)
        if f.size >= 16:
            assert pack_b * 3 <= raw_b, (m, raw_b, pack_b)
        back = np.asarray(Q.dequantize_feature(pack))
        bound = np.asarray(pack["scale"]) / 2.0 + 1e-7
        assert (np.abs(back - np.asarray(f)) <= bound).all()
        # identity on raw features
        assert Q.dequantize_feature(f) is f


# ================================== joint (tier, precision) co-decision

def _policy(bw_mbps, **kw):
    trace = BandwidthTrace.static(bw_mbps * 1e6 / 8)
    mon = {"edge": HeartbeatMonitor(trace, period=1.0)}
    return MultiTierPolicy(
        ProfileTable(base=dict(BASE)), mon, local="glass",
        tier_of={"glass": "glass", "edge": "edge64x"}, **kw)


def test_joint_decision_int8_wins_on_slow_link():
    """A slow radio makes the int8 candidate's smaller feature return
    beat fp32 on the same tier — the precision rides on the decision."""
    pol = _policy(0.5, precisions={"edge": ("fp32", "int8")})
    dec = pol.decide("enc:text", 200_000, 0.0, feat_bytes=400_000)
    est = dec.estimates["edge"]
    assert est.precision == "int8"
    # and the engine-visible decision carries the winning precision
    assert dec.precision == dec.estimates[dec.tier].precision


def test_joint_decision_ties_keep_fp32():
    """With compute scale 1.0 and no feature bytes, int8 buys nothing —
    the per-tier argmin must keep fp32 (no gratuitous quantization)."""
    pol = _policy(100.0, precisions={"edge": ("fp32", "int8")},
                  int8_compute_scale=1.0)
    dec = pol.decide("enc:text", 1000, 0.0, feat_bytes=0)
    assert dec.estimates["edge"].precision == "fp32"
    assert dec.precision == "fp32"


def test_joint_enumeration_all_fp32_matches_legacy():
    """precisions armed but fp32-only == precisions=None, decision for
    decision across payloads and times (the enumeration's fp32 leg IS
    the legacy estimate at feat_bytes=0; with feat_bytes > 0 the armed
    model deliberately charges every remote candidate the feature
    return trip — that refinement exists only once the rung is on,
    which is why the ENGINE disarms entirely for all-fp32 maps)."""
    legacy = _policy(2.0)
    armed = _policy(2.0, precisions={"edge": ("fp32",)})
    for payload in (0, 10_000, 1_000_000):
        for t in (0.0, 3.5, 10.0):
            a = legacy.decide("enc:text", payload, t)
            b = armed.decide("enc:text", payload, t)
            assert a.tier == b.tier
            assert a.precision == b.precision == "fp32"
            for n in a.estimates:
                assert a.estimates[n].cost == b.estimates[n].cost
    # armed + feat_bytes: the remote fp32 candidate pays the return trip
    c = armed.decide("enc:text", 10_000, 0.0, feat_bytes=123)
    d = legacy.decide("enc:text", 10_000, 0.0, feat_bytes=123)
    assert c.estimates["edge"].transfer_s > d.estimates["edge"].transfer_s


def test_policy_rejects_bad_precision_map():
    with pytest.raises(ValueError, match="unknown host or precision"):
        _policy(2.0, precisions={"nope": ("int8",)})
    with pytest.raises(ValueError, match="unknown host or precision"):
        _policy(2.0, precisions={"edge": ("int4",)})


# ================================================= engine-level rungs

def test_engine_precision_off_bit_identical_lag_scenarios(zoo_models):
    """All-fp32 precision map == no precision map, bit for bit (atol 0)
    on every LAG_SCENARIOS arrival ordering: timelines, tiers, and
    output arrays."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = _lag_episodes()
    pay = lambda sid, ev: payloads[ev.modality]  # noqa: E731
    plain = _tiered(splits, params)
    mapped = _tiered(splits, params,
                     precision={"ph1": "fp32", "edge64x": "fp32"})
    plain.run_arrivals(eps, pay)
    mapped.run_arrivals(eps, pay)
    assert len(plain.records) == len(mapped.records) > 0
    for a, b in zip(plain.records, mapped.records):
        assert (a.sid, a.index, a.tier, a.enc_tier, a.tail_tier) == \
               (b.sid, b.index, b.tier, b.enc_tier, b.tail_tier)
        assert a.t_emit == b.t_emit and a.t_start == b.t_start
        assert a.precision == b.precision == "fp32"
        if a.outputs is not None:
            for k in a.outputs:
                np.testing.assert_array_equal(np.asarray(a.outputs[k]),
                                              np.asarray(b.outputs[k]))


def test_engine_int8_packs_cache_and_shrinks_feature_wire(zoo_models):
    """int8 flights commit the packed form to the glass cache and the
    remote->glass feature links carry >= 3x fewer bytes than the same
    workload served fp32."""
    cfg, splits, shared, params, payloads = zoo_models
    eps = _lag_episodes()
    pay = lambda sid, ev: payloads[ev.modality]  # noqa: E731
    f32 = _tiered(splits, params, bandwidth=30.0)
    q8 = _tiered(splits, params, bandwidth=30.0,
                 precision={"ph1": "int8", "edge64x": "int8"})
    f32.run_arrivals(eps, pay)
    q8.run_arrivals(eps, pay)
    q_recs = [r for r in q8.records if r.precision == "int8"]
    assert q_recs, "slow uplink never chose an int8 flight"
    # the cache holds the packed wire form for int8-encoded modalities
    packed = 0
    for r in q_recs:
        if r.model is None:
            continue
        e = q8.cache.peek(q8._cache_key(r.sid, r.model), r.modality)
        if e is not None and Q.is_quantized_feature(e.feature):
            packed += 1
    assert packed > 0

    # the FEATURE payload itself shrinks >= 3x (text is wide enough for
    # the asymptotic ratio on the tiny config)...
    sm = splits["text+vitals+scene"]
    raw_text = sm.encoders["text"](shared, payloads["text"])
    text_recs = [r for r in q_recs
                 if r.modality == "text" and r.model is not None]
    assert text_recs
    e = q8.cache.peek(q8._cache_key(text_recs[0].sid, text_recs[0].model),
                      "text")
    assert Q.is_quantized_feature(e.feature)
    assert payload_nbytes(e.feature) * 3 <= payload_nbytes(raw_text)

    # ...and the total remote->glass wire (features + the un-quantized
    # fp32 head outputs, which dominate at tiny scale) still shrinks
    def down_bytes(eng):
        return sum(s["bytes"] for link, s in eng.fabric.stats().items()
                   if link.endswith("->glass"))
    assert down_bytes(q8) < down_bytes(f32), \
        (down_bytes(q8), down_bytes(f32))
    # quantized serving still emits finals with sane outputs
    finals = [r for r in q8.records if r.kind == "final"]
    assert finals
    for r in finals:
        for v in r.outputs.values():
            assert np.isfinite(np.asarray(v)).all()


def test_engine_qparams_derived_once_for_shared_zoo(zoo_models):
    """A share_encoders zoo aliases ONE fp32 pytree, so the sidecar is
    derived exactly once however many subset models serve int8."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, bandwidth=30.0,
                  precision={"ph1": "int8", "edge64x": "int8"})
    eps = _lag_episodes()
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality])
    assert len(eng._qparams_cache) == 1


def test_engine_rejects_bad_precision_config(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    with pytest.raises(ValueError, match="unknown host or precision"):
        _tiered(splits, params, precision={"mars": "int8"})
    with pytest.raises(ValueError, match="unknown host or precision"):
        _tiered(splits, params, precision={"ph1": "int4"})


def test_engine_rejects_zoo_without_quantize_fn(zoo_models):
    """An int8-enabled spec over a model with no quantized variant must
    refuse to build, not silently serve fp32."""
    cfg, splits, shared, params, payloads = zoo_models
    from dataclasses import replace as dc_replace
    bare = {k: split(dc_replace(sm.module, quantize_fn=None))
            for k, sm in splits.items()}
    with pytest.raises(ValueError, match="quantize_fn"):
        _tiered(bare, params, precision={"ph1": "int8"})
    # ...but an all-fp32 map over the same zoo is fine (legacy rule)
    _tiered(bare, params, precision={"ph1": "fp32"})


def test_engine_int8_staleness_semantics_unchanged(zoo_models):
    """Packed cache entries obey the same <=1-step staleness contract:
    a provisional read of a quantized feature succeeds within the bound
    and the versioned entries still re-stamp on touch."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, bandwidth=30.0,
                  precision={"ph1": "int8", "edge64x": "int8"})
    for i, m in enumerate(ALL):
        eng.submit("s0", Event(i, m, float(i)), payloads[m])
    # one more text arrival: re-fuses against cached (possibly packed)
    # vitals/scene one step behind — the tolerated bound
    rec = eng.submit("s0", Event(3, "text", 3.0), payloads["text"])
    assert rec.outputs is not None and rec.kind == "final"
