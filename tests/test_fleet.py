"""Fleet-scale serving: workload generation, routing, admission, region sim.

Four claims under test:

  * **seeded open-loop workloads are deterministic** — the same
    ``(rate, horizon, seed)`` yields the identical session stream event
    for event, the empirical arrival rate matches the offered rate, the
    diurnal envelope is respected exactly, and ``time_scale`` compresses
    intra-session times only (session start instants untouched);
  * **routing is stable and load-aware** — the consistent-hash ring
    moves only ~1/N of sessions when a replica is added, and the
    least-loaded spill fires exactly when the home replica's backlog
    exceeds the fleet minimum by the spill margin;
  * **admission control is a hysteresis state machine** — sheds above
    ``enter_frac * deadline``, keeps shedding until strictly below
    ``exit_frac * deadline`` (burst recovery), honors the queue cap,
    and accounts for every decision;
  * **fleet scale never buys drift or loss** — a ``RegionSim`` run over
    mesh-placed params conserves sessions (offered == admitted + shed),
    finalizes every admitted session at bit-parity (atol 0) with a
    per-event reference engine built with the same batch bucket, and
    shed sessions emit ONLY ``degraded``-tagged partials — counted,
    never dropped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProfileTable, emsnet_zoo, split
from repro.fleet import (AdmissionController, AdmissionPolicy, AdmitAll,
                         ConsistentHashRouter, RegionSim, diurnal_rate,
                         diurnal_times, fleet_mesh, generate_workload,
                         merge_sessions, place_fleet_params, poisson_times)
from repro.obs import StreamingTracer, audit_file
from repro.serving.api import build_engine

# the fixed batch bucket used on BOTH sides of every parity comparison:
# XLA CPU picks different kernels for different batch-row counts
# (GEMV vs GEMM), so atol-0 parity is only honest when the sim flushes
# and the per-event reference hit the same padded program shape
ENGINE_KW = dict(batch_bucket_min=2, max_coalesce=2)

GLASS_PROFILE = ProfileTable(base={"enc:text": 0.08, "enc:vitals": 0.01,
                                   "enc:scene": 0.05, "tail": 0.005,
                                   "full": 0.15})


# ------------------------------------------------------------------ fixtures

@pytest.fixture(scope="module")
def fleet_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    placed, report = place_fleet_params(params, fleet_mesh())
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, placed, report, payloads


def _flatten(sessions):
    return [(s.sid, s.t_start, s.scenario,
             tuple((e.index, e.modality, e.arrival_time)
                   for e in s.events))
            for s in sessions]


# ------------------------------------------------------------------ workload

def test_workload_seeded_determinism():
    a = generate_workload(5.0, 3.0, seed=7)
    b = generate_workload(5.0, 3.0, seed=7)
    assert _flatten(a) == _flatten(b)
    c = generate_workload(5.0, 3.0, seed=8)
    assert _flatten(a) != _flatten(c)


def test_poisson_empirical_rate_and_bounds():
    ts = poisson_times(20.0, 200.0, seed=1)
    assert ts == sorted(ts)
    assert all(0.0 <= t < 200.0 for t in ts)
    rate = len(ts) / 200.0
    assert rate == pytest.approx(20.0, rel=0.15)


def test_poisson_validation():
    with pytest.raises(ValueError, match="rate"):
        poisson_times(0.0, 10.0)
    assert poisson_times(5.0, 0.0) == []
    assert poisson_times(5.0, -1.0) == []


def test_diurnal_rate_envelope():
    base, amp = 10.0, 0.6
    vals = [diurnal_rate(t, base, amp=amp, period=60.0)
            for t in np.linspace(0.0, 120.0, 97)]
    assert min(vals) >= base * (1 - amp) - 1e-9
    assert max(vals) <= base * (1 + amp) + 1e-9
    assert max(vals) == pytest.approx(base * (1 + amp), rel=1e-3)
    with pytest.raises(ValueError, match="amp"):
        diurnal_rate(0.0, base, amp=1.0)


def test_diurnal_times_rate_and_validation():
    # two whole periods: the sinusoid integrates out, so the mean
    # arrival rate should match the base rate
    ts = diurnal_times(20.0, 120.0, seed=2, amp=0.6, period=60.0)
    assert ts == sorted(ts)
    assert all(0.0 <= t < 120.0 for t in ts)
    assert len(ts) / 120.0 == pytest.approx(20.0, rel=0.25)
    with pytest.raises(ValueError, match="base_rate"):
        diurnal_times(-1.0, 10.0)
    with pytest.raises(ValueError, match="amp"):
        diurnal_times(10.0, 10.0, amp=1.5)
    with pytest.raises(ValueError, match="process"):
        generate_workload(1.0, 1.0, process="weekly")


def test_time_scale_compresses_sessions_only():
    w1 = generate_workload(2.0, 5.0, seed=3, time_scale=1.0)
    w2 = generate_workload(2.0, 5.0, seed=3, time_scale=0.5)
    assert len(w1) == len(w2) > 0
    for s1, s2 in zip(w1, w2):
        assert s2.t_start == s1.t_start          # arrivals untouched
        assert s2.scenario == s1.scenario
        assert len(s2.events) == len(s1.events)
        for e1, e2 in zip(s1.events, s2.events):
            assert e2.modality == e1.modality
            assert e2.arrival_time == pytest.approx(
                0.5 * e1.arrival_time, abs=1e-12)
    with pytest.raises(ValueError, match="time_scale"):
        generate_workload(1.0, 1.0, time_scale=0.0)


def test_merge_sessions_global_order():
    sessions = generate_workload(4.0, 4.0, seed=5)
    arrivals = merge_sessions(sessions)
    assert len(arrivals) == sum(len(s.events) for s in sessions)
    keys = [(t, sid) for t, sid, _ in arrivals]
    assert keys == sorted(keys)
    # absolute_events agrees with the merged view
    s = sessions[0]
    for e_rel, e_abs in zip(s.events, s.absolute_events()):
        assert e_abs.arrival_time == s.t_start + e_rel.arrival_time


# ------------------------------------------------------------------- router

def test_router_ring_stability_on_resize():
    sids = [f"s{i}" for i in range(400)]
    r4 = ConsistentHashRouter(4)
    r5 = ConsistentHashRouter(5)
    assert all(0 <= r4.home(s) < 4 for s in sids)
    # deterministic across instances with the same seed
    assert [r4.home(s) for s in sids] == \
        [ConsistentHashRouter(4).home(s) for s in sids]
    moved = sum(r4.home(s) != r5.home(s) for s in sids) / len(sids)
    # consistent hashing moves ~1/5 of keys, never a wholesale reshuffle
    assert 0.0 < moved < 0.45


def test_router_least_loaded_spill():
    r = ConsistentHashRouter(2, spill_s=0.05)
    sid = next(s for s in (f"s{i}" for i in range(100)) if r.home(s) == 0)
    assert r.route(sid) == 0                       # no loads: pure hash
    assert r.route(sid, loads=[0.0, 0.0]) == 0     # balanced: stay home
    assert r.spills == 0
    assert r.route(sid, loads=[1.0, 0.0]) == 1     # overloaded: spill
    assert r.spills == 1
    assert r.route(sid, loads=[0.04, 0.0]) == 0    # inside the margin
    assert r.spills == 1
    with pytest.raises(ValueError, match="loads"):
        r.route(sid, loads=[0.0])
    with pytest.raises(ValueError, match="n_replicas"):
        ConsistentHashRouter(0)


# ---------------------------------------------------------------- admission

def test_admission_policy_validation():
    with pytest.raises(ValueError, match="deadline_s"):
        AdmissionPolicy(deadline_s=0.0)
    with pytest.raises(ValueError, match="hysteresis"):
        AdmissionPolicy(deadline_s=1.0, enter_frac=0.5, exit_frac=0.5)
    with pytest.raises(ValueError, match="hysteresis"):
        AdmissionPolicy(deadline_s=1.0, enter_frac=1.0, exit_frac=0.0)


def test_admission_hysteresis_and_burst_recovery():
    c = AdmissionController(
        AdmissionPolicy(deadline_s=1.0, enter_frac=1.0, exit_frac=0.5), 2)
    assert c.admit(0, 0.0, 0.2)            # calm: admit
    assert not c.admit(0, 1.0, 1.5)        # burst: enter shedding
    assert not c.admit(0, 2.0, 0.7)        # inside band: KEEP shedding
    assert c.admit(0, 3.0, 0.4)            # drained below exit: recover
    assert c.transitions == [(1.0, 0, "enter"), (3.0, 0, "exit")]
    # replica 1 has independent state
    assert c.admit(1, 4.0, 0.9)
    assert c.stats() == {"admitted": 3, "shed": 2, "transitions": 2,
                         "shedding_now": 0}
    with pytest.raises(ValueError, match="n_replicas"):
        AdmissionController(AdmissionPolicy(deadline_s=1.0), 0)


def test_admission_queue_cap():
    c = AdmissionController(
        AdmissionPolicy(deadline_s=10.0, max_queue=2), 1)
    assert c.admit(0, 0.0, 0.0, queue_depth=2)       # at cap: fine
    assert not c.admit(0, 1.0, 0.0, queue_depth=3)   # over cap: shed
    assert not c.admit(0, 2.0, 0.0, queue_depth=3)   # cap holds recovery
    assert c.admit(0, 3.0, 0.0, queue_depth=0)       # drained: recover
    assert [k for _, _, k in c.transitions] == ["enter", "exit"]


def test_admit_all_never_sheds():
    c = AdmitAll()
    assert all(c.admit(0, float(i), 1e9) for i in range(5))
    assert c.stats() == {"admitted": 5, "shed": 0, "transitions": 0,
                         "shedding_now": 0}


# ---------------------------------------------------------------- placement

def test_place_fleet_params_identity_and_report(fleet_models):
    _, _, shared, placed, report, _ = fleet_models
    # one shared pytree in -> one placed pytree out, identity preserved
    # across zoo keys (the share_encoders grouped-tail check needs it)
    assert len({id(v) for v in placed.values()}) == 1
    ref = jax.tree.leaves(shared)
    got = jax.tree.leaves(next(iter(placed.values())))
    assert len(ref) == len(got)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert report["devices"] >= 1
    assert report["axis_sizes"]["model"] == 1
    assert report["param_leaves"] == (report["sharded_leaves"]
                                      + report["replicated_leaves"])
    assert report["param_leaves"] == len(ref)
    assert report["param_bytes"] == sum(
        x.size * x.dtype.itemsize for x in ref)


# --------------------------------------------------------------- region sim

def test_region_sim_conservation_and_bit_parity(fleet_models):
    _, splits, _, placed, _, payloads = fleet_models
    sessions = generate_workload(3.0, 2.0, seed=0, time_scale=0.2)
    assert len(sessions) >= 2
    sim = RegionSim(splits, placed, n_replicas=2,
                    engine_kw=dict(ENGINE_KW))
    rep = sim.run(sessions, lambda sid, ev: payloads[ev.modality])

    n = len(sessions)
    assert rep["sessions_offered"] == n
    assert rep["sessions_admitted"] == n and rep["sessions_shed"] == 0
    assert rep["sessions_finalized"] == n
    assert rep["events_admitted"] == sum(len(s.events) for s in sessions)
    assert sim.makespan() >= sessions[-1].t_start
    assert len(sim.ttfp) == n and len(sim.ttfinal) == n
    assert all(sim.ttfp[s.sid] <= sim.ttfinal[s.sid] for s in sessions)

    # every admitted session's finals match a per-event reference engine
    # built with the SAME fixed batch bucket, at atol 0
    for s in sessions:
        ref = build_engine(splits, placed, "batch+stream",
                           share_encoders=True, deadline_s=None,
                           **ENGINE_KW)
        preds = []
        for ev in s.events:
            ref.submit(s.sid, ev, payloads[ev.modality])
            preds.extend(ref.flush().predictions)
        want = next(p.outputs for p in reversed(preds)
                    if p.kind == "final")
        got = sim.final_outputs(s.sid)
        assert got is not None
        assert set(got) == set(want)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(want[k]))

    # fleet-wide registry is the exact union of sim + replica counters
    mx = sim.fleet_metrics()
    assert mx.get("fleet.sessions_offered") == n
    assert mx.get("fleet.flushes") == len(sim.flush_log)
    prom = mx.to_prometheus()
    assert "# TYPE emsserve_fleet_sessions_offered counter" in prom
    assert "# TYPE emsserve_fleet_ttfp_s summary" in prom
    assert f"emsserve_fleet_ttfp_s_count {n}" in prom


def test_region_sim_shed_sessions_degrade_only(fleet_models, tmp_path):
    _, splits, _, placed, _, payloads = fleet_models
    sessions = generate_workload(3.0, 2.0, seed=1, time_scale=0.2)
    # deadline far below the svc prior: every session sheds to glass
    ctrl = AdmissionController(
        AdmissionPolicy(deadline_s=1e-4, enter_frac=1.0, exit_frac=0.5), 2)
    path = tmp_path / "fleet.jsonl"
    tracer = StreamingTracer(path, buffer=16)
    sim = RegionSim(splits, placed, n_replicas=2, admission=ctrl,
                    profile=GLASS_PROFILE, tracer=tracer,
                    engine_kw=dict(ENGINE_KW))
    rep = sim.run(sessions, lambda sid, ev: payloads[ev.modality])

    n = len(sessions)
    assert rep["sessions_offered"] == n
    assert rep["sessions_admitted"] + rep["sessions_shed"] == n
    assert rep["sessions_shed"] == n == ctrl.shed
    # shed sessions: ONLY tagged partials, counted, never finalized
    assert len(sim.glass.records) == sum(len(s.events) for s in sessions)
    assert all(r.kind == "partial" and r.degraded
               for r in sim.glass.records)
    assert rep["degraded_partials"] == sum(
        1 for r in sim.glass.records if r.outputs is not None) > 0
    assert all(sim.final_outputs(s.sid) is None for s in sessions)
    assert sim.metrics.get("fleet.degraded_events") == \
        len(sim.glass.records)
    # degraded sessions still get a time-to-first-prediction
    assert set(sim.glass.ttfp) == {s.sid for s in sessions}

    # the streamed trace is auditable offline
    tracer.close(other_data={"metrics": sim.fleet_metrics().snapshot()})
    report = audit_file(path)
    assert report.ok, report.violations
