import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.kernels import ref
from repro.models import attention as A


@pytest.mark.parametrize("shape", [
    (2, 64, 64, 4, 4, 16), (1, 96, 96, 4, 2, 32), (2, 48, 48, 8, 8, 8),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
def test_flash_jnp_matches_ref(key, shape, causal, window):
    B, Sq, Sk, H, KV, D = shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    out = A.flash_attention_jnp(q, k, v, causal=causal, window=window,
                                q_chunk=32, kv_chunk=16)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5)


def test_flash_jnp_mixed_v_dim(key):
    """MLA decompressed path: Dv != Dq."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 32, 4, 24))
    k = jax.random.normal(ks[1], (1, 32, 4, 24))
    v = jax.random.normal(ks[2], (1, 32, 4, 16))
    out = A.flash_attention_jnp(q, k, v, q_chunk=8, kv_chunk=8)
    want = ref.attention_ref(q, k, v)
    assert out.shape == (1, 32, 4, 16)
    np.testing.assert_allclose(out, want, atol=2e-5)


def test_ring_write_wraps(key):
    buf = jnp.zeros((1, 4, 2, 2))
    new = jnp.ones((1, 1, 2, 2))
    out = A.ring_write(buf, new, jnp.int32(5))     # slot 5 % 4 = 1
    assert float(out[0, 1].sum()) == 4.0
    assert float(out.sum()) == 4.0


def test_decode_matches_full_attention(key, tiny_dense_cfg):
    """Ring-buffer decode at position t equals full self-attention row t."""
    cfg = tiny_dense_cfg
    p = A.attn_init(key, cfg)
    B, S = 2, 10
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full, (kf, vf) = A.attn_forward(p, x, pos, cfg)
    cache = A.attn_cache_init(cfg, B, S, jnp.float32)
    for t in range(S):
        out, cache = A.attn_decode(p, x[:, t:t + 1], cache, jnp.int32(t), cfg)
    np.testing.assert_allclose(out, full[:, -1:], atol=1e-4)


def test_sliding_window_decode_drops_old(key, tiny_dense_cfg):
    """With window W, decode at t>=W must equal attention over last W only."""
    cfg = tiny_dense_cfg
    p = A.attn_init(key, cfg)
    B, S, W = 1, 12, 4
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full, _ = A.attn_forward(p, x, pos, cfg, window=W)
    cache = A.attn_cache_init(cfg, B, W, jnp.float32)
    for t in range(S):
        out, cache = A.attn_decode(p, x[:, t:t + 1], cache, jnp.int32(t), cfg,
                                   window=W)
    np.testing.assert_allclose(out, full[:, -1:], atol=1e-4)


def test_mla_decode_matches_forward(key):
    cfg = reduced(get_config("deepseek-v3-671b"), d_model=64)
    p = A.mla_init(key, cfg)
    B, S = 2, 8
    x = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    full, _ = A.mla_forward(p, x, pos, cfg)
    cache = A.mla_cache_init(cfg, B, S, jnp.float32)
    for t in range(S):
        out, cache = A.mla_decode(p, x[:, t:t + 1], cache, jnp.int32(t), cfg)
    np.testing.assert_allclose(out, full[:, -1:], atol=1e-4)


def test_cross_attention_ignores_order(key, tiny_dense_cfg):
    """Cross attention over conditioning is permutation-equivariant in kv."""
    cfg = tiny_dense_cfg
    p = A.attn_init(key, cfg, cross=True)
    x = jax.random.normal(key, (1, 4, cfg.d_model), jnp.float32)
    cond = jax.random.normal(key, (1, 6, cfg.d_model), jnp.float32)
    kv = A.cross_kv(p, cond, cfg)
    out1 = A.cross_attn_forward(p, x, kv, cfg)
    perm = jnp.array([3, 1, 0, 2, 5, 4])
    kvp = (kv[0][:, perm], kv[1][:, perm])
    out2 = A.cross_attn_forward(p, x, kvp, cfg)
    np.testing.assert_allclose(out1, out2, atol=1e-5)
