"""Decode-attention Pallas kernel vs the model's ring-buffer oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.models.attention import plain_attention_vs_cache


@pytest.mark.parametrize("shape", [
    (2, 64, 8, 4, 16),    # B, W, H, KV, D
    (1, 100, 4, 4, 32),   # MHA, non-divisible W
    (2, 48, 8, 2, 64),    # GQA 4:1
])
@pytest.mark.parametrize("window", [0, 24])
def test_decode_kernel_matches_oracle(key, shape, window):
    B, W, H, KV, D = shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D))
    kbuf = jax.random.normal(ks[1], (B, W, KV, D))
    vbuf = jax.random.normal(ks[2], (B, W, KV, D))
    t = W + 5
    # ring-buffer positions: slot s holds position with s == pos % W,
    # some slots never written (-1)
    pos = np.array([(t - (s % (W + 3))) for s in range(W)], np.int32)
    pos[::7] = -1
    pos = jnp.asarray(pos)
    got = decode_attention(q, kbuf, vbuf, pos, jnp.int32(t), window=window,
                           block_k=16, interpret=True)
    want = plain_attention_vs_cache(q, kbuf, vbuf, pos, jnp.int32(t),
                                    window=window, scale=1.0 / math.sqrt(D))
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_decode_kernel_all_invalid_slots_safe(key):
    """Cache with no valid entries must not NaN (denominator guard)."""
    B, W, H, KV, D = 1, 16, 2, 2, 8
    q = jax.random.normal(key, (B, 1, H, D))
    buf = jnp.ones((B, W, KV, D))
    pos = jnp.full((W,), -1, jnp.int32)
    out = decode_attention(q, buf, buf, pos, jnp.int32(3), block_k=8,
                           interpret=True)
    assert not jnp.isnan(out).any()


def test_decode_kernel_bf16(key):
    B, W, H, KV, D = 1, 32, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, H, D), jnp.bfloat16)
    kbuf = jax.random.normal(ks[1], (B, W, KV, D), jnp.bfloat16)
    vbuf = jax.random.normal(ks[2], (B, W, KV, D), jnp.bfloat16)
    pos = jnp.arange(W, dtype=jnp.int32)
    got = decode_attention(q, kbuf, vbuf, pos, jnp.int32(W - 1), block_k=16,
                           interpret=True)
    want = plain_attention_vs_cache(q, kbuf, vbuf, pos, jnp.int32(W - 1),
                                    window=0, scale=1.0 / math.sqrt(D))
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=5e-2)
