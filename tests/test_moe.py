import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M


def _cfg(tiny_moe_cfg, **kw):
    return dataclasses.replace(tiny_moe_cfg, **kw)


def test_dispatch_matches_dense_ref(key, tiny_moe_cfg):
    cfg = _cfg(tiny_moe_cfg, capacity_factor=8.0)   # no drops
    p = M.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    y, aux = M.moe_forward(p, x, cfg)
    want = M.moe_ref(p, x, cfg)
    np.testing.assert_allclose(y, want, atol=1e-4)
    assert float(aux) > 0


def test_capacity_drops_are_bounded(key, tiny_moe_cfg):
    """With capacity_factor ~0, most contributions are dropped but shared
    experts / shapes stay sane."""
    cfg = _cfg(tiny_moe_cfg, capacity_factor=0.01)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model), jnp.float32)
    y, _ = M.moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert not jnp.isnan(y).any()


def test_capacity_rounding(tiny_moe_cfg):
    cfg = _cfg(tiny_moe_cfg)
    c = M.capacity(128, cfg)
    assert c % 8 == 0 and c >= 8
    assert c >= 128 * cfg.experts_per_tok / cfg.n_experts


def test_aux_loss_uniform_router_is_one(key, tiny_moe_cfg):
    """With a zero router (uniform probs), Switch aux loss == 1."""
    cfg = _cfg(tiny_moe_cfg)
    p = M.moe_init(key, cfg)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(key, (1, 64, cfg.d_model), jnp.float32)
    _, aux = M.moe_forward(p, x, cfg)
    assert float(aux) == pytest.approx(1.0, rel=1e-3)


def test_shared_expert_always_active(key, tiny_moe_cfg):
    cfg = _cfg(tiny_moe_cfg, n_shared_experts=1,
               moe_d_ff=max(16, tiny_moe_cfg.moe_d_ff))
    p = M.moe_init(key, cfg)
    assert "shared" in p
    x = jax.random.normal(key, (1, 8, cfg.d_model), jnp.float32)
    y, _ = M.moe_forward(p, x, cfg)
    # zeroing routed experts leaves the shared contribution
    p2 = dict(p, gate=jnp.zeros_like(p["gate"]), up=jnp.zeros_like(p["up"]))
    y2, _ = M.moe_forward(p2, x, cfg)
    assert float(jnp.abs(y2).sum()) > 0


def test_grad_flows_through_dispatch(key, tiny_moe_cfg):
    cfg = _cfg(tiny_moe_cfg, capacity_factor=4.0)
    p = M.moe_init(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model), jnp.float32)

    def loss(p):
        y, aux = M.moe_forward(p, x, cfg)
        return jnp.sum(y * y) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["down"]).sum()) > 0
    assert float(jnp.abs(g["router"]).sum()) > 0
