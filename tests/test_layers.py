import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_rmsnorm_unit_scale(key):
    p = L.rmsnorm_init(16)
    x = jax.random.normal(key, (4, 16)) * 10
    y = L.rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-4)


def test_layernorm_zero_mean(key):
    p = L.layernorm_init(32)
    x = jax.random.normal(key, (4, 32)) + 5
    y = L.layernorm(p, x)
    np.testing.assert_allclose(jnp.mean(y, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(jnp.std(y, -1), 1.0, rtol=1e-3)


def test_rope_preserves_norm(key):
    x = jax.random.normal(key, (2, 8, 4, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (2, 8))
    y = L.apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)


def test_rope_relative_property(key):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))

    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.full((1, 1), i), 100.0)
        kj = L.apply_rope(k, jnp.full((1, 1), j), 100.0)
        return float(jnp.sum(qi * kj))

    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)
    assert dot_at(5, 5) == pytest.approx(dot_at(0, 0), rel=1e-4)


def test_rope_zero_position_identity(key):
    x = jax.random.normal(key, (1, 4, 2, 8))
    pos = jnp.zeros((1, 4), jnp.int32)
    np.testing.assert_allclose(L.apply_rope(x, pos, 1e4), x, atol=1e-6)


@pytest.mark.parametrize("act", ["swiglu", "relu2"])
def test_mlp_shapes(key, act):
    p = L.mlp_init(key, 16, 32, act)
    x = jax.random.normal(key, (3, 5, 16))
    y = L.mlp(p, x, act)
    assert y.shape == (3, 5, 16)
    assert not jnp.isnan(y).any()


def test_dense_bias(key):
    p = L.dense_init(key, 4, 6, bias=True)
    x = jnp.zeros((2, 4))
    np.testing.assert_allclose(L.dense(p, x), 0.0)
