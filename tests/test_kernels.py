"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.rwkv6 import rwkv6_scan as rwkv_raw

FA_SHAPES = [
    (2, 64, 64, 4, 4, 16),     # MHA
    (1, 96, 96, 4, 2, 32),     # GQA 2:1
    (2, 48, 128, 8, 2, 64),    # cross-ish Sq != Sk, GQA 4:1
    (1, 33, 65, 2, 1, 8),      # non-divisible by block (padding path)
]


@pytest.mark.parametrize("shape", FA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(key, shape, dtype):
    B, Sq, Sk, H, KV, D = shape
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, KV, D), dtype)
    got = fa_raw(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    want = ref.attention_ref(q, k, v, causal=True)
    atol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=atol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_attention_window(key, window):
    q = jax.random.normal(key, (1, 64, 4, 16))
    got = fa_raw(q, q, q, causal=True, window=window, block_q=16, block_k=16,
                 interpret=True)
    want = ref.attention_ref(q, q, q, causal=True, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_attention_kv_lengths(key):
    """Per-row key-padding mask (length-bucketed batches): matches the
    oracle, including a zero-length row which must output exactly 0."""
    ks = jax.random.split(key, 3)
    B, Sq, Sk, H, KV, D = 4, 16, 24, 4, 2, 16
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, KV, D))
    v = jax.random.normal(ks[2], (B, Sk, KV, D))
    lens = jnp.array([24, 9, 1, 0], jnp.int32)
    got = fa_raw(q, k, v, causal=False, kv_lengths=lens,
                 block_q=8, block_k=8, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, kv_lengths=lens)
    np.testing.assert_allclose(got, want, atol=2e-5)
    assert np.abs(np.asarray(got[3])).max() == 0.0


def test_flash_attention_noncausal(key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 16, 4, 16))
    k = jax.random.normal(ks[1], (2, 40, 4, 16))
    v = jax.random.normal(ks[2], (2, 40, 4, 16))
    got = fa_raw(q, k, v, causal=False, block_q=8, block_k=8, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_flash_vs_model_flash(key):
    """Pallas kernel and the model's jnp flash implement the same op."""
    from repro.models.attention import flash_attention_jnp
    q = jax.random.normal(key, (1, 64, 4, 16))
    a = fa_raw(q, q, q, causal=True, block_q=16, block_k=16, interpret=True)
    b = flash_attention_jnp(q, q, q, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(a, b, atol=2e-5)


RWKV_SHAPES = [(2, 32, 2, 16), (1, 100, 4, 8), (2, 17, 1, 32), (1, 64, 8, 64)]


@pytest.mark.parametrize("shape", RWKV_SHAPES)
def test_rwkv6_kernel_sweep(key, shape):
    B, S, H, n = shape
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, n)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, n))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, n)) * 0.1
    y1, s1 = rwkv_raw(r, k, v, w, u, block_t=16, interpret=True)
    y2, s2 = ref.rwkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(y1, y2, atol=1e-4)
    np.testing.assert_allclose(s1, s2, atol=1e-4)


def test_rwkv6_initial_state_chunked(key):
    """Chaining two kernel calls via the state equals one long call —
    the chunked-prefill contract."""
    B, S, H, n = 1, 48, 2, 16
    ks = jax.random.split(key, 5)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, n)) for i in range(3))
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, n))) * 0.5 + 0.4
    u = jax.random.normal(ks[4], (H, n)) * 0.1
    y_full, s_full = rwkv_raw(r, k, v, w, u, block_t=8, interpret=True)
    h = S // 2
    y1, s1 = rwkv_raw(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u,
                      block_t=8, interpret=True)
    y2, s2 = rwkv_raw(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s0=s1,
                      block_t=8, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4)
    np.testing.assert_allclose(s2, s_full, atol=1e-4)


def test_ops_wrappers_jit(key):
    q = jax.random.normal(key, (1, 32, 2, 16))
    out = ops.flash_attention(q, q, q, interpret=True)
    assert out.shape == q.shape
    r = jax.random.normal(key, (1, 16, 2, 8))
    w = jnp.full((1, 16, 2, 8), 0.9)
    u = jnp.zeros((2, 8))
    y, s = ops.rwkv6_scan(r, r, r, w, u, interpret=True)
    assert y.shape == r.shape and s.shape == (1, 2, 8, 8)
