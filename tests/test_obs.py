"""Unified serving observability: tracer, metrics registry, auditor.

Three claims under test:

  * **defaults off** — ``Tracer.disabled`` is a falsy no-op and every
    engine wires it by default, so an untraced run records nothing and
    the legacy counter attributes still read correctly through the
    metrics registry;
  * **deterministic traces** — events carry a monotone per-tracer seq,
    export stable-sorts at equal timestamps and serializes canonically,
    so two identical simulated-clock runs produce byte-identical files
    that validate as Chrome trace-event JSON;
  * **the auditor proves the invariants from the trace alone** — zero
    violations across every scenario family the repo serves (all
    LAG_SCENARIOS stream orderings, the 3^4 forced-placement sweep,
    speculation races incl. cancelled flights, seeded chaos schedules),
    and tampering with a trace (version skip, unstamped fuse input,
    cancel-after-deliver, emit without fuse) is caught.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BandwidthTrace, LAG_SCENARIOS, ProfileTable,
                        async_episode, emsnet_zoo, horizon,
                        nlos_bandwidth, split)
from repro.core.episodes import Event
from repro.core.offload import SpeculationPolicy
from repro.obs import (Metrics, QuantileSketch, StreamingTracer, Tracer,
                       audit_doc, audit_file, audit_tracer, jsonl_to_chrome,
                       validate_chrome)
from repro.obs.audit import main as audit_main
from repro.serving.api import build_engine
from repro.serving.transport import TransportChannel

ALL = ("text", "vitals", "scene")
TIERS = ("glass", "ph1", "edge64x")
BASE = {"enc:text": 0.08, "enc:vitals": 0.01, "enc:scene": 0.05,
        "tail": 0.005, "full": 0.15}
RACE_ALWAYS = SpeculationPolicy(deadline_s=0.0, margin_s=0.0)


@pytest.fixture(scope="module")
def zoo_models(tiny_emsnet_cfg):
    cfg = tiny_emsnet_cfg
    zoo = emsnet_zoo(cfg)
    splits = {k: split(m) for k, m in zoo.items()}
    shared = zoo["text+vitals+scene"].init_fn(jax.random.PRNGKey(0))
    params = {k: shared for k in zoo}
    rng = np.random.default_rng(0)
    payloads = {
        "text": jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 11)),
                            jnp.int32),
        "vitals": jnp.asarray(rng.normal(size=(1, 5, cfg.n_vitals)),
                              jnp.float32),
        "scene": jnp.asarray(rng.integers(0, 2, (1, cfg.scene_dim)),
                             jnp.float32),
    }
    return cfg, splits, shared, params, payloads


def _tiered(splits, params, *, bandwidth=5.0, **kw):
    kw.setdefault("max_history", None)
    kw.setdefault("tier_traces",
                  {"ph1": BandwidthTrace.static(nlos_bandwidth(0.0))})
    kw.setdefault("trace", BandwidthTrace.static(nlos_bandwidth(bandwidth)))
    kw.setdefault("tiers", TIERS)
    return build_engine(
        splits, params, "tiered", share_encoders=True,
        profile=ProfileTable(base=dict(BASE)), **kw)


def _episode():
    return [Event(i, m, float(i)) for i, m in enumerate(ALL)]


def _audit_ok(eng):
    rep = audit_tracer(eng.tracer,
                       other_data={"transport": eng.fabric.stats()})
    assert rep.ok, rep.violations
    return rep


# ====================================================== defaults off

def test_disabled_tracer_is_falsy_noop_default(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    assert not Tracer.disabled and bool(Tracer())
    Tracer.disabled.span("x", "c", 0.0, 1.0)
    Tracer.disabled.instant("y", "c", 0.0)
    assert Tracer.disabled.events == []
    eng = _tiered(splits, params)
    eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert eng.tracer is Tracer.disabled and eng.tracer.events == []


def test_legacy_counters_read_through_registry(zoo_models):
    """The historical attributes and the registry are the same number:
    migrating the counters changed their storage, not their meaning."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params)
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    m = eng.metrics
    assert eng.cache.hits == int(m.get("cache.hits")) > 0
    assert eng.cache.duplicate_commits == int(m.get("cache.duplicate_commits"))
    assert eng.fallback_count == int(m.get("placement.fallbacks"))
    assert eng.evicted_count == int(m.get("engine.evicted_sessions"))
    for name, ch in eng.fabric.stats().items():
        assert ch["bytes"] == int(m.get(f"transport.{name}.bytes"))
        assert ch["cancelled_msgs"] == int(
            m.get(f"transport.{name}.cancelled_msgs"))
    snap = eng.metrics_snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["histograms"]["serve.latency_s"]["count"] == 3
    assert snap["gauges"]["engine.sessions_live"] == 1


# ============================================== deterministic export

def test_trace_export_is_byte_reproducible(zoo_models, tmp_path):
    cfg, splits, shared, params, payloads = zoo_models
    paths = []
    for n in (1, 2):
        eng = _tiered(splits, params, tracer=Tracer(),
                      speculation=RACE_ALWAYS)
        for ev in _episode():
            eng.submit("s0", ev, payloads[ev.modality])
        p = tmp_path / f"t{n}.json"
        eng.tracer.export(p, other_data={"transport": eng.fabric.stats()})
        paths.append(p)
    b1, b2 = paths[0].read_bytes(), paths[1].read_bytes()
    assert b1 == b2 and len(b1) > 0


def test_seq_is_monotone_and_ties_sort_stably():
    tr = Tracer()
    for i in range(5):
        tr.instant("tie", "t", 1.0, track="a", i=i)   # all at the same ts
    tr.span("before", "t", 0.0, 1.0, track="b")
    doc = tr.to_chrome()
    evs = [e for e in doc["traceEvents"] if e["ph"] != "M"]
    seqs = [e["args"]["seq"] for e in evs]
    assert evs[0]["name"] == "before"                  # ts order first
    assert [e["args"]["i"] for e in evs[1:]] == list(range(5))
    assert sorted(set(seqs)) == sorted(seqs)           # unique, monotone


def test_chrome_schema_tracks_and_units(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, tracer=Tracer())
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    doc = eng.tracer.to_chrome()
    assert validate_chrome(doc) == []
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {e["args"]["name"] for e in meta if e["name"] == "thread_name"}
    assert {"cache", "session:s0"} <= names
    assert any(n.startswith("host:") for n in names)
    assert any(n.startswith("link:") for n in names)
    # lifecycle span for each arrival, in microseconds on the session tid
    spans = [e for e in doc["traceEvents"]
             if e["ph"] == "X" and e["name"] == "text#0"]
    assert len(spans) == 1 and spans[0]["dur"] > 0


# ==================================================== metrics registry

def test_metrics_registry_basics():
    m = Metrics()
    m.inc("a")
    m.inc("a", 2.5)
    assert m.get("a") == 3.5 and m.get("absent") == 0
    m.set_gauge("g", 7)
    m.gauge_fn("live", lambda: 42)
    for v in (1.0, 2.0, 3.0):
        m.observe("h", v)
    snap = m.snapshot()
    assert snap["counters"] == {"a": 3.5}
    assert snap["gauges"] == {"g": 7, "live": 42}
    assert snap["histograms"]["h"]["count"] == 3
    assert snap["histograms"]["h"]["mean"] == pytest.approx(2.0)
    json.dumps(snap)                       # JSON-serializable end to end
    m.reset()
    snap = m.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}
    assert snap["gauges"] == {"live": 42}  # callable gauges survive reset


def test_sketch_rank_error_bound_seeded():
    rng = np.random.default_rng(3)
    xs = rng.lognormal(mean=2.0, sigma=1.5, size=4000)
    sk = QuantileSketch(rel_err=0.01)
    for x in xs:
        sk.add(float(x))
    srt = np.sort(xs)
    for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
        true = float(srt[int(np.floor(q * (len(xs) - 1)))])
        got = sk.quantile(q)
        assert abs(got - true) <= 1.01 * sk.rel_err * true, q


def test_sketch_merge_is_associative_on_state():
    rng = np.random.default_rng(4)
    sks = []
    for _ in range(3):
        sk = QuantileSketch(rel_err=0.02)
        for x in rng.uniform(0.0, 50.0, size=200):
            sk.add(float(x))
        sks.append(sk)
    a, b, c = sks
    left, right = a.merge(b).merge(c), a.merge(b.merge(c))
    assert left.state() == right.state()
    for q in (0.1, 0.5, 0.99):
        assert left.quantile(q) == right.quantile(q)


# ============================================ auditor: scenario families

def test_audit_all_lag_scenarios_stream(zoo_models):
    """One streaming run holding every LAG_SCENARIOS arrival ordering at
    once replays through the auditor with zero violations."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = build_engine(splits, params, "stream", share_encoders=True,
                       max_history=None, tracer=Tracer())
    eps = {name: async_episode(name, seed=i)
           for i, name in enumerate(sorted(LAG_SCENARIOS))}
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                     sim_window=0.0)
    rep = audit_tracer(eng.tracer)
    assert rep.ok, rep.violations
    assert rep.checks["fuses"] > 0 and rep.checks["emits"] > 0


def test_audit_placement_sweep_81(zoo_models):
    """Every 3^4 forced per-submodule tier assignment produces a trace
    the auditor accepts, with the transport cross-checked against the
    live fabric stats."""
    cfg, splits, shared, params, payloads = zoo_models
    submods = ("enc:text", "enc:vitals", "enc:scene", "tail")
    total = dict.fromkeys(("commits", "fuses", "flights"), 0)
    for combo in itertools.product(TIERS, repeat=len(submods)):
        eng = _tiered(splits, params, tracer=Tracer(),
                      force=dict(zip(submods, combo)))
        for ev in _episode():
            eng.submit("s0", ev, payloads[ev.modality])
        rep = _audit_ok(eng)
        for k in total:
            total[k] += rep.checks[k]
    assert total["commits"] >= 81 * 3 and total["fuses"] >= 81 * 3
    assert total["flights"] > 0


def test_audit_speculation_remote_wins(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, tracer=Tracer(), speculation=RACE_ALWAYS)
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    assert eng.spec_count == 3
    rep = _audit_ok(eng)
    assert rep.checks["flights"] > 0
    races = [e for e in eng.tracer.events if e.name == "race.start"]
    wins = [e for e in eng.tracer.events if e.name == "race.win"]
    assert len(races) == 3 and len(wins) == 3


def test_audit_speculation_glass_wins_cancelled_flight(zoo_models):
    """The cancel-on-commit path: the starved uplink flight is
    cancelled, and the auditor both accepts the trace AND accounts the
    cancelled bytes in its conservation check."""
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, trace=BandwidthTrace.static(200.0),
                  tier_traces={}, speculation=RACE_ALWAYS, tracer=Tracer())
    rec = eng.submit("s0", Event(0, "text", 0.0), payloads["text"])
    assert rec.race_winner == "glass"
    rep = _audit_ok(eng)
    assert rep.checks["cancels"] == 1


def test_audit_seeded_chaos_schedule(zoo_models):
    cfg, splits, shared, params, payloads = zoo_models
    from repro.serving.chaos import chaos_schedule
    eps = {f"s{i}": async_episode("text_first", seed=i) for i in range(2)}
    sched = chaos_schedule(5, horizon=horizon(eps),
                           tiers=("ph1", "edge64x"),
                           mean_up_s=1.5, mean_down_s=0.6,
                           min_up_s=0.4, min_down_s=0.3)
    eng = _tiered(splits, params, tracer=Tracer(), redispatch=True,
                  speculation=RACE_ALWAYS)
    eng.run_arrivals(eps, lambda sid, ev: payloads[ev.modality],
                     schedule=sched)
    assert eng.rejoin_count >= 1
    rep = _audit_ok(eng)
    names = {e.name for e in eng.tracer.events}
    assert {"crash.inject", "rejoin"} <= names
    assert rep.checks["commits"] > 0 and rep.checks["flights"] > 0


# ================================================= auditor: tampering

def _minimal_doc():
    """Hand-built clean trace: commit -> fuse -> emit, one flight +
    cancel, all in program order."""
    tr = Tracer()
    tr.instant("cache.commit", "cache", 0.0, track="cache", key="s0",
               modality="text", step=0, tier="glass", accepted=True,
               version=0)
    tr.span("transport.flight", "transport", 0.0, 1.0, track="link:u",
            flight=0, channel="u", nbytes=100, t_send=0.0, t_deliver=1.0,
            queued_s=0.0)
    tr.instant("transport.cancel", "transport", 0.5, track="link:u",
               flight=0, channel="u", nbytes=100, t=0.5)
    tr.instant("fuse", "serve", 1.0, track="session:s0", key="s0",
               model="text", step=0, consumed={"text": [0, 0]})
    tr.instant("emit", "serve", 1.0, track="session:s0", key="s0",
               model="text", step=0, kind="partial")
    return tr.to_chrome()


def test_audit_accepts_minimal_doc_then_catches_tampering():
    doc = _minimal_doc()
    assert audit_doc(doc).ok

    def ev(name):
        return next(e for e in doc["traceEvents"] if e.get("name") == name)

    # I1: accepted version skips
    d = json.loads(json.dumps(doc))
    next(e for e in d["traceEvents"]
         if e.get("name") == "cache.commit")["args"]["version"] = 3
    assert any("I1" in v for v in audit_doc(d).violations)

    # I4: fuse consumes a step never stamped
    d = json.loads(json.dumps(doc))
    next(e for e in d["traceEvents"]
         if e.get("name") == "fuse")["args"]["consumed"] = {"text": [5, 5]}
    assert any("I4" in v for v in audit_doc(d).violations)

    # I2: staleness beyond the bound
    d = json.loads(json.dumps(doc))
    next(e for e in d["traceEvents"]
         if e.get("name") == "fuse")["args"]["consumed"] = {"text": [0, 2]}
    assert any("I2" in v for v in audit_doc(d).violations)

    # I3: cancel at/after the delivery instant
    d = json.loads(json.dumps(doc))
    next(e for e in d["traceEvents"]
         if e.get("name") == "transport.cancel")["args"]["t"] = 1.0
    assert any("I3" in v for v in audit_doc(d).violations)

    # I4: emit with no prior fuse
    d = json.loads(json.dumps(doc))
    next(e for e in d["traceEvents"]
         if e.get("name") == "emit")["args"]["key"] = "ghost"
    assert any("I4" in v for v in audit_doc(d).violations)

    assert ev("emit")["args"]["key"] == "s0"   # originals untouched


def test_audit_cli_exit_codes(zoo_models, tmp_path, capsys):
    cfg, splits, shared, params, payloads = zoo_models
    eng = _tiered(splits, params, tracer=Tracer())
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    clean = tmp_path / "clean.json"
    eng.tracer.export(clean, other_data={"transport": eng.fabric.stats()})
    assert audit_main([str(clean)]) == 0
    assert "audit OK" in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"nope": 1}))
    assert audit_main([str(bad)]) == 2

    doc = json.loads(clean.read_text())
    for e in doc["traceEvents"]:
        if e.get("name") == "emit":
            e["args"]["key"] = "ghost"
    tampered = tmp_path / "tampered.json"
    tampered.write_text(json.dumps(doc))
    assert audit_main([str(tampered)]) == 1


# =============================== byte conservation, random cancels

def test_byte_conservation_under_random_cancel_schedule():
    """Seeded random send/cancel schedule on a raw channel: the trace
    replays cleanly against the live stats, and corrupting the stats by
    one byte is detected."""
    rng = np.random.default_rng(7)
    tr = Tracer()
    ch = TransportChannel(BandwidthTrace.static(1e4), name="g->e",
                          metrics=Metrics(), tracer=tr, max_history=None)
    t = 0.0
    for _ in range(60):
        t += float(rng.uniform(0.0, 0.05))
        d = ch.send(int(rng.integers(1, 5000)), t)
        if rng.random() < 0.4:
            tc = d.t_send + 0.9 * float(rng.random()) * (d.t_deliver
                                                         - d.t_send)
            ch.cancel(d.flight, tc)
    assert ch.cancelled_msgs > 0
    stats = {ch.name: ch.stats()}
    rep = audit_doc(tr.to_chrome({"transport": stats}))
    assert rep.ok, rep.violations
    assert rep.checks["flights"] == 60
    assert rep.checks["cancels"] == ch.cancelled_msgs
    bad = {ch.name: dict(ch.stats(), bytes=ch.stats()["bytes"] + 1)}
    assert not audit_doc(tr.to_chrome({"transport": bad})).ok


# ====================================== streaming (bounded) tracer

def _record_mixed(tr, n=100):
    for i in range(n):
        if i % 3 == 0:
            tr.span(f"work#{i}", "w", i * 0.01, i * 0.01 + 0.002,
                    track=f"r{i % 2}", i=i)
        else:
            tr.instant(f"mark#{i}", "m", i * 0.01, track="fleet", i=i)


def test_streaming_tracer_bounded_ring_and_exact_roundtrip(tmp_path):
    """The ring never exceeds ``buffer`` entries, and the JSONL file
    converts offline to the EXACT Chrome doc a plain Tracer would
    export for the same event stream."""
    plain = Tracer()
    _record_mixed(plain)
    p = tmp_path / "stream.jsonl"
    st = StreamingTracer(p, buffer=8)
    high = 0
    for i in range(100):
        if i % 3 == 0:
            st.span(f"work#{i}", "w", i * 0.01, i * 0.01 + 0.002,
                    track=f"r{i % 2}", i=i)
        else:
            st.instant(f"mark#{i}", "m", i * 0.01, track="fleet", i=i)
        high = max(high, len(st.events))
    assert high <= 8                      # O(buffer), never O(events)
    other = {"metrics": {"counters": {"x": 1}}}
    assert st.close(other_data=other) == 100
    assert st.close() == 100              # idempotent no-op
    assert jsonl_to_chrome(p) == plain.to_chrome(other)
    rep = audit_file(p)
    assert rep.ok, rep.violations


def test_streaming_tracer_guards(tmp_path):
    with pytest.raises(ValueError, match="buffer"):
        StreamingTracer(tmp_path / "x.jsonl", buffer=0)
    p = tmp_path / "t.jsonl"
    with StreamingTracer(p, buffer=4) as st:
        st.instant("a", "t", 0.0)
        with pytest.raises(ValueError, match="jsonl_to_chrome"):
            st.export(tmp_path / "elsewhere.json")
    # context exit closed the file; export() on own path stays a no-op
    assert st.export() == 1
    assert len(jsonl_to_chrome(p)["traceEvents"]) == 3  # 2 meta + 1 event


def test_streaming_trace_audits_like_the_inmemory_export(
        zoo_models, tmp_path):
    """A tiered engine traced through the bounded streaming writer
    yields the same auditable doc as the in-memory tracer (the
    simulated clock makes both runs identical)."""
    cfg, splits, shared, params, payloads = zoo_models
    p = tmp_path / "tiered.jsonl"
    eng = _tiered(splits, params, tracer=StreamingTracer(p, buffer=16))
    for ev in _episode():
        eng.submit("s0", ev, payloads[ev.modality])
    stats = {"transport": eng.fabric.stats()}
    n_stream = eng.tracer.export(other_data=stats)

    ref = _tiered(splits, params, tracer=Tracer())
    for ev in _episode():
        ref.submit("s0", ev, payloads[ev.modality])
    assert n_stream == len(ref.tracer.events) > 16   # ring really spilled
    assert jsonl_to_chrome(p) == ref.tracer.to_chrome(stats)
    rep = audit_file(p)
    assert rep.ok, rep.violations


# ======================================================================
# regression tests: transport/metrics seams (PR 10 bugfix sweep)
# ======================================================================

def test_cancel_survives_history_pruning():
    """A flight whose t_deliver is still in the future must stay
    cancellable no matter how many receipts scroll past it.

    Regression: ``send()`` used to prune ``_flights`` down to the
    flights still present in the last ``max_history`` deliveries, so a
    long-queued live flight silently vanished from the cancel index
    under fleet-scale load and ``cancel()`` returned False.
    """
    from repro.core import BandwidthTrace
    tr = BandwidthTrace.static(1e6)                 # 1 MB/s
    ch = TransportChannel(tr, latency_s=0.0, overhead_bytes=0,
                          max_history=4)
    big = ch.send(int(1e7), 0.0)                    # 10 s on the wire
    assert big.t_deliver >= 10.0
    for i in range(100):                            # >> 4*max_history
        ch.send(100, 0.001 * (i + 1))
    # the big flight is still in the air at t=5 -> must cancel cleanly
    assert ch.cancel(big.flight, 5.0) is True
    assert big.cancelled
    # settled flights DO get pruned once the clock passes them: the
    # index stays bounded after everything has delivered
    for i in range(100):
        ch.send(100, 20.0 + 0.001 * i)
    assert len(ch._flights) <= 4 * ch.max_history + 1


def test_prometheus_collision_disambiguated():
    """Distinct registry keys that sanitize to one Prometheus name
    (``cache.hits`` vs ``cache_hits``) must export under distinct
    names with exactly one ``# TYPE`` line each (duplicate TYPE lines
    are invalid exposition and scrapers reject the whole page)."""
    m = Metrics()
    m.inc("cache.hits", 3)
    m.inc("cache_hits", 5)
    m.set_gauge("cache.hits", 7)                    # cross-kind collision
    text = m.to_prometheus()
    type_lines = [l for l in text.splitlines() if l.startswith("# TYPE")]
    names = [l.split()[2] for l in type_lines]
    assert len(names) == len(set(names)) == 3
    assert "emsserve_cache_hits 3.0" in text
    assert "emsserve_cache_hits_2 5.0" in text
    assert "emsserve_cache_hits_3 7.0" in text
    # deterministic: same registry exports byte-identically
    assert m.to_prometheus() == text


def test_sketch_boundary_value_keeps_error_bound():
    """A value sitting exactly on a bucket boundary (v == gamma^i) must
    keep the advertised |q̂ - q| <= rel_err*q bound.

    Regression: float slop in ``log(v)/log_gamma`` pushed the ratio
    just above the integer i, ``ceil`` landed the value in bucket i+1,
    and the reported midpoint overshot the bound by one ulp-cascade.
    gamma^16 at rel_err=0.01 is such a value on this float stack.
    """
    s = QuantileSketch(rel_err=0.01)
    v = s._gamma ** 16
    s.add(v)
    s.add(10.0 * v)                   # keep min/max clamp from saving us
    got = s.quantile(0.0)
    assert abs(got - v) <= s.rel_err * v
    # structural pin: the boundary value sits in bucket i, not i+1
    assert s._buckets.get(16) == 1
