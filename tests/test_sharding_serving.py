"""Sharding policy rules + LLM serving engine (prefix cache)."""
import numpy as np

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.distributed.sharding import Policy, abstract_mesh
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.serving.engine import LLMServer, Request
from repro.serving.kv_cache import cache_plan, uses_window
from repro.configs.base import SHAPES


# ------------------------------------------------------------- sharding

def _fake_mesh(shape=(4, 2), axes=("data", "model")):
    """AbstractMesh lets us test specs without 8 real devices."""
    return abstract_mesh(shape, axes)


def test_param_pspecs_cover_tree():
    cfg = get_config("mistral-nemo-12b")
    mesh = _fake_mesh()
    pol = Policy(cfg, mesh)
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    specs = pol.param_pspecs(aparams)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(jax.tree.leaves(aparams))
    assert all(isinstance(s, P) for s in leaves)


def test_param_specs_divisible():
    """Every sharded dim divides by its mesh axes (the _fit guarantee)."""
    for arch in ("qwen1.5-32b", "deepseek-v3-671b", "olmoe-1b-7b"):
        cfg = get_config(arch)
        mesh = _fake_mesh((16, 16))
        pol = Policy(cfg, mesh)
        aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                                 jax.random.PRNGKey(0))
        specs = pol.param_pspecs(aparams)

        def check(leaf, spec):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([pol.sizes[a] for a in axes]))
                assert dim % n == 0, (arch, leaf.shape, spec)
        jax.tree.map(check, aparams, specs,
                     is_leaf=lambda x: isinstance(x, P))


def test_norms_replicated():
    cfg = get_config("mistral-nemo-12b")
    pol = Policy(cfg, _fake_mesh())
    aparams = jax.eval_shape(lambda k: T.init_params(cfg, k),
                             jax.random.PRNGKey(0))
    specs = pol.param_pspecs(aparams)
    s = specs["final_norm"]["scale"]
    assert tuple(s) == () or all(a is None for a in tuple(s))


def test_cache_specs_long_vs_batch():
    cfg = get_config("mistral-nemo-12b")
    pol = Policy(cfg, _fake_mesh())
    acache = jax.eval_shape(lambda: T.init_cache(cfg, 8, 64, jnp.bfloat16))
    batch_specs = pol.cache_pspecs(acache, long=False)
    long_specs = pol.cache_pspecs(acache, long=True)
    kb = tuple(batch_specs["0"]["0"]["kv"]["k"])
    kl = tuple(long_specs["0"]["0"]["kv"]["k"])
    assert kb[1] is not None and kb[2] is None      # batch sharded
    assert kl[1] is None and kl[2] is not None      # seq sharded


def test_cache_plan_policy():
    dense = get_config("mistral-nemo-12b")
    jamba = get_config("jamba-v0.1-52b")
    rwkv = get_config("rwkv6-1.6b")
    deeps = get_config("deepseek-v3-671b")
    long = SHAPES["long_500k"]
    d32 = SHAPES["decode_32k"]
    assert uses_window(dense, long) and not uses_window(dense, d32)
    assert not uses_window(jamba, long)     # hybrid: native full attn
    assert not uses_window(rwkv, long)      # attention-free
    assert not uses_window(deeps, long)     # MLA latent
    cl, w = cache_plan(dense, long)
    assert cl == w == dense.long_context_window
    cl, w = cache_plan(dense, d32)
    assert cl == 32768 and w == 0


def test_host_mesh_constrain_runs():
    cfg = reduced(get_config("mistral-nemo-12b"))
    mesh = make_host_mesh()
    pol = Policy(cfg, mesh)
    with mesh:
        x = jnp.zeros((2, 4, cfg.d_model))
        y = pol.constrain(x)
        assert y.shape == x.shape


# ------------------------------------------------------ LLM serving

@pytest.fixture(scope="module")
def server():
    cfg = reduced(get_config("mistral-nemo-12b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_greedy_generation_deterministic(server):
    cfg, params = server
    srv = LLMServer(cfg, params, cache_len=64)
    prompt = np.arange(1, 9, dtype=np.int32)
    r1 = srv.serve_one(Request("a", prompt, max_new_tokens=5))
    srv2 = LLMServer(cfg, params, cache_len=64)
    r2 = srv2.serve_one(Request("b", prompt, max_new_tokens=5))
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_prefix_cache_reuse_same_tokens(server):
    """Second request extending a served prefix re-encodes only the
    suffix AND produces identical continuations."""
    cfg, params = server
    prompt = np.arange(1, 11, dtype=np.int32)
    ext = np.concatenate([prompt, np.array([3, 7], np.int32)])

    srv = LLMServer(cfg, params, cache_len=64, enable_prefix_cache=True)
    srv.serve_one(Request("warm", prompt, max_new_tokens=1))
    r_hit = srv.serve_one(Request("hit", ext, max_new_tokens=6))
    assert r_hit.prefix_hit and r_hit.prefill_tokens == 2

    srv_cold = LLMServer(cfg, params, cache_len=64, enable_prefix_cache=False)
    r_cold = srv_cold.serve_one(Request("cold", ext, max_new_tokens=6))
    assert not r_cold.prefix_hit and r_cold.prefill_tokens == len(ext)
    np.testing.assert_array_equal(r_hit.tokens, r_cold.tokens)


def test_prefix_cache_exact_match(server):
    cfg, params = server
    prompt = np.arange(1, 9, dtype=np.int32)
    srv = LLMServer(cfg, params, cache_len=64)
    r1 = srv.serve_one(Request("a", prompt, max_new_tokens=4))
    r2 = srv.serve_one(Request("b", prompt, max_new_tokens=4))
    assert r2.prefix_hit
    np.testing.assert_array_equal(r1.tokens, r2.tokens)


def test_serving_ssm_arch():
    """Prefix caching works identically for constant-state archs."""
    cfg = reduced(get_config("rwkv6-1.6b"))
    params = T.init_params(cfg, jax.random.PRNGKey(1))
    srv = LLMServer(cfg, params, cache_len=64)
    prompt = np.arange(1, 7, dtype=np.int32)
    r1 = srv.serve_one(Request("a", prompt, max_new_tokens=3))
    r2 = srv.serve_one(Request("b", np.concatenate([prompt, r1.tokens[:1]]),
                               max_new_tokens=3))
    assert r2.prefix_hit and r2.prefill_tokens == 1
