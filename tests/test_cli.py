"""launch/serve.py argument validation (ISSUE 6 satellite).

The fault-schedule flags are validated loudly and EARLY — before any
zoo construction or profiling — so a mis-typed schedule fails in
milliseconds, not after a minute of warmup. Each test drives the real
``main()`` through ``sys.argv`` and pins the refusal message.
"""
import pytest

from repro.launch import serve


def _main(monkeypatch, *argv):
    monkeypatch.setattr("sys.argv", ["serve.py", *argv])
    serve.main()


def test_outage_requires_tiered_spec(monkeypatch):
    with pytest.raises(SystemExit, match="requires a tiered spec"):
        _main(monkeypatch, "--engine", "stream", "--outage-at", "2")


def test_rejoin_requires_outage(monkeypatch):
    with pytest.raises(SystemExit, match="requires --outage-at"):
        _main(monkeypatch, "--engine", "tiered", "--rejoin-at", "3")


def test_rejoin_must_be_strictly_after_outage(monkeypatch):
    with pytest.raises(SystemExit, match="must be strictly after"):
        _main(monkeypatch, "--engine", "tiered",
              "--outage-at", "2", "--rejoin-at", "2")


def test_outage_beyond_episode_horizon_rejected(monkeypatch):
    with pytest.raises(SystemExit, match="beyond the episode horizon"):
        _main(monkeypatch, "--engine", "tiered", "--outage-at", "999")


def test_speculate_requires_tiered_spec(monkeypatch):
    with pytest.raises(SystemExit, match="require a tiered spec"):
        _main(monkeypatch, "--engine", "stream", "--speculate")
    with pytest.raises(SystemExit, match="require a tiered spec"):
        _main(monkeypatch, "--engine", "batch", "--redispatch")


def test_chaos_seed_requires_tiered_and_tiers(monkeypatch):
    with pytest.raises(SystemExit, match="requires a tiered spec"):
        _main(monkeypatch, "--engine", "stream", "--chaos-seed", "7")
    with pytest.raises(SystemExit, match="needs --tiers"):
        _main(monkeypatch, "--engine", "tiered", "--chaos-seed", "7")


def test_chaos_seed_conflicts_with_outage(monkeypatch):
    with pytest.raises(SystemExit, match="conflicts with --outage-at"):
        _main(monkeypatch, "--engine", "tiered",
              "--tiers", "glass,ph1,edge64x",
              "--chaos-seed", "7", "--outage-at", "2")
