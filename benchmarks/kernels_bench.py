"""Kernel micro-benchmarks: jnp-flash vs materialized reference on CPU
(wall time), plus Pallas interpret-mode correctness spot checks. On TPU
the same harness times the Mosaic kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as C


def run(quick=True):
    from repro.kernels import ref
    from repro.models.attention import flash_attention_jnp

    rows = []
    shapes = [(1, 256, 8, 64), (1, 512, 8, 64)] if quick else \
        [(1, 256, 8, 64), (1, 1024, 8, 64), (2, 2048, 16, 64)]
    for (B, S, H, D) in shapes:
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, H, D), jnp.float32)
        flash = jax.jit(lambda q: flash_attention_jnp(q, q, q, causal=True))
        full = jax.jit(lambda q: ref.attention_ref(q, q, q, causal=True))
        t_flash = C.bench(flash, q, iters=3)
        t_full = C.bench(full, q, iters=3)
        rows.append(C.csv_row(f"kernel_flash_jnp_B{B}_S{S}", t_flash * 1e6,
                              f"materialized_us={t_full*1e6:.0f}"))
    return rows


if __name__ == "__main__":
    run(quick=False)
