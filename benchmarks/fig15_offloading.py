"""Paper Figure 15: edge-assisted offloading.

Scenario 2 (static NLOS distances 0-30 m): cumulative episode latency
when offloading vs on-glass, per distance. Scenario 3 (mobility): the
EMT walks 0->30->0 m; adaptive offloading vs always-offload vs
always-on-glass. Latencies combine the measured per-module profile
(scaled to the paper's tiers) with the NLOS bandwidth model.
"""
from __future__ import annotations

import numpy as np

from . import common as C


def _policy(base, trace, **kw):
    from repro.core import AdaptiveOffloadPolicy, HeartbeatMonitor, ProfileTable
    return AdaptiveOffloadPolicy(ProfileTable(base=base),
                                 HeartbeatMonitor(trace), **kw)


def run(quick=True):
    from repro.core import BandwidthTrace, EMSServe, nlos_bandwidth, profile, table6

    cfg = C.emsnet_cfg(quick, text_encoder="tinybert")
    splits, params = C.build_split_models(cfg)
    payloads = C.sample_payloads(cfg)
    C.warmup_engine_models(splits, params, payloads)
    base = profile(splits["m3"], params["m3"], payloads, iters=3)
    events = table6()[1]
    rows = []

    # scenario 2: static distances
    for dist in (0, 5, 10, 20, 30):
        trace = BandwidthTrace.static(nlos_bandwidth(dist))
        res = {}
        for force in ("edge", "glass", None):
            eng = EMSServe(splits, params,
                           policy=_policy(base, trace, force=force),
                           cached=True)
            eng.run_episode(events, lambda ev: payloads[ev.modality])
            res[force or "adaptive"] = eng.cumulative_time()
        rows.append(C.csv_row(
            f"fig15_static_{dist}m", res["adaptive"] * 1e6,
            f"edge={res['edge']*1e3:.1f}ms;glass={res['glass']*1e3:.1f}ms"))
        assert res["adaptive"] <= min(res["edge"], res["glass"]) * 1.05

    # scenario 3: walking 0 -> 30 -> 0 m
    dist = list(np.linspace(0, 30, 11)) + list(np.linspace(30, 0, 10))
    trace = BandwidthTrace.walk(dist, nlos_bandwidth)
    res = {}
    for name, kw in (("adaptive", {}), ("always_edge", {"force": "edge"}),
                     ("always_glass", {"force": "glass"})):
        eng = EMSServe(splits, params, policy=_policy(base, trace, **kw),
                       cached=True)
        eng.run_episode(events, lambda ev: payloads[ev.modality])
        res[name] = eng.cumulative_time()
    rows.append(C.csv_row(
        "fig15_mobility", res["adaptive"] * 1e6,
        f"always_edge={res['always_edge']*1e3:.1f}ms;"
        f"always_glass={res['always_glass']*1e3:.1f}ms"))
    assert res["adaptive"] <= min(res["always_edge"], res["always_glass"]) * 1.05
    return rows


if __name__ == "__main__":
    run(quick=False)
